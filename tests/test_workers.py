"""Unit coverage for the process-worker substrate (DESIGN.md §5 satellites):
ObjectStore thread/process-host safety + spill re-admission, CheckpointManager
mirror rotation/pinning/adoption, narrow-dtype checkpoint bytes, spawn-safe
factories, and the raw ProcessWorker command protocol."""
import os
import threading

import numpy as np
import pytest

from repro.core import (CheckpointManager, ObjectStore, TrainableFactory,
                        factory_from_class, tree_from_bytes, tree_to_bytes)
from repro.core.workers import ProcessWorker

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
COUNTER_FACTORY = TrainableFactory(target="_worker_trainables:Counter",
                                   sys_path=(TESTS_DIR,))


# ---------------------------------------------------------------------------------
# ObjectStore: lock safety + spill surface
# ---------------------------------------------------------------------------------

class TestObjectStoreConcurrency:
    def test_hammer_from_threads(self, tmp_path):
        store = ObjectStore(capacity_bytes=20_000, spill_dir=str(tmp_path))
        errors = []

        def worker(tid):
            try:
                for i in range(200):
                    key = f"t{tid}/obj{i % 7}"
                    store.put(np.arange(64) + tid, key=key)
                    assert store.get(key).shape == (64,)
                    if i % 5 == 0:
                        store.delete(key)
                    store.contains(key)
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(t,)) for t in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors

    def test_get_readmits_spilled_entry(self, tmp_path):
        store = ObjectStore(capacity_bytes=300, spill_dir=str(tmp_path))
        a = store.put(np.arange(32, dtype=np.float64))  # 256B
        b = store.put(np.arange(32, dtype=np.float64))  # evicts a to disk
        assert store.n_evicted == 1
        assert a not in store._mem and store.contains(a)
        got = store.get(a)  # disk read -> re-admitted into the LRU
        assert a in store._mem
        np.testing.assert_array_equal(got, np.arange(32, dtype=np.float64))
        # second get is a pure memory hit (b was evicted to make room)
        assert store.get(a) is not None and b not in store._mem

    def test_put_spilled_and_export_cross_store(self, tmp_path):
        """Two stores sharing a spill dir see each other's spilled entries —
        the process-IPC surface."""
        producer = ObjectStore(spill_dir=str(tmp_path))
        consumer = ObjectStore(spill_dir=str(tmp_path))
        key = producer.put_spilled(b"checkpoint-bytes", key="ckpt/t/1")
        assert consumer.contains(key)
        assert consumer.get(key) == b"checkpoint-bytes"
        # export: force a memory-resident entry onto the shared surface
        k2 = producer.put({"x": 1}, key="obj/x")
        path = producer.export(k2)
        assert os.path.exists(path)
        assert consumer.get(k2) == {"x": 1}

    def test_peek_does_not_readmit(self, tmp_path):
        store = ObjectStore(capacity_bytes=300, spill_dir=str(tmp_path))
        a = store.put(np.arange(32, dtype=np.float64))
        store.put(np.arange(32, dtype=np.float64))  # evicts a to disk
        assert a not in store._mem
        np.testing.assert_array_equal(store.peek(a),
                                      np.arange(32, dtype=np.float64))
        assert a not in store._mem  # one-shot read: no cache, no LRU churn

    def test_no_spill_dir_still_refuses_eviction(self):
        store = ObjectStore(capacity_bytes=300)
        store.put(np.arange(32, dtype=np.float64))
        with pytest.raises(RuntimeError, match="spill_dir"):
            store.put(np.arange(32, dtype=np.float64))


# ---------------------------------------------------------------------------------
# Checkpoint codec: narrow dtypes are a hard requirement for the bytes path
# ---------------------------------------------------------------------------------

class TestCheckpointDtypes:
    @pytest.mark.parametrize("dtype", ["float16", "bfloat16", "float32", "int8",
                                       "uint32", "bool"])
    def test_numpy_roundtrip(self, dtype):
        import ml_dtypes
        dt = np.dtype(dtype) if dtype != "bfloat16" else np.dtype(ml_dtypes.bfloat16)
        x = np.arange(12).reshape(3, 4).astype(dt)
        out = tree_from_bytes(tree_to_bytes({"x": x, "nested": [x, (x,)]}))
        assert out["x"].dtype == dt
        np.testing.assert_array_equal(out["x"].astype(np.float64),
                                      x.astype(np.float64))

    @pytest.mark.parametrize("dtype", ["bfloat16", "float16"])
    def test_jax_array_roundtrip(self, dtype):
        import jax.numpy as jnp
        x = jnp.linspace(0, 1, 8, dtype=dtype)
        out = tree_from_bytes(tree_to_bytes({"w": x}))
        assert str(out["w"].dtype) == dtype
        np.testing.assert_allclose(out["w"].astype(np.float32),
                                   np.asarray(x, dtype=np.float32))

    def test_scalars_and_structure(self):
        tree = {"a": 1, "b": 2.5, "c": None, "d": "s", "e": True,
                "f": [1, (2, 3)], "g": np.float32(7)}
        out = tree_from_bytes(tree_to_bytes(tree))
        assert out["f"] == [1, (2, 3)] and out["g"] == 7.0

    def test_crc_detects_corruption(self):
        data = bytearray(tree_to_bytes({"x": np.arange(4)}))
        data[10] ^= 0xFF
        with pytest.raises(IOError, match="CRC"):
            tree_from_bytes(bytes(data))


# ---------------------------------------------------------------------------------
# CheckpointManager: rotation deletes mirrors, pinning, adopt/export
# ---------------------------------------------------------------------------------

def _mgr(tmp_path, **kw):
    store = ObjectStore(spill_dir=str(tmp_path / "spill"))
    return CheckpointManager(store, dir=str(tmp_path / "ckpt"), **kw)


class TestCheckpointManager:
    def test_rotation_deletes_store_and_mirror(self, tmp_path):
        mgr = _mgr(tmp_path, keep_last=2, durable=True)
        ckpts = [mgr.save("t", i, {"n": np.asarray(i)}) for i in range(1, 5)]
        # first two rotated out: store entry AND disk mirror gone
        for old in ckpts[:2]:
            assert not mgr.store.contains(old.store_key)
            assert not os.path.exists(old.path)
        for live in ckpts[2:]:
            assert mgr.store.contains(live.store_key)
            assert os.path.exists(live.path)
        assert mgr.latest("t") is ckpts[-1]

    def test_rotation_keeps_references_shared_with_live_entries(self, tmp_path):
        """A PBT rewind re-reaches an iteration and checkpoints it again, so
        two history entries share a store key and mirror path; rotating the
        old entry must not destroy the live entry's data."""
        mgr = _mgr(tmp_path, keep_last=2, durable=True)
        mgr.save("t", 1, {"n": 1})
        mgr.save("t", 2, {"n": 2})
        rewound = mgr.save("t", 2, {"n": 22})  # same key/path as the iter-2 above
        mgr.save("t", 3, {"n": 3})  # rotates the OLD iter-2 entry
        assert mgr.store.contains(rewound.store_key)
        assert os.path.exists(rewound.path)
        assert mgr.restore(rewound) == {"n": 22}

    def test_pinned_checkpoint_survives_rotation(self, tmp_path):
        mgr = _mgr(tmp_path, keep_last=1, durable=True)
        donor = mgr.save("t", 1, {"n": np.asarray(1)})
        donor.pinned = True  # what PBT does when staging an exploit
        later = [mgr.save("t", i, {"n": np.asarray(i)}) for i in range(2, 5)]
        assert mgr.store.contains(donor.store_key)
        assert os.path.exists(donor.path)
        assert mgr.restore(donor) == {"n": np.asarray(1)}
        # unpinned intermediates were rotated normally
        assert not mgr.store.contains(later[0].store_key)

    def test_adopt_bytes_and_restore_decodes(self, tmp_path):
        """The process-worker path: child puts tree_to_bytes payloads on the
        spill surface; the host adopts them and restore() yields the tree."""
        mgr = _mgr(tmp_path, durable=True)
        payload = tree_to_bytes({"n": np.arange(3)})
        key = mgr.store.put_spilled(payload, key="ckpt/t/7")
        ckpt = mgr.adopt("t", 7, key)
        assert ckpt.training_iteration == 7
        assert os.path.exists(ckpt.path)  # durable mirror, raw bytes
        restored = mgr.restore(ckpt)
        np.testing.assert_array_equal(restored["n"], np.arange(3))
        # the mirror is load_pytree-compatible (same on-disk format)
        from repro.core import load_pytree
        np.testing.assert_array_equal(load_pytree(ckpt.path)["n"], np.arange(3))

    def test_export_copy_from_memory_and_disk(self, tmp_path):
        """export_copy snapshots the payload under a fresh private key — the
        source can be rotated/rewritten without invalidating the reader."""
        mgr = _mgr(tmp_path, durable=True)
        ckpt = mgr.save("t", 1, {"n": np.asarray(5)})
        key = mgr.export_copy(ckpt)
        assert key != ckpt.store_key and key.startswith("export/")
        other = ObjectStore(spill_dir=str(tmp_path / "spill"))
        assert other.contains(key)
        # even after the source is deleted, the snapshot survives
        mgr.store.delete(ckpt.store_key)
        assert other.contains(key)
        # disk-only checkpoint (store lost, e.g. after restart): re-exported
        key2 = mgr.export_copy(ckpt)
        assert key2 != key and other.contains(key2)


# ---------------------------------------------------------------------------------
# Spawn-safe factories
# ---------------------------------------------------------------------------------

class TestTrainableFactory:
    def test_resolve_target(self):
        cls = COUNTER_FACTORY.resolve()
        t = cls({"inc": 2})
        assert t.train()["n"] == 2

    def test_factory_from_class_importable(self):
        from _worker_trainables import Counter
        fac = factory_from_class(Counter)
        assert fac is not None
        assert fac.resolve() is Counter

    def test_factory_from_class_rejects_locals(self):
        from repro.core.api import Trainable

        class Local(Trainable):
            pass

        assert factory_from_class(Local) is None

    def test_callable_factory(self):
        fac = TrainableFactory(target="repro.core.api:wrap_function",
                               args=(_a_training_fn,), call=True)
        cls = fac.resolve()
        assert cls.__name__.startswith("Function[")

    def test_registry_roundtrip(self):
        from repro.core import register_worker_factory, resolve_worker_factory
        register_worker_factory("counter-test", COUNTER_FACTORY)
        assert resolve_worker_factory("counter-test") is COUNTER_FACTORY
        with pytest.raises(KeyError, match="register_worker_factory"):
            resolve_worker_factory("nope-not-registered")


def _a_training_fn(tune):  # module-level: picklable for the factory test
    tune.report(loss=1.0)


# ---------------------------------------------------------------------------------
# Raw worker protocol
# ---------------------------------------------------------------------------------

@pytest.mark.timeout(120)
class TestProcessWorkerProtocol:
    def _recv(self, w, want, timeout=60.0):
        assert w.conn.poll(timeout), f"no {want} within {timeout}s"
        msg = w.conn.recv()
        assert msg[0] == want, msg
        return msg

    def test_step_save_restore_reset_stop(self, tmp_path):
        spill = str(tmp_path / "spill")
        w = ProcessWorker(COUNTER_FACTORY, "t0", {"inc": 1}, spill)
        try:
            self._recv(w, "READY")
            w.send("STEP")
            _, iteration, metrics, done = self._recv(w, "RESULT")
            assert (iteration, done) == (1, False) and metrics["n"] == 1

            w.send("SAVE")
            _, key, it = self._recv(w, "SAVED")
            assert it == 1
            # checkpoint bytes are on the shared spill surface, decodable
            host_store = ObjectStore(spill_dir=spill)
            state = tree_from_bytes(host_store.get(key))
            assert state == {"n": 1}

            w.send("STEP")
            self._recv(w, "RESULT")
            w.send("RESTORE", key, 1)
            self._recv(w, "RESTORED")
            w.send("STEP")
            _, iteration, metrics, _ = self._recv(w, "RESULT")
            assert iteration == 2 and metrics["n"] == 2  # restored n=1, +1

            w.send("RESET_CONFIG", {"inc": 10})
            _, ok = self._recv(w, "RESET")
            assert ok
            w.send("STEP")
            _, _, metrics, _ = self._recv(w, "RESULT")
            assert metrics["n"] == 12

            w.send("STOP")
            self._recv(w, "STOPPED")
            assert w.join(timeout=30)
        finally:
            w.kill()

    def test_error_reported_not_fatal_to_parent(self, tmp_path):
        fac = TrainableFactory(target="_worker_trainables:CrashOnce",
                               sys_path=(TESTS_DIR,))
        w = ProcessWorker(fac, "t0", {"fail_at": 1, "marker_dir": str(tmp_path)},
                          str(tmp_path / "spill"))
        try:
            self._recv(w, "READY")
            w.send("STEP")
            msg = self._recv(w, "ERROR")
            assert "injected failure" in msg[1]
            assert w.join(timeout=30)  # worker exits after reporting
        finally:
            w.kill()

    def test_kill_reclaims_mid_step(self, tmp_path):
        fac = TrainableFactory(target="_worker_trainables:Sleeper",
                               sys_path=(TESTS_DIR,))
        w = ProcessWorker(fac, "t0", {"sleep_s": 60.0}, str(tmp_path / "spill"))
        try:
            self._recv(w, "READY")
            w.send("STEP")  # now stuck inside a 60s step
            w.kill(join_timeout=10)
            assert not w.alive()  # SIGKILL reclaims what a thread never could
        finally:
            if w.alive():
                w.kill()
