"""Satellite 1: the scenario matrix reaches the *process* tier.

``SimWorkerTrainable`` runs the scenario DSL's fault vocabulary inside real
spawned worker processes — crashes are raises, kills are ``os._exit``, and
stragglers sleep real wall seconds while the controller's deadline math
rides the injected clock (the virtual-deadline contract from PR 5)."""
import os

import pytest

from repro.core import (CheckpointManager, EventType, FIFOScheduler,
                        ObjectStore, Resources, Trial, TrialStatus)
from repro.core.clock import VirtualClock
from repro.core.process_executor import ProcessMeshExecutor
from repro.core.workers import TrainableFactory
from repro.testing import Scenario, run_scenario
from repro.testing.invariants import check_all
from repro.testing.simworker import SimWorkerTrainable, _fire


def _fifo():
    return FIFOScheduler(metric="loss", mode="min")


class TestFireMarkers:
    def test_fire_consumes_exactly_limit_across_incarnations(self, tmp_path):
        d = str(tmp_path)
        assert _fire(d, "s0", "crash", 2)       # incarnation 1
        assert _fire(d, "s0", "crash", 2)       # incarnation 2
        assert not _fire(d, "s0", "crash", 2)   # budget durably spent
        assert not _fire(d, "s0", "crash", 0)   # limit 0 never fires
        assert not _fire("", "s0", "crash", 5)  # no dir -> no faults
        assert sorted(os.listdir(d)) == ["s0.crash.0", "s0.crash.1"]

    def test_fire_sites_are_independent(self, tmp_path):
        d = str(tmp_path)
        assert _fire(d, "s0", "crash", 1)
        assert _fire(d, "s0", "kill", 1)        # different site, own budget
        assert _fire(d, "s1", "crash", 1)       # different trial, own budget
        assert not _fire(d, "s0", "crash", 1)


@pytest.mark.timeout(600)
class TestProcessTierScenarios:
    def test_fault_storm_in_real_processes(self):
        """The pscen acceptance run: 8 trials, one mid-run raise, one real
        ``os._exit`` kill, one double-crash that exhausts max_failures=1 —
        all faults reconcile through check_all."""
        cfgs = []
        crashes = fatal = 0
        for i in range(8):
            cfg = {"lr": 0.01 + i * 0.001}
            if i == 2:
                cfg["crash_at"] = 2
                crashes += 1
            if i == 5:
                cfg["kill_at"] = 3
                crashes += 1
            if i == 7:
                cfg["crash_at"] = 1
                cfg["crash_count"] = 2
                crashes += 2
                fatal += 1
            cfgs.append(cfg)
        sc = Scenario(name="pstorm", configs=cfgs, stop_iteration=4,
                      max_failures=1, heartbeat_timeout=60.0,
                      expected_crashes=crashes, expected_fatal=fatal)
        res = run_scenario(sc, _fifo, executor="process", pool_devices=8)
        check_all(res)
        by = res.by_status()
        assert by == {"TERMINATED": 7, "ERROR": 1}, by
        # crash@2, kill@3, and the double-crasher's FIRST crash all restart.
        assert res.runner.n_restarts == 3
        assert res.runner.n_errors == 1
        # The killed/crashed trials RESUMED (gapless streams already checked
        # by check_all; the ERROR trial is the double-crasher).
        (err,) = [t for t in res.trials if t.status == TrialStatus.ERROR]
        assert err.config.get("crash_count") == 2

    def test_virtual_deadline_kills_real_straggler(self, tmp_path):
        """A child stuck in a *real* sleep is reaped by a five-minute
        straggler deadline that elapses in virtual milliseconds: deadline
        arithmetic reads the injected clock, never the child's wall."""
        clock = VirtualClock()
        factory = TrainableFactory(
            target="repro.testing.simworker:SimWorkerTrainable")
        ex = ProcessMeshExecutor(
            factory_resolver=lambda _n: factory,
            checkpoint_manager=CheckpointManager(ObjectStore()),
            clock=clock, heartbeat_timeout=0.0, straggler_deadline=300.0,
            spawn_timeout=0, checkpoint_freq=1)
        # Stall on the FIRST step: that one is credited by READY's initial
        # grant, so no runner is needed to put the worker in_step.
        trial = Trial({"sim_id": "strag", "fault_dir": str(tmp_path),
                       "straggle_at": 1, "straggle_wall_s": 60.0},
                      trainable_name="SimWorkerTrainable",
                      resources=Resources(cpu=1.0, devices=1),
                      stopping_criteria={"training_iteration": 5},
                      trial_id="strag-0")
        try:
            assert ex.start_trial(trial)
            seen = []
            while not any(e.type == EventType.ERROR for e in seen):
                ev = ex.get_next_event(timeout=30.0)  # 30 virtual s per call
                if ev is not None:
                    seen.append(ev)
                assert clock.monotonic() < 100_000.0, (
                    f"no ERROR after huge virtual wait; saw "
                    f"{[e.type for e in seen]}")
            kinds = [e.type for e in seen]
            assert EventType.HEARTBEAT_MISSED not in kinds  # warnings off
            assert EventType.KILLED in kinds, kinds
            killed = next(e for e in seen if e.type == EventType.KILLED)
            assert killed.info.get("stalled_s", 0) >= 300.0
            assert clock.monotonic() >= 300.0   # the deadline truly elapsed
            assert EventType.RESULT not in kinds  # it never finished a step
        finally:
            ex.shutdown()

    def test_straggler_scenario_roundtrip(self):
        """The DSL path: ``straggle_at`` in a process-tier scenario produces
        HEARTBEAT_MISSED warnings that reconcile in check_all."""
        cfgs = [{"lr": 0.01}, {"lr": 0.012, "straggle_at": 2}]
        sc = Scenario(name="pstrag", configs=cfgs, stop_iteration=3,
                      max_failures=1, heartbeat_timeout=0.5,
                      expected_stragglers=1)
        res = run_scenario(sc, _fifo, executor="process", pool_devices=4)
        check_all(res)
        assert res.by_status() == {"TERMINATED": 2}


class TestSimWorkerTrainableUnit:
    """In-process contract checks (no spawn): loss shape, save/restore,
    reset_config — the parts every scheduler in the matrix leans on."""

    def test_loss_and_checkpoint_roundtrip(self, tmp_path):
        t = SimWorkerTrainable({"lr": 0.03, "sim_id": "u0",
                                "fault_dir": str(tmp_path)})
        r1 = t.step()
        assert r1["loss"] == pytest.approx((0.03 - 0.01) ** 2 + 1.0)
        state = t.save()
        t.step()
        t.restore(state)
        assert t.step()["n"] == 2

    def test_reset_config_moves_lr(self, tmp_path):
        t = SimWorkerTrainable({"lr": 0.03, "sim_id": "u1",
                                "fault_dir": str(tmp_path)})
        assert t.reset_config({"lr": 0.01})
        assert t.step()["loss"] == pytest.approx(1.0)

    def test_crash_durably_consumed(self, tmp_path):
        cfg = {"lr": 0.01, "sim_id": "u2", "fault_dir": str(tmp_path),
               "crash_at": 1}
        t = SimWorkerTrainable(cfg)
        with pytest.raises(RuntimeError, match="injected crash"):
            t.step()
        # A rebuilt incarnation sees the marker and sails through.
        t2 = SimWorkerTrainable(cfg)
        assert t2.step()["n"] == 1
