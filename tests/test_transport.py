"""Cluster wire framing: handshake, heartbeats, fuzzed malformed input, and
the wall-jump-safe age-math contract (DESIGN.md §11).

The framing tests are adversarial by design: partial reads, oversized
frames, corrupt length prefixes, truncated pickles and mid-frame
disconnects must every one surface as a typed TransportError the pump can
route to host eviction — never a wedge, never a silent misparse.
"""
import os
import pickle
import socket
import struct
import threading
import time

import pytest

from repro.cluster.transport import (DEFAULT_MAX_FRAME, HEARTBEAT, MAGIC,
                                     PROTO_VERSION, FramingError,
                                     SocketTransport, TransportClosed,
                                     TransportError, client_handshake,
                                     server_handshake, virtual_pair)
from repro.core.clock import VirtualClock, WallClock


def pair():
    """A connected (server_transport, client_socket) pair, handshake done."""
    srv = socket.create_server(("127.0.0.1", 0))
    addr = srv.getsockname()[:2]
    out = {}

    def serve():
        s, _ = srv.accept()
        out["tr"], out["hello"] = server_handshake(s)

    t = threading.Thread(target=serve)
    t.start()
    c = socket.create_connection(addr)
    ctr = client_handshake(c, {"trial_id": "t0", "pid": 1, "token": "tok"})
    t.join()
    srv.close()
    return out["tr"], ctr, out["hello"]


class TestFraming:
    def test_round_trip_and_hello(self):
        tr, ctr, hello = pair()
        assert hello == {"trial_id": "t0", "pid": 1, "token": "tok"}
        ctr.send(("STEP", {"k": 1}))
        assert tr.recv() == ("STEP", {"k": 1})
        tr.send(("RESULT", [1, 2, 3]))
        assert ctr.recv() == ("RESULT", [1, 2, 3])
        tr.close()
        ctr.close()

    def test_heartbeat_is_zero_length_frame_and_returns_sentinel(self):
        tr, ctr, _ = pair()
        ctr.send_heartbeat()
        # Must RETURN the sentinel, not swallow it and block for a next
        # frame: a recv that loops would wedge the shared pump thread.
        assert tr.recv() == HEARTBEAT
        tr.close()
        ctr.close()

    def test_large_frame_round_trips(self):
        tr, ctr, _ = pair()
        blob = os.urandom(2_000_000)
        ctr.send(("CKPT", blob))
        kind, got = tr.recv()
        assert kind == "CKPT" and got == blob
        tr.close()
        ctr.close()

    def test_partial_reads_reassemble(self):
        """A frame dribbled one byte at a time still parses (TCP gives no
        message boundaries; _read_exact must loop)."""
        tr, ctr, _ = pair()
        payload = pickle.dumps(("STEP", {"x": list(range(100))}))
        frame = struct.pack("!I", len(payload)) + payload
        done = []

        def dribble():
            for i in range(len(frame)):
                ctr.sock.sendall(frame[i:i + 1])
                if i % 50 == 0:
                    time.sleep(0.001)
            done.append(True)

        t = threading.Thread(target=dribble)
        t.start()
        assert tr.recv() == ("STEP", {"x": list(range(100))})
        t.join()
        assert done
        tr.close()
        ctr.close()


class TestMalformedInput:
    """Every corruption class maps to a typed error, immediately."""

    def test_oversized_frame_rejected_before_allocation(self):
        tr, ctr, _ = pair()
        ctr.sock.sendall(struct.pack("!I", DEFAULT_MAX_FRAME + 1))
        with pytest.raises(FramingError, match="cap"):
            tr.recv()
        tr.close()
        ctr.close()

    def test_corrupt_length_prefix_garbage_payload(self):
        """A plausible length followed by non-pickle bytes -> FramingError
        (corrupt stream), not a crash and not a hang."""
        tr, ctr, _ = pair()
        junk = b"\x00\x01\x02not a pickle at all"
        ctr.sock.sendall(struct.pack("!I", len(junk)) + junk)
        with pytest.raises(FramingError):
            tr.recv()
        tr.close()
        ctr.close()

    def test_mid_frame_disconnect_is_transport_closed(self):
        tr, ctr, _ = pair()
        payload = pickle.dumps(("STEP",))
        # Announce a full frame, deliver half, vanish.
        ctr.sock.sendall(struct.pack("!I", len(payload)) + payload[: len(payload) // 2])
        ctr.close()
        with pytest.raises(TransportClosed, match="mid-frame"):
            tr.recv()
        tr.close()

    def test_clean_disconnect_between_frames_is_transport_closed(self):
        tr, ctr, _ = pair()
        ctr.close()
        with pytest.raises(TransportClosed):
            tr.recv()
        tr.close()

    def test_error_taxonomy_matches_pump_except_clause(self):
        """The base pump catches (EOFError, OSError); both cluster error
        types must land in that net without the core importing cluster."""
        assert issubclass(TransportClosed, EOFError)
        assert issubclass(FramingError, OSError)
        assert issubclass(TransportClosed, TransportError)
        assert issubclass(FramingError, TransportError)

    @pytest.mark.parametrize("greeting", [
        b"HTTP/1.1 GET /",                      # wrong protocol entirely
        b"XXXX" + bytes([PROTO_VERSION]),       # bad magic
        MAGIC + bytes([PROTO_VERSION + 1]),     # version skew
    ])
    def test_handshake_rejects_bad_greeting(self, greeting):
        srv = socket.create_server(("127.0.0.1", 0))
        addr = srv.getsockname()[:2]
        err = []

        def serve():
            s, _ = srv.accept()
            try:
                server_handshake(s, timeout=5.0)
            except TransportError as e:
                err.append(e)

        t = threading.Thread(target=serve)
        t.start()
        c = socket.create_connection(addr)
        c.sendall(greeting)
        t.join()
        assert err, "server accepted a bad greeting"
        c.close()
        srv.close()

    def test_fuzz_random_prefixes_never_wedge(self):
        """Random garbage streams: recv must raise a TransportError subclass
        within the socket timeout, never hang and never raise anything the
        pump wouldn't catch."""
        import random
        rng = random.Random(0)
        for trial in range(8):
            tr, ctr, _ = pair()
            tr.sock.settimeout(5.0)
            n = rng.randint(1, 64)
            ctr.sock.sendall(bytes(rng.getrandbits(8) for _ in range(n)))
            ctr.close()  # garbage then EOF
            with pytest.raises((TransportError, OSError)):
                while True:  # at most a few frames of garbage then EOF
                    tr.recv()
            tr.close()


class TestWallJumpSafety:
    """Satellite 2: heartbeat/reconnect age math must read clock.monotonic()
    (never time.time()) — the PR 5 wall-jump-safe contract."""

    class JumpyClock(WallClock):
        """Wall clock whose epoch axis teleports hours on every read; the
        monotonic axis stays honest.  Any age math that touches time()
        becomes wildly wrong under it."""

        def __init__(self):
            super().__init__()
            self._jump = 0.0

        def time(self):
            self._jump = -self._jump + (3600.0 if self._jump <= 0 else 0.0)
            return super().time() + self._jump

    def test_recv_stamp_rides_monotonic_not_wall(self):
        clock = self.JumpyClock()
        srv = socket.create_server(("127.0.0.1", 0))
        addr = srv.getsockname()[:2]
        out = {}

        def serve():
            s, _ = srv.accept()
            out["tr"], _ = server_handshake(s, clock=clock)

        t = threading.Thread(target=serve)
        t.start()
        c = socket.create_connection(addr)
        ctr = client_handshake(c, {"trial_id": "t", "pid": 0, "token": ""})
        t.join()
        srv.close()
        tr = out["tr"]
        before = clock.monotonic()
        ctr.send_heartbeat()
        assert tr.recv() == HEARTBEAT
        after = clock.monotonic()
        # The stamp sits inside the monotonic window: a time()-based stamp
        # would be off by +-1h.
        assert before <= tr.last_recv_mono <= after
        assert abs(tr.last_recv_mono - clock.monotonic()) < 60.0
        tr.close()
        ctr.close()

    def test_host_age_math_survives_wall_jumps(self):
        """A 2-host virtual-tier mini-run under the jumpy clock: heartbeat
        ages stay sane, so no host is ever evicted and every trial finishes.
        If any eviction path read time(), the +-1h teleports would blow the
        1s host_timeout instantly."""
        from repro.cluster import ClusterMeshExecutor
        from repro.cluster.sim import SimFleet
        from repro.core import (CheckpointManager, ObjectStore, Resources,
                                Trial, TrialRunner, FIFOScheduler)
        from repro.core.clock import use_clock
        from repro.core.workers import TrainableFactory

        clock = self.JumpyClock()
        with use_clock(clock):
            ex = ClusterMeshExecutor(
                checkpoint_manager=CheckpointManager(ObjectStore()),
                hosts="2x2", transport="virtual", placement="fixed",
                heartbeat_timeout=0.2,  # -> 0.05s monitor cadence
                host_timeout=1.0, spawn_timeout=0,
                checkpoint_freq=1, clock=clock,
                factory_resolver=lambda _n: TrainableFactory(
                    target="repro.testing.sim:SimTrainable"))
            fleet = SimFleet(ex, clock, heartbeat_interval=0.05)
            runner = TrialRunner(
                FIFOScheduler(metric="loss", mode="min"), ex,
                trainable_name="SimTrainable",
                stopping_criteria={"training_iteration": 3})
            for i in range(3):
                runner.add_trial(Trial(
                    {"sim_id": f"j{i}", "sim_token": "jumpy",
                     "step_s": 0.05},
                    trainable_name="SimTrainable",
                    resources=Resources(cpu=1.0, devices=1),
                    stopping_criteria={"training_iteration": 3},
                    trial_id=f"jumpy-{i}"))
            fleet.start()
            try:
                trials = runner.run()
            finally:
                fleet.stop()
        assert ex.n_host_evictions == 0, (
            "wall-time jumps triggered a host eviction: some age math is "
            "reading time() instead of monotonic()")
        assert all(t.status.value == "TERMINATED" for t in trials)


class TestVirtualTransport:
    def test_round_trip_eof_and_partition(self):
        clock = VirtualClock()
        a, b = virtual_pair(clock, name="v")
        a.send(("STEP",))
        assert b.recv() == ("STEP",)
        assert not b.poll(0)
        a.close()
        with pytest.raises(TransportClosed):
            b.recv()

    def test_partition_drops_silently_but_close_delivers(self):
        clock = VirtualClock()
        dropped = []
        a, b = virtual_pair(clock, name="p",
                            drop=lambda side, obj: dropped.append(obj) or True)
        a.send(("RESULT", 1))
        assert b._q.empty() and dropped == [("RESULT", 1)]
        # A SIGKILL'd process's FIN still arrives through a partition.
        a.close()
        with pytest.raises(TransportClosed):
            b.recv()

    def test_send_after_peer_close_raises(self):
        clock = VirtualClock()
        a, b = virtual_pair(clock, name="c")
        b.close()
        with pytest.raises(TransportClosed):
            a.send(("STEP",))
