"""Model zoo correctness: decode==forward consistency, chunked-scan
equivalence, family-specific behaviours."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (ModelConfig, MoEConfig, decode_step, forward_encode,
                          forward_train, init_params, prefill)
from repro.models.rwkv6 import _wkv_chunked, _wkv_step
from repro.models.rglru import _rglru_scan
from repro.models.moe import apply_moe_layer, init_moe_layer

V = 96


def lm_cfg(**kw):
    base = dict(arch_id="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=V)
    base.update(kw)
    return ModelConfig(**base).validate()


def check_decode_matches_forward(cfg, S=17, n_decode=4, atol=2e-3):
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, S + n_decode), 0, cfg.vocab_size)
    full = forward_encode(params, {"tokens": toks}, cfg)
    logits, caches = prefill(params, {"tokens": toks[:, :S]}, cfg, max_len=S + n_decode)
    errs = [float(jnp.abs(logits - full[:, S - 1]).max())]
    for i in range(n_decode):
        logits, caches = decode_step(params, caches, toks[:, S + i],
                                     jnp.asarray(S + i), cfg)
        errs.append(float(jnp.abs(logits - full[:, S + i]).max()))
    assert max(errs) < atol, f"decode diverges from teacher-forcing: {errs}"


class TestDecodeConsistency:
    def test_dense_gqa(self):
        check_decode_matches_forward(lm_cfg())

    def test_dense_mqa_headdim(self):
        check_decode_matches_forward(lm_cfg(n_heads=2, n_kv_heads=1, head_dim=48))

    def test_sliding_window(self):
        check_decode_matches_forward(lm_cfg(sliding_window=8), S=21)

    def test_qkv_bias_layernorm(self):
        check_decode_matches_forward(lm_cfg(qkv_bias=True, norm="layernorm"))

    def test_rwkv6(self):
        check_decode_matches_forward(lm_cfg(
            family="ssm", n_heads=2, rwkv_head_dim=32))

    def test_hybrid_rglru(self):
        check_decode_matches_forward(lm_cfg(
            family="hybrid", n_layers=5, n_kv_heads=1,
            block_pattern=("rglru", "rglru", "local_attn"),
            sliding_window=8, rglru_d_rnn=64))

    def test_geglu_tied_scaled(self):
        check_decode_matches_forward(lm_cfg(
            activation="geglu", tie_embeddings=True, embedding_scale=True))


class TestRWKVChunking:
    @pytest.mark.parametrize("chunk", [1, 7, 16, 37, 64])
    def test_chunked_equals_sequential(self, chunk):
        key = jax.random.key(3)
        B, S, H, N = 2, 37, 2, 8
        r, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, N)) * 0.5
                   for i in range(3))
        logw = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 4), (B, S, H, N)) * 0.5 - 2)
        u = jax.random.normal(jax.random.fold_in(key, 5), (H, N)) * 0.3
        s0 = jax.random.normal(jax.random.fold_in(key, 6), (B, H, N, N)) * 0.2
        ys, st = [], s0
        for t in range(S):
            y, st = _wkv_step(r[:, t], k[:, t], v[:, t], logw[:, t], u, st)
            ys.append(y)
        y_ref = jnp.stack(ys, 1)
        y_c, st_c = _wkv_chunked(r, k, v, logw, u, s0, chunk)
        np.testing.assert_allclose(y_c, y_ref, atol=1e-4)
        np.testing.assert_allclose(st_c, st, atol=1e-4)


class TestRGLRU:
    def test_associative_scan_matches_loop(self):
        key = jax.random.key(0)
        B, S, R = 2, 33, 8
        a = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 1), (B, S, R)))
        b = jax.random.normal(jax.random.fold_in(key, 2), (B, S, R)) * 0.3
        h0 = jax.random.normal(jax.random.fold_in(key, 3), (B, R)) * 0.1
        h = _rglru_scan(a, b, h0)
        hh, out = h0, []
        for t in range(S):
            hh = a[:, t] * hh + b[:, t]
            out.append(hh)
        np.testing.assert_allclose(h, jnp.stack(out, 1), atol=1e-5)

    def test_state_bounded(self):
        """|h| stays bounded: a in (0,1) with sqrt(1-a^2) input normalization."""
        cfg = lm_cfg(family="hybrid", n_layers=3, n_kv_heads=1,
                     block_pattern=("rglru", "rglru", "local_attn"),
                     sliding_window=8, rglru_d_rnn=64)
        params = init_params(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (2, 64), 0, V)
        logits = forward_encode(params, {"tokens": toks}, cfg)
        assert jnp.isfinite(logits).all()


class TestMoE:
    def _cfg(self, **kw):
        moe = MoEConfig(n_experts=4, top_k=2, d_expert=32, group_size=16, **kw)
        return lm_cfg(family="moe", moe=moe)

    def test_output_shape_and_aux(self):
        cfg = self._cfg()
        p = init_moe_layer(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (2, 32, 64))
        out, aux = apply_moe_layer(p, x, cfg)
        assert out.shape == x.shape
        assert jnp.isfinite(out).all() and jnp.isfinite(aux)
        # Switch aux loss is ~1 for near-uniform routing at init
        assert 0.5 < float(aux) < 4.0

    def test_shared_experts_add(self):
        cfg = self._cfg(n_shared=1)
        p = init_moe_layer(jax.random.key(0), cfg)
        assert "shared" in p
        x = jax.random.normal(jax.random.key(1), (2, 32, 64))
        out, _ = apply_moe_layer(p, x, cfg)
        assert jnp.isfinite(out).all()

    def test_capacity_drops_dont_nan(self):
        """Tiny capacity forces token drops; output must stay finite."""
        moe = MoEConfig(n_experts=4, top_k=2, d_expert=16, group_size=16,
                        capacity_factor=0.25)
        cfg = lm_cfg(family="moe", moe=moe)
        p = init_moe_layer(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (1, 64, 64))
        out, aux = apply_moe_layer(p, x, cfg)
        assert jnp.isfinite(out).all()

    def test_moe_gradients_flow_to_experts(self):
        cfg = self._cfg()
        p = init_moe_layer(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (1, 32, 64))

        def loss(p):
            out, aux = apply_moe_layer(p, x, cfg)
            return (out ** 2).mean() + 0.01 * aux

        g = jax.grad(loss)(p)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(l))
                             for l in jax.tree_util.tree_leaves(g)))
        assert float(gnorm) > 0


class TestFrontendStubs:
    def test_audio_masked_loss(self):
        cfg = lm_cfg(family="audio", encoder_only=True, frontend="audio_stub",
                     frontend_dim=24, n_heads=4, n_kv_heads=4)
        params = init_params(jax.random.key(0), cfg)
        feats = jax.random.normal(jax.random.key(1), (2, 32, 24))
        labels = jax.random.randint(jax.random.key(2), (2, 32), 0, V)
        mask = (jnp.arange(32) % 3 == 0)[None, :] * jnp.ones((2, 1))
        loss, m = forward_train(params, {"features": feats, "labels": labels,
                                         "loss_mask": mask}, cfg)
        assert jnp.isfinite(loss)

    def test_vlm_prefix_excluded_from_loss(self):
        cfg = lm_cfg(family="vlm", n_kv_heads=1, frontend="vision_stub",
                     frontend_dim=24, n_prefix_embeds=4)
        params = init_params(jax.random.key(0), cfg)
        pe = jax.random.normal(jax.random.key(1), (2, 4, 24))
        toks = jax.random.randint(jax.random.key(2), (2, 12), 0, V)
        loss, m = forward_train(params, {"patch_embeds": pe, "tokens": toks,
                                         "labels": toks}, cfg)
        assert jnp.isfinite(loss)


class TestEncoderBidirectional:
    def test_encoder_sees_future(self):
        """Bidirectional: changing a future token changes an earlier logit."""
        cfg = lm_cfg(encoder_only=True)
        params = init_params(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (1, 16), 0, V)
        toks2 = toks.at[0, 12].set((toks[0, 12] + 1) % V)
        a = forward_encode(params, {"tokens": toks}, cfg)
        b = forward_encode(params, {"tokens": toks2}, cfg)
        assert float(jnp.abs(a[0, 3] - b[0, 3]).max()) > 0

    def test_causal_does_not_see_future(self):
        cfg = lm_cfg()
        params = init_params(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (1, 16), 0, V)
        toks2 = toks.at[0, 12].set((toks[0, 12] + 1) % V)
        a = forward_encode(params, {"tokens": toks}, cfg)
        b = forward_encode(params, {"tokens": toks2}, cfg)
        np.testing.assert_allclose(a[0, :12], b[0, :12], atol=1e-6)
