"""EventBus contract: typed events, atomic sequencing, thread-safe FIFO
delivery, and the poll-style executor compat shim."""
import threading

from repro.core import (CheckpointManager, EventBus, EventType, FIFOScheduler,
                        ObjectStore, Result, SerialMeshExecutor, Trainable,
                        Trial, TrialEvent)


class Two(Trainable):
    def setup(self, config):
        self.fail = config.get("fail", False)

    def step(self):
        if self.fail:
            raise RuntimeError("kaput")
        return {"loss": 0.5}

    def save(self):
        return {}

    def restore(self, state):
        pass


class TestEventBus:
    def test_fifo_and_seq(self):
        bus = EventBus()
        for i in range(5):
            bus.publish(TrialEvent(EventType.RESULT, f"t{i}"))
        out = bus.drain()
        assert [e.trial_id for e in out] == [f"t{i}" for i in range(5)]
        assert [e.seq for e in out] == [0, 1, 2, 3, 4]
        assert bus.empty() and len(bus) == 0
        assert bus.n_published == 5

    def test_get_timeout_returns_none(self):
        bus = EventBus()
        assert bus.get() is None
        assert bus.get(timeout=0.01) is None

    def test_concurrent_publishers_ordering(self):
        """seq order == delivery order, and per-producer FIFO is preserved,
        under many concurrent publisher threads."""
        bus = EventBus()
        n_threads, n_events = 8, 200
        barrier = threading.Barrier(n_threads)

        def produce(tid):
            barrier.wait()
            for i in range(n_events):
                bus.publish(TrialEvent(EventType.RESULT, f"p{tid}",
                                       info={"i": i}))

        threads = [threading.Thread(target=produce, args=(t,))
                   for t in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        events = bus.drain()
        assert len(events) == n_threads * n_events
        # global sequence numbers are exactly the delivery order
        assert [e.seq for e in events] == list(range(n_threads * n_events))
        # each producer's events arrive in the order it published them
        per_producer = {}
        for e in events:
            per_producer.setdefault(e.trial_id, []).append(e.info["i"])
        for tid, seen in per_producer.items():
            assert seen == list(range(n_events)), tid

    def test_concurrent_drain_while_publishing(self):
        """A consumer draining concurrently with publishers loses nothing."""
        bus = EventBus()
        total = 500
        collected = []
        done = threading.Event()

        def consume():
            while not (done.is_set() and bus.empty()):
                ev = bus.get(timeout=0.01)
                if ev is not None:
                    collected.append(ev)

        consumer = threading.Thread(target=consume)
        consumer.start()
        producers = [threading.Thread(
            target=lambda lo: [bus.publish(TrialEvent(EventType.RESULT, str(i)))
                               for i in range(lo, lo + 100)],
            args=(k * 100,)) for k in range(total // 100)]
        for p in producers:
            p.start()
        for p in producers:
            p.join()
        done.set()
        consumer.join(timeout=5)
        assert len(collected) == total
        assert sorted(e.seq for e in collected) == list(range(total))


class TestCompatShim:
    """Poll-style executors keep working through TrialExecutor.get_next_event."""

    def _executor(self):
        return SerialMeshExecutor(lambda n: Two, CheckpointManager(ObjectStore()),
                                  total_devices=4, checkpoint_freq=0)

    def test_result_event(self):
        ex = self._executor()
        trial = Trial({}, stopping_criteria={"training_iteration": 3})
        assert ex.start_trial(trial)
        ev = ex.get_next_event()
        assert ev.type == EventType.RESULT
        assert ev.trial_id == trial.trial_id
        assert isinstance(ev.result, Result)
        assert ev.result.metrics["loss"] == 0.5
        ex.shutdown()

    def test_error_event(self):
        ex = self._executor()
        trial = Trial({"fail": True})
        assert ex.start_trial(trial)
        ev = ex.get_next_event()
        assert ev.type == EventType.ERROR
        assert "kaput" in ev.error
        ex.shutdown()

    def test_empty_returns_none(self):
        assert self._executor().get_next_event() is None
