"""Scheduler semantics: each of the six Table-1 algorithms behaves per its
source paper, all through the identical TrialScheduler interface."""
import numpy as np
import pytest

from repro.core import (ASHAScheduler, FIFOScheduler, HyperBandScheduler,
                        MedianStoppingRule, PopulationBasedTraining,
                        Resources, SchedulerDecision, Trial, TrialStatus,
                        TrialRunner, CheckpointManager, ObjectStore,
                        SerialMeshExecutor, Trainable, register_trainable,
                        run_experiments, uniform, loguniform)


class DecayTrainable(Trainable):
    """loss = quality + amplitude * 0.8^iter — separable quality per trial."""

    def setup(self, config):
        self.q = config["quality"]
        self.x = 1.0

    def step(self):
        self.x *= 0.8
        return {"loss": self.q + self.x}

    def save(self):
        return {"x": self.x, "q": self.q}

    def restore(self, state):
        self.x = state["x"]
        self.q = state["q"]

    def reset_config(self, cfg):
        self.q = cfg["quality"]
        return True


def run_qualities(qualities, scheduler, max_iter=20, devices=4, checkpoint_freq=1):
    store = ObjectStore()
    executor = SerialMeshExecutor(
        trainable_cls_resolver=lambda name: DecayTrainable,
        checkpoint_manager=CheckpointManager(store),
        total_devices=devices, checkpoint_freq=checkpoint_freq)
    runner = TrialRunner(scheduler, executor,
                         stopping_criteria={"training_iteration": max_iter})
    for i, q in enumerate(qualities):
        runner.add_trial(Trial({"quality": q}, trial_id=f"t{i:03d}",
                               stopping_criteria={"training_iteration": max_iter}))
    trials = runner.run()
    return {t.trial_id: t for t in trials}


class TestFIFO:
    def test_all_run_to_completion(self):
        trials = run_qualities([0.1, 0.5, 0.9], FIFOScheduler(metric="loss", mode="min"))
        assert all(t.training_iteration == 20 for t in trials.values())
        assert all(t.status == TrialStatus.TERMINATED for t in trials.values())


class TestASHA:
    def test_early_stops_bad_trials(self):
        qualities = list(np.linspace(0.0, 2.0, 16))
        sched = ASHAScheduler(metric="loss", mode="min", max_t=20,
                              grace_period=2, reduction_factor=3)
        trials = run_qualities(qualities, sched)
        total = sum(t.training_iteration for t in trials.values())
        assert total < 16 * 20 * 0.6, "ASHA should spend far less than full budget"
        best = min(trials.values(), key=lambda t: t.config["quality"])
        worst = max(trials.values(), key=lambda t: t.config["quality"])
        assert best.training_iteration > worst.training_iteration

    def test_max_t_terminates(self):
        sched = ASHAScheduler(metric="loss", mode="min", max_t=5, grace_period=1)
        trials = run_qualities([0.1], sched, max_iter=50)
        assert trials["t000"].training_iteration <= 5

    def test_validation(self):
        with pytest.raises(ValueError):
            ASHAScheduler(max_t=1, grace_period=5)


class TestHyperBand:
    def test_budget_much_less_than_full(self):
        qualities = list(np.linspace(0.0, 2.0, 18))
        sched = HyperBandScheduler(metric="loss", mode="min", max_t=27, eta=3)
        trials = run_qualities(qualities, sched, max_iter=27)
        total = sum(t.training_iteration for t in trials.values())
        assert total < 18 * 27 * 0.5
        # survivors of successive halving are low-quality(=good) trials
        finishers = [t for t in trials.values() if t.training_iteration >= 27]
        assert finishers and all(t.config["quality"] < 1.0 for t in finishers)

    def test_pause_resume_through_checkpoints(self):
        """Synchronous HB pauses early arrivals; they must resume losslessly."""
        sched = HyperBandScheduler(metric="loss", mode="min", max_t=9, eta=3)
        trials = run_qualities([0.1, 0.2, 0.3, 0.4, 0.5, 0.6], sched,
                               max_iter=9, devices=2)
        assert any(t.training_iteration >= 9 for t in trials.values())


class TestMedianStopping:
    def test_stops_below_median(self):
        qualities = [0.0, 0.1, 0.2, 1.5, 1.6, 1.7]
        sched = MedianStoppingRule(metric="loss", mode="min", grace_period=2,
                                   min_samples_required=2)
        trials = run_qualities(qualities, sched, max_iter=15)
        good = [t for t in trials.values() if t.config["quality"] < 0.5]
        bad = [t for t in trials.values() if t.config["quality"] > 1.0]
        assert sched.n_stopped >= 2
        assert (sum(t.training_iteration for t in good) / len(good)
                > sum(t.training_iteration for t in bad) / len(bad))

    def test_grace_period_respected(self):
        sched = MedianStoppingRule(metric="loss", mode="min", grace_period=5,
                                   min_samples_required=1)
        trials = run_qualities([0.0, 5.0], sched, max_iter=8)
        assert trials["t001"].training_iteration >= 5


class TestPBT:
    def test_exploit_copies_good_params(self):
        sched = PopulationBasedTraining(
            metric="loss", mode="min", perturbation_interval=3,
            hyperparam_mutations={"quality": uniform(0.0, 2.0)},
            quantile_fraction=0.34, seed=0)
        trials = run_qualities([0.0, 1.0, 2.0], sched, max_iter=15, devices=3)
        assert sched.n_exploits >= 1
        # the worst trial should have been overwritten with a donor's config
        worst = trials["t002"]
        assert worst.config["quality"] < 2.0

    def test_explore_perturbs_numeric(self):
        sched = PopulationBasedTraining(metric="loss", mode="min",
                                        hyperparam_mutations={"lr": [1, 2, 4, 8]},
                                        resample_probability=0.0, seed=1)
        new = sched._explore({"lr": 2})
        assert new["lr"] in (1, 4)  # neighbour in the list

    def test_quantile_validation(self):
        with pytest.raises(ValueError):
            PopulationBasedTraining(quantile_fraction=0.9)


class TestSchedulerInterfaceUniformity:
    """Paper claim: one narrow interface is sufficient for all algorithms."""

    def test_all_schedulers_same_interface(self):
        from repro.core.schedulers.base import TrialScheduler
        for cls in (FIFOScheduler, ASHAScheduler, HyperBandScheduler,
                    MedianStoppingRule, PopulationBasedTraining):
            assert issubclass(cls, TrialScheduler)
            assert hasattr(cls, "on_result")
            assert hasattr(cls, "choose_trial_to_run")

    def test_decisions_are_narrow(self):
        assert {d.value for d in SchedulerDecision} == {
            "CONTINUE", "PAUSE", "STOP", "RESTART_WITH_CONFIG"}
