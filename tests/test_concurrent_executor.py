"""ConcurrentMeshExecutor + fault tolerance: worker-thread stepping under the
full scheduler matrix, restart-from-checkpoint bounded by max_failures, the
experiment-level error cap, straggler heartbeats, PBT restart error surfacing,
and crash-durable metric logs.

The sleep-bound tests (heartbeat straggler, abandoned worker, the scheduler
matrix's per-step "device work") run on a ``VirtualClock`` (DESIGN.md §7):
their timelines are the same as the old wall-clock versions — 0.6s steps
against a 0.15s heartbeat, a 1.5s stuck step against a 0.1s join — but they
execute in milliseconds and their event schedules are deterministic, so the
assertions are *tighter* than the wall versions could afford (exact heartbeat
counts, not "at least one")."""
import csv
import glob
import json
import os
import subprocess
import sys
import time

import pytest

from repro.core import (ASHAScheduler, CheckpointManager, ConcurrentMeshExecutor,
                        EventType, FIFOScheduler, HyperBandScheduler,
                        MedianStoppingRule, ObjectStore, PopulationBasedTraining,
                        Resources, SerialMeshExecutor, Trainable, Trial,
                        TrialRunner, TrialStatus, VirtualClock,
                        get_default_clock, loguniform, run_experiments,
                        use_clock)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class LrCounter(Trainable):
    """Cheap surrogate with an lr-separable loss (drives every scheduler)."""

    def setup(self, config):
        self.n = 0
        self.lr = float(config.get("lr", 0.01))

    def step(self):
        self.n += 1
        # a sliver of "device work" to overlap (virtual under a VirtualClock)
        get_default_clock().sleep(0.001)
        return {"loss": (self.lr - 0.01) ** 2 + 1.0 / self.n}

    def save(self):
        return {"n": self.n}

    def restore(self, state):
        self.n = state["n"]

    def reset_config(self, new_config):
        self.lr = float(new_config.get("lr", self.lr))
        self.config = dict(new_config)
        return True


def make_flaky(fail_at: int, max_crashes: int):
    """A Counter that raises at iteration ``fail_at``, ``max_crashes`` times
    total across rebuilds (class-level counter survives restarts)."""

    class Flaky(Trainable):
        crashes = 0

        def setup(self, config):
            self.n = 0

        def step(self):
            self.n += 1
            if self.n == fail_at and type(self).crashes < max_crashes:
                type(self).crashes += 1
                raise RuntimeError(f"injected failure #{type(self).crashes}")
            return {"loss": 1.0 / self.n}

        def save(self):
            return {"n": self.n}

        def restore(self, state):
            self.n = state["n"]

    return Flaky


def make_concurrent(cls, devices=8, checkpoint_freq=1, **kw):
    return ConcurrentMeshExecutor(lambda name: cls,
                                  CheckpointManager(ObjectStore()),
                                  total_devices=devices,
                                  checkpoint_freq=checkpoint_freq, **kw)


SCHEDULERS = {
    "fifo": lambda: FIFOScheduler(metric="loss", mode="min"),
    "asha": lambda: ASHAScheduler(metric="loss", mode="min", max_t=6,
                                  grace_period=2, reduction_factor=2),
    "hyperband": lambda: HyperBandScheduler(metric="loss", mode="min",
                                            max_t=4, eta=2),
    "median": lambda: MedianStoppingRule(metric="loss", mode="min",
                                         grace_period=2, min_samples_required=2),
    "pbt": lambda: PopulationBasedTraining(
        metric="loss", mode="min", perturbation_interval=2,
        hyperparam_mutations={"lr": loguniform(1e-4, 1e-1)}, seed=0),
}


class TestSchedulerMatrix:
    @pytest.mark.parametrize("name", list(SCHEDULERS))
    def test_scheduler_on_concurrent_executor(self, name):
        with use_clock(VirtualClock()) as vc:
            an = run_experiments(
                LrCounter,
                {"lr": loguniform(1e-3, 1e-1)},
                scheduler=SCHEDULERS[name](),
                num_samples=4,
                stop={"training_iteration": 6},
                total_devices=4,
                checkpoint_freq=1,
                executor="concurrent",
                seed=0,
                clock=vc,
            )
        assert an.best_value() is not None
        finished = [t for t in an.trials if t.status == TrialStatus.TERMINATED]
        assert finished, f"{name}: no trial finished"
        assert vc.monotonic() > 0, "no virtual time elapsed — steps never slept"
        for t in an.trials:  # per-trial results arrive strictly in order
            iters = [r.training_iteration for r in t.results]
            assert iters == sorted(iters), (name, t.trial_id, iters)
            for r in t.results:  # stamped on the virtual axis, in order
                assert r.timestamp >= 1_000_000_000


class TestConcurrentBasics:
    def test_parallel_limited_by_resources(self):
        ex = make_concurrent(LrCounter, devices=2, checkpoint_freq=0)
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                             stopping_criteria={"training_iteration": 3})
        for _ in range(5):
            runner.add_trial(Trial({}, resources=Resources(devices=1),
                                   stopping_criteria={"training_iteration": 3}))
        runner.step()
        running = sum(1 for t in runner.trials if t.status == TrialStatus.RUNNING)
        assert running == 2
        trials = runner.run()
        assert all(t.status == TrialStatus.TERMINATED for t in trials)
        assert all(t.training_iteration == 3 for t in trials)

    def test_function_trainable_on_concurrent(self):
        from repro.core import wrap_function

        def train(tune):
            x = 0.0
            for _ in range(4):
                x += tune.params["inc"]
                tune.report(value=x)

        ex = make_concurrent(wrap_function(train), checkpoint_freq=0)
        runner = TrialRunner(FIFOScheduler(metric="value", mode="max"), ex)
        runner.add_trial(Trial({"inc": 2.0}))
        (trial,) = runner.run()
        assert trial.status == TrialStatus.TERMINATED
        vals = [r.metrics["value"] for r in trial.results if "value" in r.metrics]
        assert vals == [2.0, 4.0, 6.0, 8.0]  # the trailing result is the bare done


class TestFaultTolerance:
    def test_concurrent_recovers_and_matches_clean_run(self):
        # clean reference run
        clean_ex = make_concurrent(make_flaky(3, 0))
        clean = TrialRunner(FIFOScheduler(metric="loss", mode="min"), clean_ex,
                            stopping_criteria={"training_iteration": 5})
        clean.add_trial(Trial({}, stopping_criteria={"training_iteration": 5}))
        (clean_t,) = clean.run()
        assert clean_t.status == TrialStatus.TERMINATED

        # crashes twice at iteration 3; max_failures=2 absorbs both
        from repro.core import Logger

        class Recorder(Logger):
            events = []

            def on_event(self, trial, event):
                type(self).events.append(event)

        ex = make_concurrent(make_flaky(3, 2))
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                             logger=Recorder(),
                             stopping_criteria={"training_iteration": 5},
                             max_failures=2)
        trial = Trial({}, stopping_criteria={"training_iteration": 5})
        runner.add_trial(trial)
        runner.run()
        assert trial.status == TrialStatus.TERMINATED
        assert trial.num_failures == 2
        assert runner.n_restarts == 2 and runner.n_errors == 0
        restarts = [e for e in Recorder.events if e.type == EventType.RESTARTED]
        assert len(restarts) == 2  # exactly one RESTARTED per retry, not two
        assert [r.training_iteration for r in trial.results] == \
               [r.training_iteration for r in clean_t.results]
        assert trial.last_result.metrics["loss"] == \
               pytest.approx(clean_t.last_result.metrics["loss"])

    def test_serial_executor_retries_too(self):
        cls = make_flaky(2, 1)
        ex = SerialMeshExecutor(lambda n: cls, CheckpointManager(ObjectStore()),
                                total_devices=4, checkpoint_freq=1)
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                             stopping_criteria={"training_iteration": 4},
                             max_failures=1)
        trial = Trial({}, stopping_criteria={"training_iteration": 4})
        runner.add_trial(trial)
        runner.run()
        assert trial.status == TrialStatus.TERMINATED
        assert trial.num_failures == 1
        assert trial.training_iteration == 4

    def test_failure_budget_exhausted_marks_error(self):
        ex = make_concurrent(make_flaky(2, 99))  # fails every time it reaches 2
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                             stopping_criteria={"training_iteration": 5},
                             max_failures=2)
        trial = Trial({}, stopping_criteria={"training_iteration": 5})
        runner.add_trial(trial)
        runner.run()
        assert trial.status == TrialStatus.ERROR
        assert trial.num_failures == 3  # 2 retries + the final fatal one
        assert "injected failure" in trial.error
        assert runner.n_errors == 1

    def test_experiment_error_cap_aborts(self):
        ex = make_concurrent(make_flaky(1, 99))
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                             stopping_criteria={"training_iteration": 5},
                             max_experiment_failures=1)
        for _ in range(3):
            runner.add_trial(Trial({}, stopping_criteria={"training_iteration": 5}))
        with pytest.raises(RuntimeError, match="experiment aborted"):
            runner.run()
        assert runner.n_errors == 2  # aborted as soon as the cap was crossed


class TestRestartWithConfigSurfaced:
    """PBT restart failures may no longer leave a PAUSED, sliceless trial."""

    class NoReset(Trainable):
        def setup(self, config):
            if config.get("explode"):
                raise RuntimeError("bad rebuild config")
            self.n = 0

        def step(self):
            self.n += 1
            return {"loss": 1.0 / self.n}

        def save(self):
            return {"n": self.n}

        def restore(self, state):
            self.n = state["n"]

        # reset_config inherits the base False → forces teardown + rebuild

    def _started(self):
        ex = SerialMeshExecutor(lambda n: self.NoReset,
                                CheckpointManager(ObjectStore()), total_devices=4)
        trial = Trial({}, resources=Resources(devices=2))
        assert ex.start_trial(trial)
        ex.get_next_result()
        ckpt = ex.save_checkpoint(trial)
        return ex, trial, ckpt

    def test_no_resources_requeues_with_donor_checkpoint(self):
        ex, trial, ckpt = self._started()
        ex.accountant.has_room = lambda r: False  # rebuild finds no capacity
        ex.restart_trial_with_config(trial, ckpt, {"lr": 0.5})
        assert trial.status == TrialStatus.PAUSED
        assert trial.checkpoint is ckpt  # re-launch restores the donor state
        assert trial.config == {"lr": 0.5}
        assert trial.trial_id not in ex._running

    def test_rebuild_error_marks_trial_error(self):
        ex, trial, ckpt = self._started()
        ex.restart_trial_with_config(trial, ckpt, {"explode": True})
        assert trial.status == TrialStatus.ERROR
        assert "bad rebuild config" in trial.error


class TestHeartbeat:
    class Slow(Trainable):
        def setup(self, config):
            self.n = 0

        def step(self):
            get_default_clock().sleep(0.63)
            self.n += 1
            return {"loss": 1.0}

        def save(self):
            return {"n": self.n}

        def restore(self, state):
            self.n = state["n"]

    def test_straggler_emits_heartbeat_missed(self):
        """The wall-clock version of this test could only assert "some
        heartbeat arrived within 10 real seconds".  On virtual time the whole
        schedule is deterministic: a 0.63s step against a 0.15s timeout with
        a 0.05s monitor tick warns every 0.15s — t=0.15/0.30/0.45/0.60, all
        before the RESULT — exactly four warnings, strictly ordered.  (The
        clock's timestamp-axis quantization lands the monitor ticks on
        exactly representable times, so the every-timeout re-warn throttle
        fires on the dot instead of skipping knife-edge ties.)"""
        vc = VirtualClock()
        with use_clock(vc):
            ex = make_concurrent(self.Slow, checkpoint_freq=0,
                                 heartbeat_timeout=0.15, clock=vc)
            trial = Trial({}, stopping_criteria={"training_iteration": 1})
            assert ex.start_trial(trial)
            events = []
            while EventType.RESULT not in [e.type for e in events]:
                ev = ex.get_next_event(timeout=5.0)
                assert ev is not None, "virtual run must always make progress"
                events.append(ev)
            ex.shutdown()
        kinds = [e.type for e in events]
        assert kinds == [EventType.HEARTBEAT_MISSED] * 4 + [EventType.RESULT]
        stalled = [e.info["stalled_s"] for e in events[:-1]]
        assert stalled == [pytest.approx(0.15), pytest.approx(0.30),
                           pytest.approx(0.45), pytest.approx(0.60)]
        assert vc.monotonic() == pytest.approx(0.63)  # step length, no slack


class TestSaveMidStepVirtual:
    def test_save_checkpoint_waits_out_inflight_step(self):
        """save_checkpoint against a worker mid-step must let virtual time
        run the step down (a bare lock wait would freeze the virtual epoch
        and deadlock) — the checkpoint lands right after the step completes."""
        vc = VirtualClock()
        with use_clock(vc):
            ex = make_concurrent(TestHeartbeat.Slow, checkpoint_freq=0,
                                 heartbeat_timeout=0, clock=vc)
            trial = Trial({}, stopping_criteria={"training_iteration": 3})
            assert ex.start_trial(trial)
            vc.sleep(0.1)  # worker is inside its 0.63s step, holding ws.lock
            ckpt = ex.save_checkpoint(trial)  # paced through the clock
            assert ckpt.training_iteration == 1
            assert 0.63 <= vc.monotonic() < 0.7  # waited the step out, no more
            ex.shutdown()


class TestAbandonedWorker:
    """A worker whose join times out mid-step is abandoned: its slice leaks
    (never handed to another trial while the thread still dispatches on it)
    and its stale result/checkpoint are discarded, not published."""

    class Stuck(Trainable):
        def setup(self, config):
            self.n = 0

        def step(self):
            get_default_clock().sleep(1.5)
            self.n += 1
            return {"loss": 1.0}

        def save(self):
            return {"n": self.n}

        def restore(self, state):
            self.n = state["n"]

    def test_join_timeout_leaks_slice_and_discards_result(self):
        """Same timeline as the wall version (pause 0.3s into a 1.5s step,
        0.1s join budget), but the sleeps are virtual: the join deadline
        expires at t=0.4 while the worker sleeps until t=1.5, so abandonment
        is guaranteed rather than real-scheduler-dependent."""
        vc = VirtualClock()
        with use_clock(vc):
            ex = make_concurrent(self.Stuck, devices=2, checkpoint_freq=1,
                                 heartbeat_timeout=0, join_timeout=0.1,
                                 clock=vc)
            trial = Trial({}, resources=Resources(devices=2),
                          stopping_criteria={"training_iteration": 3})
            assert ex.start_trial(trial)
            vc.sleep(0.3)          # worker is inside the 1.5s step
            ex.pause_trial(trial)  # both join attempts (halt + reap) time out
            assert vc.monotonic() == pytest.approx(0.5)  # 0.3 + 2 x 0.1
            assert trial.status == TrialStatus.PAUSED
            assert trial.checkpoint is None    # no torn checkpoint was written
            assert not ex.has_running()
            assert not ex.has_resources(trial)  # slice leaked on purpose
            vc.sleep(1.6)          # stale step completes (t=1.5) after halt
            assert ex.bus.empty()  # its result was discarded
            ex.shutdown()


_CRASH_SCRIPT = """
import os, sys
from repro.core import Trainable, run_experiments

class Killer(Trainable):
    def setup(self, config):
        self.n = 0
    def step(self):
        self.n += 1
        if self.n == 7:
            os._exit(7)  # hard crash: no atexit, no buffered-file flush
        return {"loss": 1.0 / self.n}
    def save(self):
        return {"n": self.n}
    def restore(self, state):
        self.n = state["n"]

run_experiments(Killer, {"lr": 0.1}, stop={"training_iteration": 20},
                checkpoint_freq=0, log_dir=sys.argv[1])
"""


class TestCrashDurableLogs:
    def test_logs_complete_after_hard_kill(self, tmp_path):
        """A run killed mid-flight keeps every already-reported result in the
        CSV and JSONL logs (per-result flush; satellite of DESIGN.md §4)."""
        log_dir = str(tmp_path / "run")
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src"),
                   JAX_PLATFORMS="cpu")
        proc = subprocess.run([sys.executable, "-c", _CRASH_SCRIPT, log_dir],
                              env=env, cwd=REPO, timeout=120,
                              capture_output=True, text=True)
        assert proc.returncode == 7, proc.stderr

        (csv_path,) = glob.glob(os.path.join(log_dir, "csv", "*.csv"))
        with open(csv_path, newline="") as f:
            rows = list(csv.DictReader(f))
        assert [int(r["training_iteration"]) for r in rows] == [1, 2, 3, 4, 5, 6]

        with open(os.path.join(log_dir, "events.jsonl")) as f:
            events = [json.loads(line) for line in f]
        results = [e for e in events if e["event"] == "result"]
        assert [e["iteration"] for e in results] == [1, 2, 3, 4, 5, 6]
