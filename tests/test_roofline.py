"""HLO cost-walker validation: trip-weighted flops/bytes/collectives against
analytically known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import RooflineReport, hlo_costs, model_flops


def compile_text(fn, *shapes):
    structs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(fn).lower(*structs).compile().as_text()


class TestDotFlops:
    def test_plain_matmul(self):
        txt = compile_text(lambda a, b: a @ b, (64, 128), (128, 32))
        costs = hlo_costs(txt)
        assert costs["dot_flops"] == 2 * 64 * 128 * 32

    def test_scan_trip_weighting(self):
        N, L = 128, 7

        def f(ws, x):
            def body(x, w):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(body, x, ws)
            return x.sum()

        txt = compile_text(f, (L, N, N), (N, N))
        costs = hlo_costs(txt)
        assert costs["dot_flops"] == pytest.approx(2 * N**3 * L, rel=1e-6)

    def test_grad_is_3x(self):
        N, L = 64, 5

        def f(ws, x):
            def body(x, w):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(body, x, ws)
            return x.sum()

        txt = compile_text(lambda w, x: jax.grad(f)(w, x).sum(), (L, N, N), (N, N))
        costs = hlo_costs(txt)
        assert costs["dot_flops"] == pytest.approx(6 * N**3 * L, rel=1e-6)

    def test_remat_is_4x(self):
        N, L = 64, 5

        def f(ws, x):
            @jax.checkpoint
            def body(x, w):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(body, x, ws)
            return x.sum()

        txt = compile_text(lambda w, x: jax.grad(f)(w, x).sum(), (L, N, N), (N, N))
        costs = hlo_costs(txt)
        assert costs["dot_flops"] == pytest.approx(8 * N**3 * L, rel=1e-6)

    def test_batched_dot(self):
        txt = compile_text(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                           (4, 32, 16), (4, 16, 8))
        costs = hlo_costs(txt)
        assert costs["dot_flops"] == 2 * 4 * 32 * 16 * 8


class TestModelFlops:
    def test_train_6nd(self):
        assert model_flops(1000, 50, "train") == 6 * 1000 * 50

    def test_inference_2nd(self):
        assert model_flops(1000, 50, "decode") == 2 * 1000 * 50


class TestReport:
    def _report(self, **kw):
        base = dict(arch="a", shape="s", mesh="m", chips=256,
                    device_flops=1e12, device_bytes=1e11,
                    collective_bytes=1e9, collectives_by_kind={},
                    ca_flops_raw=0, ca_bytes_raw=0,
                    arg_bytes=2**30, temp_bytes=2**30, output_bytes=0,
                    model_flops_total=2.56e14, n_tokens=1000)
        base.update(kw)
        return RooflineReport(**base)

    def test_terms_and_dominant(self):
        r = self._report()
        assert r.compute_s == pytest.approx(1e12 / 197e12)
        assert r.memory_s == pytest.approx(1e11 / 819e9)
        assert r.collective_s == pytest.approx(1e9 / 50e9)
        assert r.dominant == "memory"
        assert r.useful_flops_ratio == pytest.approx(2.56e14 / (1e12 * 256))
        assert r.hbm_per_device_gib == pytest.approx(2.0)
        assert r.step_time_s == r.memory_s

    def test_dict_roundtrip_keys(self):
        d = self._report().to_dict()
        for k in ("compute_s", "memory_s", "collective_s", "dominant",
                  "useful_flops_ratio", "step_time_s"):
            assert k in d
