"""Loggers (CSV/JSONL/console) and ExperimentAnalysis coverage."""
import csv
import json
import os

import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import (CSVLogger, ConsoleLogger, ExperimentAnalysis,
                        JSONLLogger, Result, Trial, TrialStatus)


def make_trial_with_results(values, metric="loss"):
    t = Trial({"lr": 0.1})
    for i, v in enumerate(values, start=1):
        t.record_result(Result(trial_id=t.trial_id, training_iteration=i,
                               metrics={metric: float(v)}))
    return t


class TestLoggers:
    def test_csv_logger_writes_rows(self, tmp_path):
        lg = CSVLogger(str(tmp_path))
        t = Trial({"lr": 0.1})
        for i in range(3):
            lg.on_result(t, Result(t.trial_id, i + 1, {"loss": 1.0 / (i + 1)}))
        lg.close()
        path = os.path.join(str(tmp_path), f"{t.trial_id}.csv")
        with open(path) as f:
            rows = list(csv.DictReader(f))
        assert len(rows) == 3
        assert float(rows[2]["loss"]) == pytest.approx(1 / 3)

    def test_jsonl_logger_events(self, tmp_path):
        path = str(tmp_path / "events.jsonl")
        lg = JSONLLogger(path)
        t = Trial({"lr": 0.1})
        lg.on_result(t, Result(t.trial_id, 1, {"loss": 0.5}))
        t.set_status(TrialStatus.TERMINATED)
        lg.on_trial_complete(t)
        lg.close()
        events = [json.loads(l) for l in open(path)]
        assert [e["event"] for e in events] == ["run_header", "result", "complete"]
        assert events[0]["schema_version"] == JSONLLogger.SCHEMA_VERSION
        assert events[1]["metrics"]["loss"] == 0.5
        assert events[2]["status"] == "TERMINATED"

    def test_jsonl_skips_non_json_values(self, tmp_path):
        path = str(tmp_path / "e.jsonl")
        lg = JSONLLogger(path)
        t = Trial({"lr": 0.1, "obj": object()})
        lg.on_result(t, Result(t.trial_id, 1, {"loss": 0.5, "arr": np.ones(3)}))
        lg.close()
        ev = [json.loads(l) for l in open(path)
              if json.loads(l)["event"] == "result"][0]
        assert "obj" not in ev["config"] and "arr" not in ev["metrics"]

    def test_console_quiet(self, capsys):
        lg = ConsoleLogger(verbose=False)
        t = Trial({})
        lg.on_result(t, Result(t.trial_id, 1, {"loss": 1.0}))
        lg.on_experiment_end([t])
        assert capsys.readouterr().out == ""


class TestLoggersOnVirtualClock:
    """Clock-seam coverage (DESIGN.md §7): the JSONL fallback-timestamp path
    for bus-less events, and Console flush throttling, both driven
    deterministically on a VirtualClock instead of real 5-second gaps."""

    def test_jsonl_event_timestamps_virtual_and_fallback(self, tmp_path):
        from repro.core import EventType, TrialEvent, VirtualClock

        vc = VirtualClock()
        path = str(tmp_path / "events.jsonl")
        lg = JSONLLogger(path, clock=vc)
        t = Trial({})
        vc.sleep(100.0)
        # Stamped event (came off a bus): its timestamp must be preserved.
        lg.on_event(t, TrialEvent(EventType.HEARTBEAT_MISSED, t.trial_id,
                                  timestamp=vc.time()))
        vc.sleep(50.0)
        # Unstamped event (runner/broker handed it straight to the logger):
        # the logger's own clock supplies the time — the fallback path.
        lg.on_event(t, TrialEvent(EventType.RESTARTED, t.trial_id))
        lg.close()
        header, stamped, fallback = [json.loads(l) for l in open(path)]
        assert header["event"] == "run_header"
        assert header["clock"] == "VirtualClock"
        assert stamped["event"] == "heartbeat_missed"
        assert stamped["t"] == pytest.approx(vc._epoch + 100.0)
        assert fallback["event"] == "restarted"
        assert fallback["t"] == pytest.approx(vc._epoch + 150.0)

    def test_console_flush_throttle_on_virtual_time(self, capsys):
        from repro.core import VirtualClock

        vc = VirtualClock()
        lg = ConsoleLogger(interval_s=5.0, clock=vc)
        t = Trial({})
        vc.sleep(10.0)  # move past _last=0 so the first result prints
        lg.on_result(t, Result(t.trial_id, 1, {"loss": 1.0}))
        for i in range(2, 6):  # 4 results inside the 5s window: throttled
            vc.sleep(1.0)
            lg.on_result(t, Result(t.trial_id, i, {"loss": 1.0 / i}))
        vc.sleep(1.1)  # crosses the 5s boundary: prints again
        lg.on_result(t, Result(t.trial_id, 6, {"loss": 1.0 / 6}))
        out = [l for l in capsys.readouterr().out.splitlines() if l]
        assert len(out) == 2
        assert "iter=1" in out[0] and "iter=6" in out[1]
        assert lg._n_results == 6  # every result counted, two printed


class TestAnalysis:
    def test_best_trial_min_mode(self):
        a = make_trial_with_results([3, 2, 1])
        b = make_trial_with_results([5, 4, 3.5])
        an = ExperimentAnalysis([a, b], metric="loss", mode="min")
        assert an.best_trial() is a
        assert an.best_value() == 1.0

    def test_best_trial_max_mode(self):
        a = make_trial_with_results([0.1, 0.2], metric="accuracy")
        b = make_trial_with_results([0.3, 0.25], metric="accuracy")
        an = ExperimentAnalysis([a, b], metric="accuracy", mode="max")
        assert an.best_trial() is b
        assert an.best_value() == 0.3

    def test_empty_trials(self):
        an = ExperimentAnalysis([], metric="loss", mode="min")
        assert an.best_trial() is None and an.best_config() is None

    def test_trial_without_metric_ignored(self):
        a = make_trial_with_results([1.0])
        b = Trial({})  # no results
        an = ExperimentAnalysis([a, b], metric="loss", mode="min")
        assert an.best_trial() is a

    @given(st.lists(st.lists(st.floats(0.015625, 128.0, width=32), min_size=1,
                             max_size=5), min_size=1, max_size=6))
    @settings(max_examples=25, deadline=None)
    def test_best_value_is_global_min(self, curves):
        trials = [make_trial_with_results(c) for c in curves]
        an = ExperimentAnalysis(trials, metric="loss", mode="min")
        flat = [v for c in curves for v in c]
        assert an.best_value() == pytest.approx(min(flat))


class TestTrialInvariants:
    def test_finished_cannot_restart(self):
        t = Trial({})
        t.set_status(TrialStatus.TERMINATED)
        with pytest.raises(RuntimeError):
            t.set_status(TrialStatus.RUNNING)

    def test_should_stop_on_metric_threshold(self):
        t = Trial({}, stopping_criteria={"accuracy": 0.9})
        r = Result(t.trial_id, 1, {"accuracy": 0.95})
        assert t.should_stop(r)

    def test_best_value_modes(self):
        t = make_trial_with_results([3, 1, 2])
        assert t.best_value("loss", "min") == 1
        assert t.best_value("loss", "max") == 3
        assert t.best_value("nope", "min") is None
