"""Acceptance criterion: a simulated multi-host fleet under VirtualClock
survives scripted host crashes and network partitions with every
repro.testing invariant intact (DESIGN.md §11).

These runs cover hundreds of virtual seconds in well under a wall second
each — the host-fault matrix is only tractable because the fleet, the
workers and the eviction age math all ride the injected clock.
"""
import pytest

from repro.core import FIFOScheduler
from repro.testing import Scenario, run_scenario
from repro.testing.invariants import (check_decision_provenance,
                                      check_event_log, check_no_slice_leaks)


def _fifo():
    return FIFOScheduler(metric="loss", mode="min")


def run(sc, **kw):
    return run_scenario(sc, _fifo, executor="cluster",
                        max_steps=500_000, **kw)


@pytest.mark.timeout(120)
class TestHostFaultMatrix:
    def test_crash_and_partition_fleet_survives(self):
        """4 hosts, 12 trials; h1 dies abruptly at t=8s and h2 falls off the
        network at t=12s for 200s (longer than host_timeout, so it is
        evicted too).  Every trial must still terminate, every invariant
        must hold, and both evictions must be attributed correctly."""
        sc = Scenario(
            name="crash+partition", stop_iteration=40, max_failures=2,
            heartbeat_timeout=60.0, hosts="4x4", host_timeout=90.0,
            host_faults=[("crash", "h1", 8.0),
                         ("partition", "h2", 12.0, 200.0)],
            configs=[{"lr": 0.01 + i * 0.001, "step_s": 1.0,
                      "jitter_s": 0.25} for i in range(12)])
        res = run(sc)
        check_no_slice_leaks(res)
        check_event_log(res)
        check_decision_provenance(res)
        assert res.by_status() == {"TERMINATED": 12}, res.by_status()
        ex = res.executor
        assert ex.n_host_evictions == 2
        assert not ex.hosts["h1"].alive
        assert "crash" in ex.hosts["h1"].evicted_reason
        assert not ex.hosts["h2"].alive
        assert "no heartbeat" in ex.hosts["h2"].evicted_reason
        assert ex.hosts["h0"].alive and ex.hosts["h3"].alive
        # Each evicted host's resident trials were requeued, so restarts at
        # least match the trials the two dead hosts were carrying — and the
        # partition really dropped traffic on the floor.
        assert res.runner.n_restarts > 0
        # crash + partition fired; the heal (t=212s) may land after the run
        # already finished, in which case the fault loop is stopped first.
        assert res.fleet.n_faults_fired >= 2
        assert res.fleet.network.n_dropped > 0
        # Virtual run: hundreds of simulated seconds, sub-second wall time.
        assert res.virtual_elapsed_s > 40.0
        assert res.wall_elapsed_s < 30.0

    def test_partition_heals_before_timeout_no_eviction(self):
        """A blip shorter than host_timeout must NOT evict: heartbeats resume
        after the heal and the age math forgives."""
        sc = Scenario(
            name="short-blip", stop_iteration=30, max_failures=1,
            heartbeat_timeout=60.0, hosts="2x4", host_timeout=120.0,
            host_faults=[("partition", "h1", 5.0, 20.0)],
            configs=[{"lr": 0.01, "step_s": 1.0} for _ in range(4)])
        res = run(sc)
        check_no_slice_leaks(res)
        check_event_log(res)
        assert res.by_status() == {"TERMINATED": 4}
        assert res.executor.n_host_evictions == 0
        assert res.runner.n_restarts == 0
        assert res.fleet.network.n_dropped > 0  # the blip was real

    def test_losing_every_host_but_one_still_finishes(self):
        """Serial degradation: 3 of 4 hosts crash in sequence; the survivor
        absorbs the whole queue."""
        sc = Scenario(
            name="cascade", stop_iteration=20, max_failures=4,
            heartbeat_timeout=60.0, hosts="4x2", host_timeout=60.0,
            host_faults=[("crash", "h0", 6.0), ("crash", "h1", 14.0),
                         ("crash", "h2", 22.0)],
            configs=[{"lr": 0.01 + i * 0.001, "step_s": 1.0}
                     for i in range(6)])
        res = run(sc)
        check_no_slice_leaks(res)
        check_event_log(res)
        assert res.by_status() == {"TERMINATED": 6}
        ex = res.executor
        assert ex.n_host_evictions == 3
        alive = sorted(n for n, ha in ex.hosts.items() if ha.alive)
        assert alive == ["h3"]

    def test_host_crash_exhausts_trial_budget(self):
        """max_failures=0 turns a host crash into trial ERRORs: the eviction
        is charged to every resident trial's budget."""
        sc = Scenario(
            name="no-budget", stop_iteration=60, max_failures=0,
            heartbeat_timeout=60.0, hosts="2x4", host_timeout=60.0,
            host_faults=[("crash", "h0", 10.0)],
            configs=[{"lr": 0.01 + i * 0.001, "step_s": 1.0}
                     for i in range(8)])
        res = run(sc)
        check_no_slice_leaks(res)
        check_event_log(res)
        by = res.by_status()
        assert by.get("ERROR", 0) >= 1, by  # h0 was carrying trials at t=10
        assert by.get("ERROR", 0) + by.get("TERMINATED", 0) == 8
        for t in res.trials:
            if t.status.value == "ERROR":
                # A scripted crash is indistinguishable from the processes
                # vanishing, so the base worker-death message is the record.
                assert "died unexpectedly" in t.error


@pytest.mark.timeout(120)
class TestDeterminism:
    def test_same_script_same_streams(self):
        """The virtual fleet is deterministic: identical scenario + scheduler
        (and a pinned run token, so trial ids line up) give identical
        per-trial result streams and statuses across runs."""
        def once():
            sc = Scenario(
                name="det", stop_iteration=25, max_failures=2,
                heartbeat_timeout=60.0, hosts="3x2", host_timeout=80.0,
                host_faults=[("crash", "h1", 7.0)],
                configs=[{"lr": 0.01 + i * 0.002, "step_s": 1.0,
                          "jitter_s": 0.5} for i in range(6)])
            res = run(sc, token="pinned")
            return {t.trial_id: (t.status.value,
                                 [r.training_iteration for r in t.results],
                                 [r.metrics["loss"] for r in t.results])
                    for t in res.trials}

        assert once() == once()


@pytest.mark.timeout(120)
class TestFlightRecorderHosts:
    def test_bundle_carries_per_host_state(self):
        sc = Scenario(
            name="forensics", stop_iteration=10, max_failures=2,
            heartbeat_timeout=60.0, hosts="2x2", host_timeout=60.0,
            host_faults=[("crash", "h1", 4.0)],
            configs=[{"lr": 0.01, "step_s": 1.0} for _ in range(3)])
        res = run(sc)
        assert res.flightrec is not None
        bundle = res.flightrec.bundle(executor=res.executor)
        hosts = bundle.get("hosts")
        assert hosts is not None and sorted(hosts) == ["h0", "h1"]
        assert hosts["h1"]["alive"] is False
        assert "crash" in (hosts["h1"]["evicted_reason"] or "")
        assert hosts["h0"]["alive"] is True
