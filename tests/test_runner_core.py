"""Runner state machine, executors, resources, slice pool, object store,
checkpoint serialization — the distributed-substrate invariants."""
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro.core import (CheckpointManager, FIFOScheduler, ObjectStore,
                        ResourceAccountant, Resources, SerialMeshExecutor,
                        Trainable, Trial, TrialRunner, TrialStatus,
                        load_pytree, save_pytree, tree_from_bytes,
                        tree_to_bytes, wrap_function)
from repro.dist.submesh import SlicePool


class Counter(Trainable):
    def setup(self, config):
        self.n = 0
        self.fail_at = config.get("fail_at")

    def step(self):
        self.n += 1
        if self.fail_at and self.n >= self.fail_at:
            raise RuntimeError("boom")
        return {"loss": 1.0 / self.n}

    def save(self):
        return {"n": self.n}

    def restore(self, state):
        self.n = state["n"]


def make_runner(scheduler=None, devices=4, checkpoint_freq=1, stop=10):
    ex = SerialMeshExecutor(lambda name: Counter,
                            CheckpointManager(ObjectStore()),
                            total_devices=devices,
                            checkpoint_freq=checkpoint_freq)
    return TrialRunner(scheduler or FIFOScheduler(metric="loss", mode="min"),
                       ex, stopping_criteria={"training_iteration": stop})


class TestRunner:
    def test_parallel_limited_by_resources(self):
        runner = make_runner(devices=2)
        for i in range(5):
            runner.add_trial(Trial({}, resources=Resources(devices=1),
                                   stopping_criteria={"training_iteration": 3}))
        runner.step()
        running = sum(1 for t in runner.trials if t.status == TrialStatus.RUNNING)
        assert running == 2  # only 2 devices
        trials = runner.run()
        assert all(t.status == TrialStatus.TERMINATED for t in trials)
        assert all(t.training_iteration == 3 for t in trials)

    def test_trial_error_recorded_not_fatal(self):
        runner = make_runner()
        runner.add_trial(Trial({"fail_at": 2}, stopping_criteria={"training_iteration": 5}))
        runner.add_trial(Trial({}, stopping_criteria={"training_iteration": 5}))
        trials = runner.run()
        statuses = sorted(t.status for t in trials)
        assert statuses == [TrialStatus.ERROR, TrialStatus.TERMINATED]
        assert runner.n_errors == 1

    def test_results_recorded_in_order(self):
        runner = make_runner()
        runner.add_trial(Trial({}, stopping_criteria={"training_iteration": 4}))
        (trial,) = runner.run()
        iters = [r.training_iteration for r in trial.results]
        assert iters == [1, 2, 3, 4]

    def test_metric_stop_criterion(self):
        runner = make_runner(stop=100)
        t = Trial({}, stopping_criteria={"training_iteration": 100, "loss_inv": 0})
        runner.add_trial(t)
        # loss decreases; use the iteration bound only
        runner.run(max_steps=500)
        assert t.training_iteration == 100


class TestFunctionAPI:
    def test_function_trainable_reports(self):
        def train(tune):
            x = 0
            for _ in range(5):
                x += tune.params["inc"]
                if tune.should_checkpoint():
                    tune.record_checkpoint({"x": x})
                tune.report(value=x)

        cls = wrap_function(train)
        tr = cls({"inc": 2})
        out = [tr.train()["value"] for _ in range(5)]
        assert out == [2, 4, 6, 8, 10]
        assert tr.train().get("done")
        tr.cleanup()

    def test_function_checkpoint_on_request(self):
        def train(tune):
            for i in range(10):
                if tune.should_checkpoint():
                    tune.record_checkpoint({"i": i})
                tune.report(i=i)

        tr = wrap_function(train)({})
        tr.train()
        state = tr.save()
        assert "i" in state
        tr.cleanup()

    def test_function_stop_mid_run(self):
        stopped = []

        def train(tune):
            try:
                for i in range(1000):
                    tune.report(i=i)
            finally:
                stopped.append(True)

        tr = wrap_function(train)({})
        tr.train()
        tr.cleanup()
        assert stopped


class TestCheckpointSerialization:
    def test_roundtrip_pytree(self, tmp_path):
        tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
                "b": [jnp.ones((2, 2), jnp.bfloat16), 3, "tag"],
                "c": {"d": np.int64(7), "e": None}}
        data = tree_to_bytes(tree)
        back = tree_from_bytes(data)
        np.testing.assert_array_equal(back["a"], tree["a"])
        np.testing.assert_array_equal(np.asarray(back["b"][0], np.float32),
                                      np.ones((2, 2), np.float32))
        assert back["b"][1] == 3 and back["b"][2] == "tag"
        assert back["c"]["d"] == 7 and back["c"]["e"] is None

    def test_crc_detects_corruption(self):
        data = bytearray(tree_to_bytes({"a": np.ones(4)}))
        data[10] ^= 0xFF
        with pytest.raises(IOError):
            tree_from_bytes(bytes(data))

    def test_disk_roundtrip_atomic(self, tmp_path):
        path = str(tmp_path / "ckpt" / "x.ckpt")
        save_pytree({"v": np.arange(5)}, path)
        assert np.array_equal(load_pytree(path)["v"], np.arange(5))

    @given(st.lists(st.floats(-1e6, 1e6, width=32), min_size=1, max_size=20))
    @settings(max_examples=30, deadline=None)
    def test_roundtrip_property(self, values):
        arr = np.asarray(values, np.float32)
        back = tree_from_bytes(tree_to_bytes({"x": arr}))
        np.testing.assert_array_equal(back["x"], arr)


class TestObjectStore:
    def test_put_get_delete(self):
        store = ObjectStore()
        k = store.put({"w": np.ones((4, 4))})
        assert store.contains(k)
        np.testing.assert_array_equal(store.get(k)["w"], np.ones((4, 4)))
        store.delete(k)
        assert not store.contains(k)
        with pytest.raises(KeyError):
            store.get(k)

    def test_lru_spill_to_disk(self, tmp_path):
        store = ObjectStore(capacity_bytes=1000, spill_dir=str(tmp_path))
        keys = [store.put(np.ones(100, np.float32), key=f"k{i}") for i in range(5)]
        assert store.n_spilled > 0
        for k in keys:  # all still retrievable (memory or spilled)
            assert store.get(k) is not None


class TestResources:
    def test_accounting_never_negative(self):
        acct = ResourceAccountant(4.0, 8)
        r = Resources(cpu=2, devices=4)
        acct.acquire(r)
        assert not acct.has_room(Resources(cpu=4, devices=1))
        acct.release(r)
        with pytest.raises(RuntimeError):
            acct.release(r)

    def test_negative_request_rejected(self):
        with pytest.raises(ValueError):
            Resources(cpu=-1)

    def test_overcommit_raises(self):
        acct = ResourceAccountant(1.0, 1)
        with pytest.raises(RuntimeError):
            acct.acquire(Resources(cpu=2))


class TestSlicePool:
    def test_first_fit_and_coalesce(self):
        pool = SlicePool(n_virtual=16)
        a = pool.acquire(6)
        b = pool.acquire(6)
        assert not pool.can_fit(6)
        pool.release(a)
        pool.release(b)
        c = pool.acquire(16)  # coalesced back to one range
        assert c.size == 16

    def test_mesh_from_slice(self):
        import jax
        pool = SlicePool(devices=jax.devices() * 4)  # fake 4 slots on CPU
        sl = pool.acquire(2)
        mesh = sl.make_mesh(("data",))
        assert mesh.shape["data"] == 2

    @given(st.lists(st.integers(1, 5), min_size=1, max_size=20))
    @settings(max_examples=30, deadline=None)
    def test_acquire_release_invariant(self, sizes):
        """Free count is conserved under any acquire/release sequence."""
        pool = SlicePool(n_virtual=32)
        held = []
        for s in sizes:
            if pool.can_fit(s):
                held.append(pool.acquire(s))
        used = sum(h.size for h in held)
        assert pool.n_free == 32 - used
        for h in held:
            pool.release(h)
        assert pool.n_free == 32
        assert pool.can_fit(32)
