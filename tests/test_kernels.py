"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, assert_allclose.

Kernels execute in interpret mode on CPU (the kernel body is what's tested;
tiling is TPU-side).  Hypothesis drives shape fuzzing on top of the explicit
parametrized sweeps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.key(0)


def randn(i, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, i), shape) * scale).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("B,Sq,Sk,H,K,hd", [
        (1, 64, 64, 1, 1, 32),       # minimal MHA
        (2, 128, 128, 4, 2, 64),     # GQA
        (2, 96, 160, 4, 1, 64),      # MQA, padded odd sizes
        (1, 256, 256, 8, 8, 32),     # full heads
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shape_dtype_sweep(self, B, Sq, Sk, H, K, hd, dtype):
        q = randn(1, (B, Sq, H, hd), dtype)
        k = randn(2, (B, Sk, K, hd), dtype)
        v = randn(3, (B, Sk, K, hd), dtype)
        qp = jnp.broadcast_to(jnp.arange(Sk - Sq, Sk)[None], (B, Sq))
        kp = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk))
        out = ops.flash_attention(q, k, v, qp, kp, causal=True,
                                  block_q=64, block_k=64)
        exp = ref.flash_attention_ref(q, k, v, qp, kp, causal=True)
        atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32), atol=atol)

    @pytest.mark.parametrize("causal,window,softcap", [
        (True, None, None), (False, None, None),
        (True, 32, None), (True, None, 20.0), (True, 16, 20.0),
    ])
    def test_mask_variants(self, causal, window, softcap):
        B, S, H, K, hd = 2, 128, 2, 2, 32
        q, k, v = (randn(i, (B, S, H if i == 1 else K, hd)) for i in (1, 2, 3))
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        out = ops.flash_attention(q, k, v, pos, pos, causal=causal,
                                  window=window, softcap=softcap,
                                  block_q=64, block_k=64)
        exp = ref.flash_attention_ref(q, k, v, pos, pos, causal=causal,
                                      window=window, softcap=softcap)
        np.testing.assert_allclose(out, exp, atol=2e-5)

    def test_ring_cache_invalid_slots_masked(self):
        """k_pos == -1 slots (unfilled ring entries) contribute nothing."""
        B, Sq, Sk, H, hd = 1, 64, 128, 2, 32
        q = randn(1, (B, Sq, H, hd))
        k = randn(2, (B, Sk, H, hd))
        v = randn(3, (B, Sk, H, hd))
        qp = jnp.broadcast_to(jnp.arange(100, 100 + Sq)[None], (B, Sq))
        kp_full = jnp.broadcast_to(jnp.arange(36, 36 + Sk)[None], (B, Sk))
        kp_holes = kp_full.at[:, 64:].set(-1)
        out = ops.flash_attention(q, k, v, qp, kp_holes, causal=True,
                                  block_q=64, block_k=64)
        exp = ref.flash_attention_ref(q, k[:, :64], v[:, :64], qp,
                                      kp_full[:, :64], causal=True)
        np.testing.assert_allclose(out, exp, atol=2e-5)

    def test_decode_single_query(self):
        B, Sk, H, K, hd = 4, 128, 4, 2, 64
        q = randn(1, (B, 1, H, hd))
        k = randn(2, (B, Sk, K, hd))
        v = randn(3, (B, Sk, K, hd))
        qp = jnp.full((B, 1), Sk - 1)
        kp = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk))
        out = ops.flash_attention(q, k, v, qp, kp, causal=True)
        exp = ref.flash_attention_ref(q, k, v, qp, kp, causal=True)
        np.testing.assert_allclose(out, exp, atol=2e-5)

    @given(st.integers(1, 3), st.integers(1, 4), st.integers(8, 80))
    @settings(max_examples=10, deadline=None)
    def test_fuzz_shapes(self, B, K, Sq):
        H, hd, Sk = K * 2, 16, 96
        q = randn(1, (B, Sq, H, hd))
        k = randn(2, (B, Sk, K, hd))
        v = randn(3, (B, Sk, K, hd))
        qp = jnp.broadcast_to(jnp.arange(Sk - Sq, Sk)[None], (B, Sq))
        kp = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk))
        out = ops.flash_attention(q, k, v, qp, kp, block_q=32, block_k=32)
        exp = ref.flash_attention_ref(q, k, v, qp, kp)
        np.testing.assert_allclose(out, exp, atol=3e-5)


class TestRWKV6Scan:
    def _inputs(self, B, S, H, N, dtype=jnp.float32):
        r = randn(1, (B, S, H, N), dtype, 0.5)
        k = randn(2, (B, S, H, N), dtype, 0.5)
        v = randn(3, (B, S, H, N), dtype, 0.5)
        logw = -jnp.exp(randn(4, (B, S, H, N), jnp.float32, 0.5) - 2.0)
        u = randn(5, (H, N), jnp.float32, 0.3)
        s0 = randn(6, (B, H, N, N), jnp.float32, 0.2)
        return r, k, v, logw, u, s0

    @pytest.mark.parametrize("B,S,H,N,chunk", [
        (1, 32, 1, 8, 8), (2, 50, 3, 16, 16), (2, 64, 2, 32, 32),
        (1, 100, 2, 16, 64),
    ])
    def test_shape_sweep(self, B, S, H, N, chunk):
        r, k, v, logw, u, s0 = self._inputs(B, S, H, N)
        y, sf = ops.rwkv6_scan(r, k, v, logw, u, s0, chunk=chunk)
        y_ref, sf_ref = ref.rwkv6_scan_ref(r, k, v, logw, u, s0)
        np.testing.assert_allclose(y, y_ref, atol=1e-4)
        np.testing.assert_allclose(sf, sf_ref, atol=1e-4)

    def test_bfloat16_inputs(self):
        r, k, v, logw, u, s0 = self._inputs(2, 32, 2, 16, jnp.bfloat16)
        y, sf = ops.rwkv6_scan(r, k, v, logw, u, s0, chunk=16)
        y_ref, sf_ref = ref.rwkv6_scan_ref(r, k, v, logw, u, s0)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32), atol=5e-2)

    def test_state_chaining(self):
        """Running two halves with carried state == one full run."""
        r, k, v, logw, u, s0 = self._inputs(1, 64, 2, 8)
        y_full, s_full = ops.rwkv6_scan(r, k, v, logw, u, s0, chunk=16)
        y1, s_mid = ops.rwkv6_scan(r[:, :32], k[:, :32], v[:, :32],
                                   logw[:, :32], u, s0, chunk=16)
        y2, s_end = ops.rwkv6_scan(r[:, 32:], k[:, 32:], v[:, 32:],
                                   logw[:, 32:], u, s_mid, chunk=16)
        np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, atol=1e-4)
        np.testing.assert_allclose(s_end, s_full, atol=1e-4)


class TestRGLRUScan:
    @pytest.mark.parametrize("B,S,R,ct,br", [
        (1, 32, 16, 16, 16), (3, 77, 40, 32, 16), (2, 128, 64, 64, 64),
    ])
    def test_shape_sweep(self, B, S, R, ct, br):
        a = jax.nn.sigmoid(randn(7, (B, S, R)))
        b = randn(8, (B, S, R), scale=0.3)
        h0 = randn(9, (B, R), scale=0.2)
        h = ops.rglru_scan(a, b, h0, chunk_t=ct, block_r=br)
        np.testing.assert_allclose(h, ref.rglru_scan_ref(a, b, h0), atol=1e-5)

    def test_no_initial_state(self):
        a = jax.nn.sigmoid(randn(7, (2, 40, 8)))
        b = randn(8, (2, 40, 8), scale=0.3)
        h = ops.rglru_scan(a, b, None, chunk_t=16, block_r=8)
        np.testing.assert_allclose(h, ref.rglru_scan_ref(a, b, None), atol=1e-5)

    @given(st.integers(1, 3), st.integers(5, 60), st.integers(4, 24))
    @settings(max_examples=10, deadline=None)
    def test_fuzz(self, B, S, R):
        a = jax.nn.sigmoid(randn(7, (B, S, R)))
        b = randn(8, (B, S, R), scale=0.5)
        h = ops.rglru_scan(a, b, None, chunk_t=16, block_r=8)
        np.testing.assert_allclose(h, ref.rglru_scan_ref(a, b, None), atol=1e-5)


class TestMoERouter:
    @pytest.mark.parametrize("T,E,k", [(64, 8, 2), (100, 64, 6), (256, 40, 8)])
    def test_shape_sweep(self, T, E, k):
        logits = randn(10, (T, E), scale=2.0)
        w, idx = ops.moe_router(logits, k, block_t=64)
        w_ref, idx_ref = ref.moe_router_ref(logits, k)
        np.testing.assert_allclose(w, w_ref, atol=1e-5)
        assert (idx == idx_ref).all()

    def test_weights_normalized_and_sorted(self):
        logits = randn(11, (32, 16), scale=3.0)
        w, idx = ops.moe_router(logits, 4)
        np.testing.assert_allclose(w.sum(-1), np.ones(32), atol=1e-5)
        assert (np.diff(np.asarray(w), axis=-1) <= 1e-7).all()  # descending

    def test_indices_unique_per_token(self):
        logits = randn(12, (64, 24), scale=2.0)
        _, idx = ops.moe_router(logits, 6)
        for row in np.asarray(idx):
            assert len(set(row.tolist())) == 6
