"""Direct coverage for the dist subsystem beyond the seed suite: SlicePool
fragmentation/coalescing behaviour and decode cache specs (exercised only
through the dryrun path otherwise)."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import cache_specs, sharding_strategy
from repro.dist.submesh import MeshSlice, SlicePool, balanced_shape
from repro.models import ModelConfig
from repro.models import transformer as T


class MockMesh:
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 2}


class TestSlicePoolFragmentation:
    def test_hole_reuse_exact_fit(self):
        pool = SlicePool(n_virtual=16)
        a = pool.acquire(4)
        b = pool.acquire(4)
        c = pool.acquire(8)
        pool.release(b)  # hole [4, 8) — a single free range, still healthy
        assert pool.fragments() == 0 and pool.n_free == 4
        d = pool.acquire(4)
        assert d.start == b.start  # first-fit lands in the hole
        for s in (a, c, d):
            pool.release(s)
        assert pool.fragments() == 0 and pool.can_fit(16)

    def test_fragmented_pool_rejects_contiguous_request(self):
        """6 free devices split 2+4 cannot host a 6-wide slice."""
        pool = SlicePool(n_virtual=8)
        a = pool.acquire(2)
        b = pool.acquire(2)
        c = pool.acquire(4)
        pool.release(a)
        pool.release(c)
        assert pool.n_free == 6
        assert not pool.can_fit(6)
        assert pool.can_fit(4)
        assert pool.fragments() == 1  # one hole: free space split 2 + 4
        assert pool.largest_free_block() == 4
        with pytest.raises(RuntimeError):
            pool.acquire(6)
        pool.release(b)  # middle slice returns -> full coalesce
        assert pool.fragments() == 0
        assert pool.acquire(8).size == 8

    def test_double_release_rejected(self):
        pool = SlicePool(n_virtual=4)
        s = pool.acquire(2)
        pool.release(s)
        with pytest.raises(ValueError):
            pool.release(s)

    @pytest.mark.parametrize("seed", range(5))
    def test_random_walk_conserves_capacity(self, seed):
        """Property-style: any acquire/release interleaving conserves devices
        and always coalesces back to one range when drained."""
        rng = np.random.default_rng(seed)
        pool = SlicePool(n_virtual=64)
        held = []
        for _ in range(200):
            if held and rng.random() < 0.45:
                held.remove(sl := held[rng.integers(len(held))])
                pool.release(sl)
            else:
                size = int(rng.integers(1, 9))
                if pool.can_fit(size):
                    held.append(pool.acquire(size))
            assert pool.n_free == 64 - sum(h.size for h in held)
            # free ranges never overlap a held slice
            for h in held:
                for start, size in pool._free:
                    assert h.start + h.size <= start or start + size <= h.start
        for h in held:
            pool.release(h)
        assert pool.n_free == 64 and pool.fragments() == 0

    def test_balanced_mesh_shape(self):
        assert balanced_shape(8, 1) == (8,)
        assert balanced_shape(8, 2) == (4, 2)
        assert balanced_shape(16, 2) == (4, 4)
        assert balanced_shape(12, 2) == (4, 3)
        assert balanced_shape(1, 3) == (1, 1, 1)

    def test_virtual_slice_builds_mesh(self):
        pool = SlicePool(n_virtual=8)
        sl = pool.acquire(4)
        mesh = sl.make_mesh(("data", "model"))
        assert mesh.shape["data"] == 2 and mesh.shape["model"] == 2
        with pytest.raises(ValueError):
            sl.make_mesh(("data",), shape=(3,))  # doesn't cover the slice


TINY = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=64,
                   n_heads=2, n_kv_heads=2, d_ff=128, vocab_size=64).validate()


class TestCacheSpecs:
    def test_kv_cache_batch_dim_sharded(self):
        caches = T.init_caches(TINY, batch=8, max_len=32)
        specs = cache_specs(caches, MockMesh(), global_batch=8)
        # k/v leaves: (n_layers, B, cap, K, hd) -> batch dim over ("data",)
        assert specs[0][0]["k"] == P(None, ("data",), None, None, None)
        assert specs[0][0]["v"] == P(None, ("data",), None, None, None)
        # kpos (n_layers, cap) has no batch dim -> fully replicated
        assert specs[0][0]["kpos"] == P(None, None)

    def test_indivisible_batch_replicates(self):
        caches = T.init_caches(TINY, batch=2, max_len=16)
        specs = cache_specs(caches, MockMesh(), global_batch=2)
        assert specs[0][0]["k"] == P(None, None, None, None, None)

    def test_dp_only_uses_model_axis_too(self):
        caches = T.init_caches(TINY, batch=8, max_len=16)
        with sharding_strategy("dp_only"):
            specs = cache_specs(caches, MockMesh(), global_batch=8)
        assert specs[0][0]["k"] == P(None, ("data", "model"), None, None, None)

    def test_layer_count_collision_with_batch(self):
        """n_layers == global_batch must NOT shard the stacked layer axis:
        the batch dim of a cache leaf is positional (dim 1), not value-matched."""
        import dataclasses
        cfg = dataclasses.replace(TINY, n_layers=4).validate()
        caches = T.init_caches(cfg, batch=4, max_len=16)
        specs = cache_specs(caches, MockMesh(), global_batch=4)
        # k: (n_layers=4, B=4, cap, K, hd) -> dim 1 sharded, dim 0 replicated
        assert specs[0][0]["k"] == P(None, ("data",), None, None, None)
        # kpos (n_layers=4, cap=16): dim 1 != batch anyway, but the name
        # guard must hold even if cap collided with the batch size
        kpos_collide = cache_specs(
            {"kpos": np.zeros((4, 4), np.int32)}, MockMesh(), global_batch=4)
        assert kpos_collide["kpos"] == P(None, None)
