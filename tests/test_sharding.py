"""Sharding rules: head-aware attention specs, divisibility fallbacks, batch
and cache specs.  Runs on a 1x1 CPU mesh (specs are mesh-shape-aware, so the
interesting logic is exercised with virtual sizes via a (1,1) mesh plus direct
rule checks against a fake mesh shape)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import batch_specs, param_specs, spec_for
from repro.launch.mesh import make_mesh
from repro.models import ModelConfig, init_params


def fake_key(name):
    class K:
        def __init__(self, key):
            self.key = key
    return K(name)


@pytest.fixture(scope="module")
def mesh11():
    return make_mesh((1, 1), ("data", "model"))


class TestSpecRules:
    def test_mlp_weight_sharded_when_divisible(self, mesh11):
        # on a 1x1 mesh every axis size is 1 -> everything divides
        spec = spec_for([fake_key("stack"), fake_key("blocks"),
                         fake_key("mlp"), fake_key("w_gate")],
                        (4, 64, 128), mesh11)
        assert spec == P(None, ("data",), "model")

    def test_norm_replicated(self, mesh11):
        spec = spec_for([fake_key("norm1"), fake_key("scale")], (64,), mesh11)
        assert spec == P(None)

    def test_expert_weights_expert_parallel(self, mesh11):
        spec = spec_for([fake_key("stack"), fake_key("moe"), fake_key("experts"),
                         fake_key("w_gate")], (2, 8, 64, 32), mesh11)
        assert spec == P(None, "model", ("data",), None)

    def test_head_aware_attention_replicates_unsplittable_kv(self):
        """On a model=16 axis, kv=3 heads must NOT shard; q=9 must not either."""
        cfg = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=576,
                          n_heads=9, n_kv_heads=3, d_ff=1536, vocab_size=1024)
        # fake a 16-wide model axis via a mesh over 1 device is impossible;
        # check the rule function's decision directly with a mock mesh
        class MockMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        from repro.dist.sharding import _head_aware_rules
        rules = _head_aware_rules("wk", ["stack", "attn", "wk"], cfg, MockMesh())
        assert rules == [("fsdp", None)]
        rules_q = _head_aware_rules("wq", ["stack", "attn", "wq"], cfg, MockMesh())
        assert rules_q == [("fsdp", None)]

    def test_head_aware_allows_divisible_heads(self):
        cfg = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=8192,
                          n_heads=64, n_kv_heads=8, d_ff=1024, vocab_size=1024)
        class MockMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        from repro.dist.sharding import _head_aware_rules
        assert _head_aware_rules("wq", [], cfg, MockMesh()) == [("fsdp", "tp")]
        # kv=8 doesn't divide 16 -> replicate kv projections (standard MQA)
        assert _head_aware_rules("wk", [], cfg, MockMesh()) == [("fsdp", None)]

    def test_divisibility_drop_fallback(self):
        """504-way vocab can't shard over 16: the spec drops that axis."""
        class MockMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        spec = spec_for([fake_key("embed"), fake_key("tok")], (504, 1280), MockMesh())
        # first template (tp, fsdp) fails on 504; falls through to one that fits
        assert spec[0] is None or spec[0] == ("data",)

    def test_full_param_tree_specs(self, mesh11):
        cfg = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=64,
                          n_heads=2, n_kv_heads=2, d_ff=128,
                          vocab_size=128).validate()
        params = init_params(jax.random.key(0), cfg)
        specs = param_specs(params, mesh11, cfg)
        flat = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert all(isinstance(s, P) for s in flat)


class TestBatchSpecs:
    def test_batch_sharded_when_divisible(self, mesh11):
        batch = {"tokens": jax.ShapeDtypeStruct((16, 32), np.int32)}
        specs = batch_specs(batch, mesh11)
        # data axis size 1 -> no sharding benefit, replicate
        assert specs["tokens"] == P(None, None)

    def test_odd_batch_replicated(self):
        class MockMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        batch = {"tokens": jax.ShapeDtypeStruct((1, 32), np.int32)}
        specs = batch_specs(batch, MockMesh())
        assert specs["tokens"] == P(None, None)

    def test_divisible_batch_sharded(self):
        class MockMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        batch = {"tokens": jax.ShapeDtypeStruct((256, 32), np.int32)}
        specs = batch_specs(batch, MockMesh())
        assert specs["tokens"] == P(("data",), None)


class TestShardedExecution:
    """End-to-end jit with shardings on a tiny (1,1) mesh — validates the
    full spec pipeline produces runnable programs."""

    def test_train_step_runs_with_shardings(self, mesh11):
        import jax.numpy as jnp
        from functools import partial
        from repro.dist.sharding import make_shardings, train_state_specs
        from repro.train import adamw, make_train_state, make_train_step

        cfg = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=64,
                          n_heads=2, n_kv_heads=2, d_ff=128,
                          vocab_size=64).validate()
        opt = adamw(1e-3)
        state = make_train_state(jax.random.key(0), cfg, opt)
        sh = make_shardings(train_state_specs(state, mesh11, cfg), mesh11)
        batch = {"tokens": jnp.ones((4, 16), jnp.int32),
                 "labels": jnp.ones((4, 16), jnp.int32)}
        bsh = make_shardings(batch_specs(batch, mesh11), mesh11)
        step = jax.jit(make_train_step(cfg, opt),
                       in_shardings=(sh, bsh), out_shardings=(sh, None))
        state2, m = step(state, batch)
        assert jnp.isfinite(m["total_loss"])
