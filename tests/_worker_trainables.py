"""Spawn-safe trainables for the process-worker tests.

Worker processes rebuild their trainable from an importable ``module:attr``
target (tests pass ``sys_path=(this dir,)`` in the TrainableFactory), so the
classes the process-executor tests drive must live in a real module — a class
defined inside a test function can never cross the spawn boundary.

Cross-process side-channels (did-I-crash-already markers) are files under a
config-supplied directory: class attributes don't survive into a fresh
interpreter, which is precisely the difference between this tier and the
thread tier.
"""
from __future__ import annotations

import os
import time

from repro.core.api import Trainable

__all__ = ["Counter", "LrCounter", "CrashOnce", "HangOnce", "Sleeper",
           "SliceCounter", "GrowAllergic",
           "train_fn", "make_function_trainable"]


def train_fn(tune):
    """Cooperative function-based trainable (paper Figure 2a)."""
    x = 0.0
    for _ in range(3):
        x += tune.params.get("inc", 1.0)
        tune.report(value=x)


def make_function_trainable():
    """Call-factory target: rebuilds the wrap_function adapter in the child."""
    from repro.core.api import wrap_function

    return wrap_function(train_fn)


class Counter(Trainable):
    """Deterministic arithmetic: loss = 1/n, state = n."""

    def setup(self, config):
        self.n = 0
        self.inc = int(config.get("inc", 1))

    def step(self):
        self.n += self.inc
        return {"loss": 1.0 / self.n, "n": self.n}

    def save(self):
        return {"n": self.n}

    def restore(self, state):
        self.n = state["n"]

    def reset_config(self, new_config):
        self.inc = int(new_config.get("inc", self.inc))
        return True


class LrCounter(Trainable):
    """lr-separable loss (drives every scheduler); mirrors the thread-tier
    fixture in test_concurrent_executor.py."""

    def setup(self, config):
        self.n = 0
        self.lr = float(config.get("lr", 0.01))

    def step(self):
        self.n += 1
        return {"loss": (self.lr - 0.01) ** 2 + 1.0 / self.n}

    def save(self):
        return {"n": self.n}

    def restore(self, state):
        self.n = state["n"]

    def reset_config(self, new_config):
        self.lr = float(new_config.get("lr", self.lr))
        self.config = dict(new_config)
        return True


class CrashOnce(Trainable):
    """Raises at iteration ``fail_at`` on the first incarnation only (a marker
    file under ``marker_dir`` records that the crash already happened)."""

    def setup(self, config):
        self.n = 0
        self.fail_at = int(config.get("fail_at", 3))
        self.marker = os.path.join(config["marker_dir"], "crashed.marker")

    def step(self):
        self.n += 1
        if self.n == self.fail_at and not os.path.exists(self.marker):
            with open(self.marker, "w") as f:
                f.write("crashed")
            raise RuntimeError("injected failure (process tier)")
        return {"loss": 1.0 / self.n}

    def save(self):
        return {"n": self.n}

    def restore(self, state):
        self.n = state["n"]


class HangOnce(Trainable):
    """Hangs (sleeps ~forever) at iteration ``hang_at`` on the first
    incarnation only — the kill-on-straggle fixture: the monitor must SIGKILL
    it, and the restarted worker (marker present) runs clean from the last
    checkpoint."""

    def setup(self, config):
        self.n = 0
        self.hang_at = int(config.get("hang_at", 3))
        self.hang_s = float(config.get("hang_s", 120.0))
        self.marker = os.path.join(config["marker_dir"], "hung.marker")

    def step(self):
        self.n += 1
        if self.n == self.hang_at and not os.path.exists(self.marker):
            with open(self.marker, "w") as f:
                f.write("hanging")
            time.sleep(self.hang_s)  # SIGKILL arrives mid-sleep
        return {"loss": 1.0 / self.n}

    def save(self):
        return {"n": self.n}

    def restore(self, state):
        self.n = state["n"]


class SliceCounter(Trainable):
    """Counter that reports the mesh-slice size it was built over — the
    elastic-resize fixture: after a broker resize the rebuilt instance sees
    the new ``_slice``, while ``n`` must survive the SAVE/RESTORE hop."""

    def setup(self, config):
        self.n = 0

    def step(self):
        self.n += 1
        sl = self.config.get("_slice")
        return {"loss": 1.0 / self.n, "n": self.n,
                "devices": sl.size if sl is not None else 0}

    def save(self):
        return {"n": self.n}

    def restore(self, state):
        self.n = state["n"]


class GrowAllergic(Trainable):
    """Refuses to build over more than ``max_ok`` devices — the resize-
    fallback fixture: the rebuild half of a grow fails, the executor must
    roll back to the old slice and the trial must finish unharmed."""

    def setup(self, config):
        sl = config.get("_slice")
        max_ok = int(config.get("max_ok", 2))
        if sl is not None and sl.size > max_ok:
            raise RuntimeError(
                f"injected rebuild failure: {sl.size} devices > max_ok={max_ok}")
        self.n = 0

    def step(self):
        self.n += 1
        sl = self.config.get("_slice")
        return {"loss": 1.0 / self.n,
                "devices": sl.size if sl is not None else 0}

    def save(self):
        return {"n": self.n}

    def restore(self, state):
        self.n = state["n"]


class Sleeper(Trainable):
    """Fixed-length steps (slice-holding sleep), for pause/kill timing tests."""

    def setup(self, config):
        self.n = 0
        self.sleep_s = float(config.get("sleep_s", 0.05))

    def save(self):
        return {"n": self.n}

    def restore(self, state):
        self.n = state["n"]

    def step(self):
        time.sleep(self.sleep_s)
        self.n += 1
        return {"loss": 1.0 / self.n}
