"""ClusterMeshExecutor on the localhost socket tier: FIFO result-stream
equality against the process tier, cross-host checkpoint recovery, framing
corruption escalating to host eviction (with the pump surviving), and the
placement policies (DESIGN.md §11)."""
import os
import socket
import struct
import threading
import time

import pytest

from repro.cluster import (ClusterMeshExecutor, FixedPlacement, HostSpec,
                           RooflinePlacement, parse_hosts)
from repro.cluster.hosts import HostAgent, fetch
from repro.cluster.placement import estimate_step_s, workload_cost
from repro.cluster.transport import client_handshake
from repro.core import (CheckpointManager, EventType, ObjectStore, Resources,
                        TrainableFactory, Trial, TrialStatus,
                        register_worker_factory, run_experiments, grid_search)
from repro.core.clock import WallClock
from repro.core.object_store import ObjectStore as _Store

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))


def factory(name: str) -> TrainableFactory:
    return TrainableFactory(target=f"_worker_trainables:{name}",
                            sys_path=(TESTS_DIR,))


def make_executor(name: str, hosts="2x4", **kw):
    kw.setdefault("placement", "fixed")
    return ClusterMeshExecutor(
        factory_resolver=lambda _n: factory(name),
        checkpoint_manager=CheckpointManager(ObjectStore()),
        hosts=hosts, checkpoint_freq=kw.pop("checkpoint_freq", 1), **kw)


# -- roster parsing --------------------------------------------------------------------

class TestParseHosts:
    def test_formats(self):
        assert [(s.name, s.devices) for s in parse_hosts(3)] == [
            ("h0", 8), ("h1", 8), ("h2", 8)]
        assert [(s.name, s.devices) for s in parse_hosts("2x4")] == [
            ("h0", 4), ("h1", 4)]
        assert [(s.name, s.devices) for s in parse_hosts("a:2,b:6")] == [
            ("a", 2), ("b", 6)]
        specs = parse_hosts([HostSpec("x", devices=1), ("y", 3)])
        assert [(s.name, s.devices) for s in specs] == [("x", 1), ("y", 3)]

    def test_rejects_duplicates_and_empty(self):
        with pytest.raises(ValueError):
            parse_hosts("a:2,a:4")
        with pytest.raises(ValueError):
            parse_hosts([])


# -- placement cost model --------------------------------------------------------------

def _trial(config=None, devices=1):
    return Trial(config or {}, trainable_name="T",
                 resources=Resources(cpu=1.0, devices=devices),
                 stopping_criteria={"training_iteration": 1})


class TestPlacement:
    def test_collective_term_grows_with_width(self):
        spec = HostSpec("h", devices=8)
        cost = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 1e9}
        assert estimate_step_s(cost, spec, 1) == 0.0  # no ring of one
        assert (estimate_step_s(cost, spec, 8)
                > estimate_step_s(cost, spec, 2) > 0.0)

    def test_roofline_right_sizes_instead_of_max_width(self):
        """Collective-bound workload: the model must pick a NARROW slice
        even though 8 devices are free."""
        hosts = [HostAgent(HostSpec("h0", devices=8), WallClock())]
        pol = RooflinePlacement(devices_per_trial=8)
        t = _trial({"_cost": {"flops": 1e9, "bytes": 0.0, "coll_bytes": 1e12}},
                   devices=8)
        choice = pol.place(t, hosts)
        assert choice is not None
        host, width = choice
        assert width < 8, ("collective-bound trial was given the full host; "
                           "right-sizing is not happening")

    def test_roofline_compute_bound_goes_wide(self):
        hosts = [HostAgent(HostSpec("h0", devices=8), WallClock())]
        pol = RooflinePlacement(devices_per_trial=1)
        t = _trial({"_cost": {"flops": 1e18, "bytes": 0.0, "coll_bytes": 0.0}},
                   devices=1)
        _, width = pol.place(t, hosts)
        assert width == 8, "compute-bound trial should take the widest slice"

    def test_unprofiled_falls_back_to_fixed(self):
        hosts = [HostAgent(HostSpec("h0", devices=8), WallClock())]
        pol = RooflinePlacement(devices_per_trial=2)
        t = _trial(devices=4)
        assert workload_cost(t) is None
        _, width = pol.place(t, hosts)
        assert width == 2  # devices_per_trial override, not the request

    def test_profile_denormalizes_to_cost(self):
        t = _trial()
        t.profile = {"roofline_compute_s": 1.0, "roofline_memory_s": 0.5,
                     "roofline_collective_s": 0.0, "dominant": "compute"}
        cost = workload_cost(t)
        assert cost is not None and cost["flops"] > 0 and cost["bytes"] > 0

    def test_fixed_prefers_most_free_alive_host(self):
        clock = WallClock()
        a = HostAgent(HostSpec("a", devices=8), clock)
        b = HostAgent(HostSpec("b", devices=8), clock)
        a.pool.acquire(6)
        choice = FixedPlacement().place(_trial(devices=2), [a, b])
        assert choice is not None and choice[0] is b
        b.alive = False
        choice = FixedPlacement().place(_trial(devices=2), [a, b])
        assert choice is not None and choice[0] is a


# -- cross-host checkpoint fetch -------------------------------------------------------

class TestFetch:
    def test_cas_digest_verified(self, tmp_path):
        import hashlib
        src = _Store(spill_dir=str(tmp_path / "src"))
        dst = _Store(spill_dir=str(tmp_path / "dst"))
        data = b"checkpoint-bytes"
        key = f"cas/t0/{hashlib.sha256(data).hexdigest()}"
        src.put_spilled(data, key=key)
        fetch(key, src, dst)
        assert dst.peek(key) == data
        # Corrupt payload under a digest key must be refused.
        bad_key = f"cas/t0/{hashlib.sha256(b'other').hexdigest()}"
        src.put_spilled(data, key=bad_key)
        with pytest.raises(IOError):
            fetch(bad_key, src, dst)

    def test_missing_key_raises(self, tmp_path):
        src = _Store(spill_dir=str(tmp_path / "a"))
        dst = _Store(spill_dir=str(tmp_path / "b"))
        with pytest.raises(KeyError):
            fetch("cas/t0/nope", src, dst)


# -- socket tier end-to-end ------------------------------------------------------------

@pytest.mark.timeout(600)
class TestSocketTier:
    def test_fifo_stream_equality_vs_process_tier(self):
        """Acceptance criterion: a 2-host localhost-socket sweep reproduces
        the process tier's statuses, result streams and losses exactly."""
        from _worker_trainables import LrCounter
        register_worker_factory("LrCounter", factory("LrCounter"))

        def sweep(executor, **kw):
            an = run_experiments(
                LrCounter, {"lr": grid_search([0.001, 0.005, 0.02, 0.08])},
                stop={"training_iteration": 5}, checkpoint_freq=1,
                executor=executor, seed=0, total_devices=8, **kw)
            return {
                t.config["lr"]: (t.status.value,
                                 [r.training_iteration for r in t.results],
                                 [r.metrics["loss"] for r in t.results])
                for t in an.trials}

        ref = sweep("process")
        got = sweep("cluster", hosts="2x4", placement="fixed")
        assert got == ref

    def test_crash_restart_restores_across_hosts(self, tmp_path):
        """A crashed trial's checkpoint was fetched to the controller before
        adoption, so the restart restores wherever placement lands it."""
        from _worker_trainables import CrashOnce
        register_worker_factory("CrashOnce", factory("CrashOnce"))
        an = run_experiments(
            CrashOnce, {"marker_dir": str(tmp_path), "fail_at": 3},
            stop={"training_iteration": 6}, checkpoint_freq=1,
            executor="cluster", hosts="2x4", placement="fixed",
            max_failures=2, seed=0)
        (t,) = an.trials
        assert t.status == TrialStatus.TERMINATED
        assert t.num_failures == 1
        ns = [round(1.0 / r.metrics["loss"]) for r in t.results]
        assert ns == [1, 2, 3, 4, 5, 6], (
            f"stream reset instead of restoring from checkpoint: {ns}")

    def test_framing_corruption_evicts_host_pump_survives(self):
        """A stranger dialing back with the victim's trial_id and spewing a
        corrupt frame must evict that host — and the pump must keep serving
        the other host's trials afterwards."""
        ex = make_executor("Sleeper", hosts="2x2", heartbeat_timeout=0.0)
        victim = Trial({"sleep_s": 0.2}, trainable_name="Sleeper",
                       resources=Resources(cpu=1.0, devices=1),
                       stopping_criteria={"training_iteration": 50},
                       trial_id="victim")
        try:
            assert ex.start_trial(victim)
            deadline = time.time() + 60
            while time.time() < deadline:
                if ex.get_next_event(timeout=1.0) is not None:
                    break  # worker is up and talking
            victim_host = ex._host_of["victim"].name
            # Reconnect-attach as the victim, then send garbage.
            sock = socket.create_connection(ex._listener.address, timeout=10)
            tr = client_handshake(
                sock, {"trial_id": "victim", "pid": 0, "token": ex._token})
            junk = b"this is not a pickle"
            tr.sock.sendall(struct.pack("!I", len(junk)) + junk)
            deadline = time.time() + 60
            while time.time() < deadline and ex.n_host_evictions == 0:
                ex.get_next_event(timeout=0.5)
            assert ex.n_host_evictions == 1
            assert not ex.hosts[victim_host].alive
            assert "corrupt" in (ex.hosts[victim_host].evicted_reason or "")
            tr.close()

            # The pump is not wedged: a fresh trial on the surviving host
            # still runs to completion.
            pump_alive = any(t.name == "repro-proc-pump" and t.is_alive()
                             for t in threading.enumerate())
            assert pump_alive, "pump thread died on the corrupt frame"
            after = Trial({"sleep_s": 0.01}, trainable_name="Sleeper",
                          resources=Resources(cpu=1.0, devices=1),
                          stopping_criteria={"training_iteration": 1},
                          trial_id="after")
            assert ex.start_trial(after)
            seen = set()
            deadline = time.time() + 60
            while time.time() < deadline and EventType.RESULT not in seen:
                ev = ex.get_next_event(timeout=1.0)
                if ev is not None and ev.trial_id == "after":
                    seen.add(ev.type)
            assert EventType.RESULT in seen, (
                "surviving host's trial produced nothing — pump wedged")
        finally:
            ex.shutdown()

    def test_host_state_and_listener_rejects_bad_token(self):
        ex = make_executor("Counter", hosts="2x2", heartbeat_timeout=0.0)
        try:
            state = ex.host_state()
            assert sorted(state) == ["h0", "h1"]
            assert all(s["alive"] and s["free"] == 2 for s in state.values())
            # A dialer with the wrong roster token is turned away: the
            # handshake acks (the token rides the hello, checked after), then
            # the listener hangs up without attaching.
            sock = socket.create_connection(ex._listener.address, timeout=10)
            sock.settimeout(10)
            tr = client_handshake(
                sock, {"trial_id": "x", "pid": 0, "token": "wrong"},
                timeout=10.0)
            tr.sock.settimeout(10.0)
            with pytest.raises(EOFError):
                tr.recv()
            tr.close()
            deadline = time.time() + 10
            while time.time() < deadline and ex._listener.n_rejected == 0:
                time.sleep(0.05)
            assert ex._listener.n_rejected == 1
        finally:
            ex.shutdown()
