"""ProcessMeshExecutor: scheduler matrix vs the serial executor, crash
recovery across real process boundaries, and the kill-on-straggle state
machine (SIGKILL mid-step -> slice reclaimed -> requeue-from-checkpoint)."""
import os
import signal
import time

import pytest

from repro.core import (ASHAScheduler, CheckpointManager, EventType,
                        FIFOScheduler, HyperBandScheduler, Logger, ObjectStore,
                        PopulationBasedTraining, ProcessMeshExecutor, Resources,
                        TrainableFactory, Trial, TrialRunner, TrialStatus,
                        grid_search, loguniform, register_worker_factory,
                        run_experiments)
from repro.dist.submesh import SlicePool

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))


def factory(name: str) -> TrainableFactory:
    return TrainableFactory(target=f"_worker_trainables:{name}",
                            sys_path=(TESTS_DIR,))


def make_executor(name: str, devices=8, checkpoint_freq=1, **kw):
    return ProcessMeshExecutor(
        factory_resolver=lambda _n: factory(name),
        checkpoint_manager=CheckpointManager(ObjectStore()),
        total_devices=devices, checkpoint_freq=checkpoint_freq, **kw)


class Recorder(Logger):
    def __init__(self):
        self.events = []

    def on_event(self, trial, event):
        self.events.append(event)

    def of(self, kind):
        return [e for e in self.events if e.type == kind]


SCHEDULERS = {
    "fifo": lambda: FIFOScheduler(metric="loss", mode="min"),
    "asha": lambda: ASHAScheduler(metric="loss", mode="min", max_t=6,
                                  grace_period=2, reduction_factor=2),
    "hyperband": lambda: HyperBandScheduler(metric="loss", mode="min",
                                            max_t=4, eta=2),
    "pbt": lambda: PopulationBasedTraining(
        metric="loss", mode="min", perturbation_interval=2,
        hyperparam_mutations={"lr": loguniform(1e-4, 1e-1)}, seed=0),
}


@pytest.mark.timeout(600)
class TestSchedulerMatrix:
    """The existing scheduler matrix, on worker processes."""

    @pytest.mark.parametrize("name", list(SCHEDULERS))
    def test_scheduler_on_process_executor(self, name):
        from _worker_trainables import LrCounter

        register_worker_factory("LrCounter", factory("LrCounter"))
        an = run_experiments(
            LrCounter,
            {"lr": loguniform(1e-3, 1e-1)},
            scheduler=SCHEDULERS[name](),
            num_samples=4,
            stop={"training_iteration": 6},
            total_devices=4,
            checkpoint_freq=1,
            executor="process",
            seed=0,
        )
        assert an.best_value() is not None
        finished = [t for t in an.trials if t.status == TrialStatus.TERMINATED]
        assert finished, f"{name}: no trial finished"
        for t in an.trials:  # per-trial results arrive strictly in order
            iters = [r.training_iteration for r in t.results]
            assert iters == sorted(iters), (name, t.trial_id, iters)

    def test_fifo_results_match_serial_executor(self):
        """Deterministic trainable + FIFO: the process tier must reproduce the
        serial tier's result stream exactly (same losses at same iterations)."""
        from _worker_trainables import LrCounter

        def sweep(executor):
            register_worker_factory("LrCounter", factory("LrCounter"))
            return run_experiments(
                LrCounter,
                {"lr": grid_search([0.005, 0.02, 0.08])},  # same trials both runs
                scheduler=FIFOScheduler(metric="loss", mode="min"),
                stop={"training_iteration": 5},
                total_devices=4,
                checkpoint_freq=1,
                executor=executor,
                seed=0,
            )

        serial, process = sweep("serial"), sweep("process")
        assert serial.best_value() == pytest.approx(process.best_value())
        s_by_cfg = {t.config["lr"]: t for t in serial.trials}
        for t in process.trials:
            ref = s_by_cfg[t.config["lr"]]
            assert t.status == ref.status == TrialStatus.TERMINATED
            assert ([r.training_iteration for r in t.results]
                    == [r.training_iteration for r in ref.results])
            for mine, theirs in zip(t.results, ref.results):
                assert mine.metrics["loss"] == pytest.approx(theirs.metrics["loss"])


@pytest.mark.timeout(600)
class TestFaultTolerance:
    def test_child_crash_restarts_from_checkpoint(self, tmp_path):
        """A worker that raises at iteration 3 is rebuilt in a fresh process
        and resumes from the iteration-2 checkpoint (no recomputation drift)."""
        rec = Recorder()
        ex = make_executor("CrashOnce")
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                             logger=rec,
                             stopping_criteria={"training_iteration": 5},
                             max_failures=1)
        trial = Trial({"fail_at": 3, "marker_dir": str(tmp_path)},
                      stopping_criteria={"training_iteration": 5})
        runner.add_trial(trial)
        runner.run()
        assert trial.status == TrialStatus.TERMINATED
        assert trial.num_failures == 1 and runner.n_restarts == 1
        assert len(rec.of(EventType.RESTARTED)) == 1
        assert [r.training_iteration for r in trial.results] == [1, 2, 3, 4, 5]
        assert trial.results[-1].metrics["loss"] == pytest.approx(1 / 5)

    def test_worker_sigkilled_externally_is_restarted(self, tmp_path):
        """Hard SIGKILL from outside (OOM-killer analogue): the pump sees the
        dead pipe, publishes ERROR, and max_failures restarts the trial from
        its last checkpoint."""
        rec = Recorder()
        ex = make_executor("Sleeper", devices=2)
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                             logger=rec,
                             stopping_criteria={"training_iteration": 6},
                             max_failures=1)
        trial = Trial({"sleep_s": 0.2}, resources=Resources(devices=2),
                      stopping_criteria={"training_iteration": 6})
        runner.add_trial(trial)
        # drive until a couple of checkpoints exist, then murder the worker
        deadline = time.time() + 120
        while trial.training_iteration < 2 and time.time() < deadline:
            runner.step()
        pid = ex.worker_pid(trial.trial_id)
        assert pid is not None
        os.kill(pid, signal.SIGKILL)
        runner.run()
        assert trial.status == TrialStatus.TERMINATED
        assert trial.training_iteration == 6
        assert trial.num_failures == 1
        assert len(rec.of(EventType.RESTARTED)) == 1
        assert [r.training_iteration for r in trial.results] == [1, 2, 3, 4, 5, 6]


@pytest.mark.timeout(600)
class TestKillOnStraggle:
    def test_straggler_killed_slice_reacquired_same_step(self, tmp_path):
        """The acceptance scenario: trial A hangs mid-step holding the only
        slice; the monitor SIGKILLs it after the deadline; PENDING trial B
        acquires the freed slice in the very next scheduler step; A restarts
        from its last checkpoint and both finish."""
        rec = Recorder()
        pool = SlicePool(n_virtual=2)
        ex = ProcessMeshExecutor(
            factory_resolver=lambda name: factory(name),
            checkpoint_manager=CheckpointManager(ObjectStore()),
            total_devices=2, slice_pool=pool, checkpoint_freq=1,
            heartbeat_timeout=0.3, straggler_deadline=1.0)
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                             logger=rec,
                             stopping_criteria={"training_iteration": 4},
                             max_failures=1)
        hang = Trial({"hang_at": 3, "marker_dir": str(tmp_path)},
                     trainable_name="HangOnce",
                     resources=Resources(devices=2),
                     stopping_criteria={"training_iteration": 4})
        pending = Trial({"inc": 1}, trainable_name="Counter",
                        resources=Resources(devices=2),
                        stopping_criteria={"training_iteration": 4})
        runner.add_trial(hang)
        runner.add_trial(pending)

        # Step the runner manually so we can observe the handoff precisely.
        deadline = time.time() + 180
        killed_seen = False
        while time.time() < deadline:
            more = runner.step()
            if not killed_seen and rec.of(EventType.KILLED):
                killed_seen = True
            if killed_seen and pending.status in (TrialStatus.RUNNING,
                                                  TrialStatus.TERMINATED):
                break
            if not more:
                break
        # The straggler was SIGKILLed and its slice went to the pending trial
        # within one scheduler step of the kill being processed.
        assert rec.of(EventType.KILLED), "monitor never killed the straggler"
        assert ex.n_killed == 1
        assert pending.status in (TrialStatus.RUNNING, TrialStatus.TERMINATED)
        assert hang.status in (TrialStatus.PAUSED, TrialStatus.PENDING,
                               TrialStatus.RUNNING, TrialStatus.TERMINATED)

        runner.run()
        # Straggle-heartbeats preceded the kill; the trial restarted from the
        # iteration-2 checkpoint and completed.
        assert rec.of(EventType.HEARTBEAT_MISSED)
        assert len(rec.of(EventType.RESTARTED)) == 1
        assert hang.status == TrialStatus.TERMINATED
        assert hang.num_failures == 1
        assert [r.training_iteration for r in hang.results] == [1, 2, 3, 4]
        assert pending.status == TrialStatus.TERMINATED
        assert pool.n_free == 2  # everything returned to the pool

    def test_virtual_deadline_math_kills_straggler(self):
        """Clock-seam port (DESIGN.md §7): the straggler deadline is FIVE
        MINUTES of *virtual* time, fast-forwarded in milliseconds of real
        time while the child sleeps on real wall-clock — children keep real
        time, the monitor's deadline arithmetic reads the injected clock.
        The wall version of this escalation (below) can only afford a 0.8s
        deadline; this one proves production-scale timeouts are testable."""
        from repro.core import VirtualClock

        vc = VirtualClock()
        pool = SlicePool(n_virtual=2)
        ex = ProcessMeshExecutor(
            factory_resolver=lambda name: factory("Sleeper"),
            checkpoint_manager=CheckpointManager(ObjectStore()),
            total_devices=2, slice_pool=pool, checkpoint_freq=0,
            heartbeat_timeout=0.0, straggler_deadline=300.0,
            spawn_timeout=0,  # spawn ages would fast-forward too: disable
            clock=vc)
        stuck = Trial({"sleep_s": 120.0}, resources=Resources(devices=2),
                      stopping_criteria={"training_iteration": 3})
        other = Trial({"sleep_s": 0.01}, resources=Resources(devices=2),
                      stopping_criteria={"training_iteration": 1})
        try:
            assert ex.start_trial(stuck)
            seen = set()
            deadline = time.time() + 120
            while time.time() < deadline and EventType.ERROR not in seen:
                ev = ex.get_next_event(timeout=30.0)  # 30 virtual s per call
                if ev is not None:
                    seen.add(ev.type)
            assert EventType.KILLED in seen and EventType.ERROR in seen
            assert ex.n_killed == 1
            assert vc.monotonic() >= 300.0  # the deadline actually elapsed
            ex.requeue_trial(stuck)
            assert ex.has_resources(other)  # slice reclaimed
            # The healthy child ahead runs on real time while virtual time
            # races — disable the (already-proven) kill escalation so its
            # virtual step age cannot SIGKILL a live, progressing worker.
            ex.straggler_deadline = 0.0
            assert ex.start_trial(other)
            ev = None
            deadline = time.time() + 120
            while time.time() < deadline:
                ev = ex.get_next_event(timeout=30.0)
                if ev is not None and ev.type == EventType.RESULT:
                    break
            assert ev is not None and ev.type == EventType.RESULT
            assert ev.trial_id == other.trial_id
        finally:
            ex.shutdown()

    def test_executor_level_slice_release_on_requeue(self, tmp_path):
        """After KILLED+ERROR, requeue_trial releases the slice immediately —
        has_resources flips before any relaunch."""
        pool = SlicePool(n_virtual=2)
        ex = ProcessMeshExecutor(
            factory_resolver=lambda name: factory("Sleeper"),
            checkpoint_manager=CheckpointManager(ObjectStore()),
            total_devices=2, slice_pool=pool, checkpoint_freq=0,
            heartbeat_timeout=0.0, straggler_deadline=0.8)
        stuck = Trial({"sleep_s": 60.0}, resources=Resources(devices=2),
                      stopping_criteria={"training_iteration": 3})
        other = Trial({"sleep_s": 0.01}, resources=Resources(devices=2),
                      stopping_criteria={"training_iteration": 1})
        try:
            assert ex.start_trial(stuck)
            assert not ex.has_resources(other)
            seen = set()
            deadline = time.time() + 120
            while time.time() < deadline and EventType.ERROR not in seen:
                ev = ex.get_next_event(timeout=2.0)
                if ev is not None:
                    seen.add(ev.type)
            assert EventType.KILLED in seen and EventType.ERROR in seen
            ex.requeue_trial(stuck)
            assert stuck.status == TrialStatus.PENDING  # no checkpoint yet
            assert ex.has_resources(other)              # slice is back
            assert ex.start_trial(other)
            ev = ex.get_next_event(timeout=60.0)
            assert ev is not None and ev.type == EventType.RESULT
            assert ev.trial_id == other.trial_id
        finally:
            ex.shutdown()


def _next_result(ex, timeout=60.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        ev = ex.get_next_event(timeout=deadline - time.time())
        if ev is not None and ev.type == EventType.RESULT:
            return ev
    raise AssertionError("no RESULT event in time")


@pytest.mark.timeout(600)
class TestProcessPBTRestart:
    def test_restart_with_config_in_place(self, tmp_path):
        """RESET_CONFIG + RESTORE without tearing the process down."""
        ex = make_executor("Counter", devices=4)
        trial = Trial({"inc": 1}, resources=Resources(devices=2))
        try:
            assert ex.start_trial(trial)
            _next_result(ex)
            ckpt = ex.save_checkpoint(trial)
            pid_before = ex.worker_pid(trial.trial_id)
            ex.restart_trial_with_config(trial, ckpt, {"inc": 5})
            assert ex.worker_pid(trial.trial_id) == pid_before  # same process
            ev = _next_result(ex)
            # restored n=1 then stepped with inc=5
            assert ev.result.metrics["n"] == 6
        finally:
            ex.shutdown()

    def test_function_trainable_via_factory(self):
        """Cooperative function trainables work inside a worker process (the
        wrap_function adapter is rebuilt in the child via a call-factory)."""
        fac = TrainableFactory(target="_worker_trainables:make_function_trainable",
                               call=True, sys_path=(TESTS_DIR,))
        ex = ProcessMeshExecutor(
            factory_resolver=lambda name: fac,
            checkpoint_manager=CheckpointManager(ObjectStore()),
            total_devices=4, checkpoint_freq=0)
        runner = TrialRunner(FIFOScheduler(metric="value", mode="max"), ex)
        t1 = Trial({"inc": 2.0})
        runner.add_trial(t1)
        runner.run()
        assert t1.status == TrialStatus.TERMINATED
        vals = [r.metrics["value"] for r in t1.results if "value" in r.metrics]
        assert vals == [2.0, 4.0, 6.0]
