"""The large-scale fault-scenario matrix (DESIGN.md §7) on virtual time.

Each sweep runs 1000 trials (250 per scheduler cell) of a scripted failure
class — crash storm, straggler cascade, elastic resize churn — across
FIFO/ASHA/HyperBand/PBT on the concurrent executor, then audits the run:
zero slice leaks, gapless per-trial streams, restart/error counts reconciling
exactly with the scripted faults, and (on a capacity-1 pool) decision
equivalence against the serial reference tier.  Minute-scale heartbeat and
straggle timelines run in real milliseconds, which is the entire point of
the clock seam: this file covers more failure schedules than every wall-time
executor test combined, in a fraction of the time.

CI runs this file as its own job (see .github/workflows/ci.yml); the unit
job ignores it to protect the tier-1 wall-clock budget.
"""
import time

import pytest

from repro.core import (ASHAScheduler, FIFOScheduler, HyperBandScheduler,
                        PopulationBasedTraining)
from repro.testing import (SimTrainable, check_all, check_serial_equivalence,
                           crash_storm, resize_churn, reset_faults,
                           run_scenario, straggler_cascade)

N_PER_CELL = 250  # x 4 schedulers = a 1000-trial sweep per scenario class

SCHEDULERS = {
    "fifo": lambda: FIFOScheduler(metric="loss", mode="min"),
    "asha": lambda: ASHAScheduler(metric="loss", mode="min", max_t=5,
                                  grace_period=2, reduction_factor=2),
    "hyperband": lambda: HyperBandScheduler(metric="loss", mode="min",
                                            max_t=4, eta=2),
    "pbt": lambda: PopulationBasedTraining(
        metric="loss", mode="min", perturbation_interval=2,
        hyperparam_mutations={"lr": [0.005, 0.02, 0.08]}, seed=0),
}

SCENARIOS = {
    "crash-storm": lambda n, seed: crash_storm(n_trials=n, seed=seed),
    "straggler-cascade": lambda n, seed: straggler_cascade(n_trials=n, seed=seed),
    "resize-churn": lambda n, seed: resize_churn(n_trials=n, seed=seed),
}

# Wall budget per 250-trial cell; the whole 12-cell matrix must land far
# under the 60s acceptance bound, so a single cell creeping past this is a
# perf regression worth failing on.
CELL_WALL_BUDGET_S = 20.0


@pytest.mark.timeout(300)
class TestFaultScenarioMatrix:
    @pytest.mark.parametrize("scenario_name", list(SCENARIOS))
    @pytest.mark.parametrize("sched_name", list(SCHEDULERS))
    def test_sweep_cell(self, scenario_name, sched_name):
        scenario = SCENARIOS[scenario_name](N_PER_CELL, seed=11)
        t0 = time.monotonic()
        result = run_scenario(scenario, SCHEDULERS[sched_name],
                              executor="concurrent", pool_devices=8)
        wall = time.monotonic() - t0
        # Only FIFO runs every trial to completion, so only there do the
        # scripted fault counts reconcile exactly; early-stopping schedulers
        # may cancel a trial before its fault fires (bounds still hold).
        check_all(result,
                  strict=(sched_name == "fifo"),
                  gapless=(sched_name != "pbt"))
        assert result.virtual_elapsed_s > 10.0, "suspiciously little virtual time"
        assert wall < CELL_WALL_BUDGET_S, (
            f"{scenario_name} x {sched_name}: {N_PER_CELL} trials took "
            f"{wall:.1f}s wall (> {CELL_WALL_BUDGET_S}s) — virtual-time "
            f"harness perf regression")
        # State continuity through every restart/resize: the counter a trial
        # reports must track its iteration exactly (PBT clones excepted — a
        # donor's counter legitimately jumps the stream forward).
        if sched_name != "pbt":
            for t in result.trials:
                for r in t.results:
                    assert r.metrics["n"] == r.training_iteration, (
                        t.trial_id, r.training_iteration, r.metrics)

    def test_resize_churn_actually_churns(self):
        scenario = resize_churn(n_trials=80, seed=3)
        result = run_scenario(scenario, SCHEDULERS["asha"],
                              executor="concurrent", pool_devices=8)
        check_all(result, strict=False)
        assert result.runner.broker is not None
        assert result.runner.broker.n_resized >= 1, (
            "fair-share churn scenario produced no resizes")

    def test_straggler_cascade_surfaces_every_straggler(self):
        from repro.core import EventType

        scenario = straggler_cascade(n_trials=120, seed=5)
        result = run_scenario(scenario, SCHEDULERS["fifo"],
                              executor="concurrent", pool_devices=8)
        check_all(result, strict=True)
        warned = {e.trial_id for e in result.recorder.of(EventType.HEARTBEAT_MISSED)}
        assert len(warned) == scenario.expected_stragglers
        # heartbeats never perturbed an outcome: every trial still finished
        assert all(t.status.value == "TERMINATED" for t in result.trials)


@pytest.mark.timeout(300)
class TestSerialEquivalence:
    """On a capacity-1 pool the concurrent tier (virtual worker threads,
    heartbeat monitor running) must reproduce the serial executor's statuses,
    result streams and losses exactly — faults included."""

    @pytest.mark.parametrize("sched_name", list(SCHEDULERS))
    def test_equivalence_under_faults(self, sched_name):
        scenario = crash_storm(n_trials=10, seed=23, crash_frac=0.5,
                               fatal_frac=0.1)
        check_serial_equivalence(scenario, SCHEDULERS[sched_name])

    def test_equivalence_with_stragglers(self):
        # Heartbeat events fire on the concurrent run only; decisions must
        # not notice.
        scenario = straggler_cascade(n_trials=8, seed=2, straggle_frac=0.5,
                                     heartbeat_timeout=10.0)
        check_serial_equivalence(scenario, SCHEDULERS["asha"])


class TestSimTrainableFaults:
    def test_crash_fires_limited_times(self):
        reset_faults()
        cfg = {"sim_id": "x", "sim_token": "tok", "step_s": 0.0,
               "crash_at": 2, "crash_count": 2}
        for incarnation in range(3):
            tr = SimTrainable(dict(cfg))
            tr.restore({"n": 1})
            if incarnation < 2:
                with pytest.raises(RuntimeError, match="injected crash"):
                    tr.step()
            else:
                assert tr.step()["n"] == 2  # budget spent; step succeeds
        reset_faults("tok")

    def test_kill_is_distinct_exception(self):
        from repro.testing import SimKilled

        reset_faults()
        tr = SimTrainable({"sim_id": "k", "sim_token": "tok2", "step_s": 0.0,
                           "kill_at": 1})
        with pytest.raises(SimKilled):
            tr.step()
        assert tr.step()["n"] == 1  # kill fires once
        reset_faults("tok2")

    def test_straggle_consumes_virtual_time(self):
        from repro.core import VirtualClock, use_clock

        reset_faults()
        with use_clock(VirtualClock()) as vc:
            tr = SimTrainable({"sim_id": "s", "sim_token": "tok3",
                               "step_s": 1.0, "straggle_at": 2,
                               "straggle_s": 300.0})
            tr.step()
            assert vc.monotonic() == pytest.approx(1.0)
            tr.step()  # the straggle
            assert vc.monotonic() == pytest.approx(301.0)
            tr.step()  # fired once; back to scripted pace
            assert vc.monotonic() == pytest.approx(302.0)
        reset_faults("tok3")
