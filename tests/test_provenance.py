"""Decision provenance (DESIGN.md §10): journaled scheduler/searcher verdicts,
explain surfaces, durable scheduler/searcher state, the crash-forensics
flight recorder, and the ``repro.launch.explain`` CLI.

Acceptance (ISSUE 8): the explain CLI answers "why did trial X stop/pause/
get-perturbed" from the journal alone for FIFO/ASHA/HyperBand/MedianStopping/
PBT, and a SIGTERM'd 100-trial VirtualClock crash storm leaves a forensic
bundle from which the CLI reproduces the same answers byte-identically across
two identical-token runs.
"""
import json
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (ASHAScheduler, CheckpointManager, FIFOScheduler,
                        GPSearcher, GridSearcher, HyperBandScheduler,
                        MedianStoppingRule, ObjectStore,
                        PopulationBasedTraining, RandomSearcher, Result,
                        SchedulerDecision, SerialMeshExecutor, TPESearcher,
                        Trainable, Trial, TrialRunner, TrialStatus,
                        run_experiments, uniform)
from repro.core.events import EventType
from repro.launch.explain import main as explain_main
from repro.obs.analysis import ExperimentAnalysis, format_decision
from repro.obs.flightrec import (FlightRecorder, SearchStateSnapshotter,
                                 json_safe)
from repro.testing import (RecordingLogger, check_decision_provenance,
                           crash_storm, run_scenario)


class DecayTrainable(Trainable):
    """loss = quality + 0.8^iter — separable per-trial quality."""

    def setup(self, config):
        self.q = config["quality"]
        self.x = 1.0

    def step(self):
        self.x *= 0.8
        return {"loss": self.q + self.x}

    def save(self):
        return {"x": self.x, "q": self.q}

    def restore(self, state):
        self.x = state["x"]
        self.q = state["q"]

    def reset_config(self, cfg):
        self.q = cfg["quality"]
        return True


def run_qualities(qualities, scheduler, max_iter=20, devices=4,
                  journal_path=None):
    """Run one quality per trial; returns (trials dict, RecordingLogger)."""
    from repro.core.loggers import CompositeLogger, JSONLLogger

    store = ObjectStore()
    executor = SerialMeshExecutor(
        trainable_cls_resolver=lambda name: DecayTrainable,
        checkpoint_manager=CheckpointManager(store),
        total_devices=devices, checkpoint_freq=1)
    recorder = RecordingLogger()
    logger = recorder
    journal = None
    if journal_path is not None:
        journal = JSONLLogger(journal_path, run_id="run-prov")
        logger = CompositeLogger([recorder, journal])
    runner = TrialRunner(scheduler, executor, logger=logger,
                         stopping_criteria={"training_iteration": max_iter})
    for i, q in enumerate(qualities):
        runner.add_trial(Trial({"quality": q}, trial_id=f"t{i:03d}",
                               stopping_criteria={"training_iteration": max_iter}))
    trials = runner.run()
    if journal is not None:
        journal.close()
    return {t.trial_id: t for t in trials}, recorder


def decision_infos(recorder, trial_id=None):
    out = [e.info for e in recorder.of(EventType.DECISION)
           if trial_id is None or e.trial_id == trial_id]
    return out


# ---------------------------------------------------------------------------
# explain_last — scheduler and searcher verdicts carry their inputs
# ---------------------------------------------------------------------------

class TestExplainLast:
    def test_asha_rung_stop_inputs(self):
        sched = ASHAScheduler(metric="loss", mode="min", max_t=10,
                              grace_period=1, reduction_factor=2)
        trials = [Trial({"i": i}, trial_id=f"a{i}") for i in range(3)]
        for t in trials:
            sched.on_trial_add(None, t)
        sched.on_result(None, trials[0], Result("a0", 1, {"loss": 0.1}))
        # a1 beats the 1-sample cutoff (-0.05 > -0.1), so only a2 gets cut
        sched.on_result(None, trials[1], Result("a1", 1, {"loss": 0.05}))
        d = sched.on_result(None, trials[2], Result("a2", 1, {"loss": 5.0}))
        assert d == SchedulerDecision.STOP
        rec = sched.explain_last()
        assert rec["trial_id"] == "a2" and rec["verdict"] == "STOP"
        inp = rec["inputs"]
        assert inp["reason"] == "rung" and inp["milestone"] == 1
        assert inp["score"] == -5.0 and inp["score"] < inp["cutoff"]
        assert inp["n_rung"] == 2 and inp["rf"] == 2
        # the drain queue holds every recorded verdict, then empties
        drained = sched.pop_decisions()
        assert [r["trial_id"] for r in drained if r["verdict"] == "STOP"] == ["a2"]
        assert sched.pop_decisions() == []

    def test_asha_max_t_stop(self):
        sched = ASHAScheduler(metric="loss", mode="min", max_t=5,
                              grace_period=1)
        t = Trial({}, trial_id="m0")
        sched.on_trial_add(None, t)
        assert sched.on_result(None, t, Result("m0", 5, {"loss": 0.1})) \
            == SchedulerDecision.STOP
        assert sched.explain_last()["inputs"] == {"reason": "max_t", "max_t": 5}

    def test_median_stop_inputs(self):
        sched = MedianStoppingRule(metric="loss", mode="min", grace_period=1,
                                   min_samples_required=2)
        ts = [Trial({}, trial_id=f"m{i}") for i in range(3)]
        for step in (1, 2):
            sched.on_result(None, ts[0], Result("m0", step, {"loss": 0.1}))
            sched.on_result(None, ts[1], Result("m1", step, {"loss": 0.2}))
        sched.on_result(None, ts[2], Result("m2", 1, {"loss": 5.0}))
        d = sched.on_result(None, ts[2], Result("m2", 2, {"loss": 5.0}))
        assert d == SchedulerDecision.STOP
        inp = sched.explain_last()["inputs"]
        assert inp["reason"] == "median" and inp["step"] == 2
        assert inp["best_so_far"] < inp["median"] and inp["n_others"] == 2

    def test_fifo_runner_stop_reason_journaled(self):
        trials, rec = run_qualities([0.1, 0.5], FIFOScheduler(metric="loss",
                                                              mode="min"),
                                    max_iter=5)
        for tid in trials:
            infos = decision_infos(rec, tid)
            assert len(infos) == 1
            info = infos[0]
            assert info["source"] == "runner" and info["verdict"] == "STOP"
            assert info["inputs"] == {"reason": "stopping_criterion",
                                      "criterion": "training_iteration",
                                      "bound": 5, "value": 5}

    def test_hyperband_cut_records(self):
        sched = HyperBandScheduler(metric="loss", mode="min", max_t=9, eta=3)
        trials, rec = run_qualities(list(np.linspace(0.0, 2.0, 9)), sched,
                                    max_iter=9, devices=3)
        cuts = [i for i in decision_infos(rec)
                if i["inputs"].get("reason") in ("cut", "cut_after_error")]
        assert cuts, "a 9-trial eta=3 bracket must have cut at least once"
        stopped = [i for i in cuts if i["verdict"] == "STOP"]
        kept = [i for i in cuts if i["verdict"] in ("CONTINUE", "PROMOTE")]
        assert stopped and kept
        for i in stopped:
            assert i["inputs"]["rank"] >= i["inputs"]["n_keep"]
            assert i["inputs"]["score"] <= i["inputs"]["cut_score"]
        for i in kept:
            assert i["inputs"]["rank"] < i["inputs"]["n_keep"]
        # milestone_wait PAUSE verdicts are journaled too
        waits = [i for i in decision_infos(rec)
                 if i["inputs"].get("reason") == "milestone_wait"]
        assert all(i["verdict"] == "PAUSE" for i in waits)

    def test_pbt_exploit_records_lineage(self):
        sched = PopulationBasedTraining(
            metric="loss", mode="min", perturbation_interval=3,
            hyperparam_mutations={"quality": uniform(0.0, 2.0)},
            quantile_fraction=0.34, seed=0)
        trials, rec = run_qualities([0.0, 1.0, 2.0], sched, max_iter=15,
                                    devices=3)
        exploits = [i for i in decision_infos(rec)
                    if i["verdict"] == "RESTART_WITH_CONFIG"]
        assert len(exploits) == sched.n_exploits >= 1
        for i in exploits:
            inp = i["inputs"]
            assert inp["reason"] == "exploit"
            assert inp["donor"] in trials
            assert inp["donor_score"] >= inp["my_score"]
            assert "quality" in inp["new_config"]

    def test_searcher_explain_last(self):
        space = {"x": uniform(0.0, 1.0)}
        rs = RandomSearcher(space, max_trials=4, seed=1)
        assert rs.explain_last() is None
        rs.suggest("s0")
        assert rs.explain_last()["inputs"] == {
            "strategy": "random", "n_suggested": 1, "max_trials": 4}
        gs = GridSearcher({"x": uniform(0.0, 1.0)}, num_samples=3, seed=2)
        gs.suggest("g0")
        gs.suggest("g1")
        rec = gs.explain_last()
        assert rec["trial_id"] == "g1"
        assert rec["inputs"] == {"strategy": "grid", "index": 1}

    def test_gp_tpe_explain_posterior_inputs(self):
        space = {"x": uniform(0.0, 1.0)}
        gp = GPSearcher(space, n_startup_trials=2, seed=3)
        gp.suggest("g0")
        assert gp.explain_last()["inputs"]["strategy"] == "random_startup"
        for i in range(3):
            gp.observe(f"g{i}", {"x": 0.1 * (i + 1)}, 1.0 - 0.2 * i, True)
        gp.suggest("g3")
        inp = gp.explain_last()["inputs"]
        assert inp["strategy"] == "gp_ei" and inp["n_obs"] == 3
        assert {"best_score", "ei", "posterior_mean",
                "posterior_std"} <= set(inp)
        tpe = TPESearcher(space, n_startup_trials=2, seed=4)
        for i in range(3):
            tpe.observe(f"t{i}", {"x": 0.2 * (i + 1)}, float(i), True)
        tpe.suggest("t3")
        inp = tpe.explain_last()["inputs"]
        assert inp["strategy"] == "tpe"
        assert inp["n_good"] + inp["n_bad"] == inp["n_obs"] == 3


# ---------------------------------------------------------------------------
# state_dict / load_state_dict — JSON-durable scheduler + searcher state
# ---------------------------------------------------------------------------

def _json_roundtrip(state):
    return json.loads(json.dumps(json_safe(state)))


class TestDurableState:
    def test_fifo_stateless(self):
        assert FIFOScheduler().state_dict() == {}

    def test_asha_roundtrip(self):
        s1 = ASHAScheduler(metric="loss", mode="min", max_t=10,
                           grace_period=1, reduction_factor=2)
        trials = [Trial({}, trial_id=f"a{i}") for i in range(4)]
        for t in trials:
            s1.on_trial_add(None, t)
        for i, t in enumerate(trials[:3]):
            s1.on_result(None, t, Result(t.trial_id, 1, {"loss": 0.1 * i}))
        state = _json_roundtrip(s1.state_dict())
        s2 = ASHAScheduler(metric="loss", mode="min", max_t=10,
                           grace_period=1, reduction_factor=2)
        s2.load_state_dict(state)
        assert _json_roundtrip(s2.state_dict()) == state
        # restored rung state reproduces the original's next verdict
        r = Result("a3", 1, {"loss": 9.0})
        assert s2.on_result(None, trials[3], r) \
            == s1.on_result(None, trials[3], r) == SchedulerDecision.STOP

    def test_hyperband_roundtrip(self):
        s1 = HyperBandScheduler(metric="loss", mode="min", max_t=9, eta=3)
        trials, _ = run_qualities(list(np.linspace(0.0, 2.0, 9)), s1,
                                  max_iter=9, devices=3)
        state = _json_roundtrip(s1.state_dict())
        s2 = HyperBandScheduler(metric="loss", mode="min", max_t=9, eta=3)
        s2.load_state_dict(state, trials=trials)
        assert _json_roundtrip(s2.state_dict()) == state
        assert s2.n_stopped == s1.n_stopped

    def test_median_roundtrip(self):
        s1 = MedianStoppingRule(metric="loss", mode="min", grace_period=1,
                                min_samples_required=2)
        run_qualities([0.0, 0.1, 2.0], s1, max_iter=8, devices=3)
        state = _json_roundtrip(s1.state_dict())
        s2 = MedianStoppingRule(metric="loss", mode="min", grace_period=1,
                                min_samples_required=2)
        s2.load_state_dict(state)
        assert _json_roundtrip(s2.state_dict()) == state

    def test_pbt_roundtrip_preserves_rng_stream(self):
        mk = lambda: PopulationBasedTraining(
            metric="loss", mode="min", perturbation_interval=3,
            hyperparam_mutations={"quality": uniform(0.0, 2.0)}, seed=0)
        s1 = mk()
        run_qualities([0.0, 1.0, 2.0], s1, max_iter=9, devices=3)
        state = _json_roundtrip(s1.state_dict())
        s2 = mk()
        s2.load_state_dict(state)
        assert _json_roundtrip(s2.state_dict()) == state
        # the restored rng continues the exact stream
        assert s2._explore({"quality": 1.0}) == s1._explore({"quality": 1.0})

    def test_random_searcher_roundtrip(self):
        space = {"x": uniform(0.0, 1.0)}
        s1 = RandomSearcher(space, seed=5)
        for i in range(3):
            s1.suggest(f"r{i}")
        state = _json_roundtrip(s1.state_dict())
        s2 = RandomSearcher(space, seed=0)  # seed overwritten by load
        s2.load_state_dict(state)
        assert s2.suggest("r3") == s1.suggest("r3")

    def test_grid_searcher_fast_forward(self):
        space = {"x": uniform(0.0, 1.0)}
        s1 = GridSearcher(space, num_samples=5, seed=6)
        for i in range(2):
            s1.suggest(f"g{i}")
        state = _json_roundtrip(s1.state_dict())
        s2 = GridSearcher(space, num_samples=5, seed=6)
        s2.load_state_dict(state)
        assert s2._n_emitted == 2
        assert s2.suggest("g2") == s1.suggest("g2")

    @pytest.mark.parametrize("cls,kw", [(GPSearcher, {"n_startup_trials": 2}),
                                        (TPESearcher, {"n_startup_trials": 2})])
    def test_model_searcher_roundtrip(self, cls, kw):
        space = {"x": uniform(0.0, 1.0)}
        s1 = cls(space, seed=7, **kw)
        for i in range(3):
            s1.observe(f"o{i}", {"x": 0.2 * (i + 1)}, 1.0 - 0.3 * i, True)
        state = _json_roundtrip(s1.state_dict())
        s2 = cls(space, seed=0, **kw)
        s2.load_state_dict(state)
        assert s2.suggest("n0") == s1.suggest("n0")
        assert s2.explain_last()["inputs"] == s1.explain_last()["inputs"]


# ---------------------------------------------------------------------------
# flight recorder — bounded rings, forensic bundles, byte-determinism
# ---------------------------------------------------------------------------

class TestFlightRecorder:
    def test_ring_is_bounded(self):
        fr = FlightRecorder(capacity=16, decision_capacity=8)
        from repro.core.events import TrialEvent
        for i in range(100):
            fr.record_event(TrialEvent(EventType.RESULT, f"t{i}"))
            fr.record_decision(TrialEvent(EventType.DECISION, f"t{i}"))
        b = fr.bundle()
        assert len(b["events"]) == 16 and len(b["decisions"]) == 8
        assert b["n_events_seen"] == 100
        # the ring kept the MOST RECENT events
        assert b["events"][-1]["trial_id"] == "t99"

    def test_json_safe_coerces_everything(self):
        class Opaque:
            def __repr__(self):
                return "<opaque>"
        v = json_safe({"a": np.float64(1.5), "b": [np.int32(2), Opaque()],
                       "c": {"d": (1, 2)}})
        assert json.dumps(v)  # serializes
        assert v["a"] == 1.5 and v["b"] == [2, "<opaque>"]

    def test_bundle_contents_from_storm(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_FLIGHTREC_DIR", str(tmp_path / "fr"))
        res = run_scenario(crash_storm(n_trials=30, seed=2),
                           lambda: FIFOScheduler(metric="loss", mode="min"),
                           pool_devices=8, token="fr-bundle")
        path = res.flightrec.dump(res.runner, res.executor, reason="manual")
        assert os.path.basename(path) == "run-fr-bundle-00-manual.json"
        with open(path) as f:
            b = json.load(f)
        assert b["run_id"] == "run-fr-bundle" and b["reason"] == "manual"
        assert b["schema_version"] == 1
        assert b["decisions"] and b["events"]
        assert b["scheduler"]["type"] == "FIFOScheduler"
        tids = [r["trial_id"] for r in b["trials"]]
        assert tids == sorted(tids) and len(tids) == 30
        assert b["status_counts"].get("TERMINATED", 0) > 0
        assert b["pool"]["utilization"] == 0.0  # run finished, pool drained
        assert b["n_restarts"] == res.runner.n_restarts

    def test_same_token_bundles_byte_identical(self, tmp_path, monkeypatch):
        paths = []
        for d in ("one", "two"):
            monkeypatch.setenv("REPRO_FLIGHTREC_DIR", str(tmp_path / d))
            res = run_scenario(crash_storm(n_trials=30, seed=2),
                               lambda: FIFOScheduler(metric="loss",
                                                     mode="min"),
                               pool_devices=8, token="fr-det")
            paths.append(res.flightrec.dump(res.runner, res.executor,
                                            reason="manual"))
        b1 = open(paths[0], "rb").read()
        b2 = open(paths[1], "rb").read()
        assert b1 == b2

    def test_snapshotter_throttles_on_clock(self, tmp_path):
        from repro.core.clock import VirtualClock
        clock = VirtualClock()
        snap = SearchStateSnapshotter(str(tmp_path / "ss.json"), clock=clock,
                                      interval_s=10.0)
        sched = MedianStoppingRule()
        assert snap.maybe_snapshot(sched) is True
        assert snap.maybe_snapshot(sched) is False  # inside the window
        clock._now += 11.0
        assert snap.maybe_snapshot(sched) is True
        assert snap.n_snapshots == 2
        state = json.load(open(str(tmp_path / "ss.json")))
        assert state["scheduler"]["type"] == "MedianStoppingRule"
        assert "scores" in state["scheduler"]["state"]


# ---------------------------------------------------------------------------
# provenance invariants + journaling policy over a crash storm
# ---------------------------------------------------------------------------

class TestProvenanceInvariants:
    def test_checker_passes_all_schedulers(self):
        for factory in (
            lambda: FIFOScheduler(metric="loss", mode="min"),
            lambda: ASHAScheduler(metric="loss", mode="min", max_t=5,
                                  grace_period=1, reduction_factor=2),
            lambda: MedianStoppingRule(metric="loss", mode="min",
                                       grace_period=1,
                                       min_samples_required=3),
        ):
            res = run_scenario(crash_storm(n_trials=30, seed=5), factory,
                               pool_devices=8)
            check_decision_provenance(res)

    def test_checker_catches_missing_records(self):
        res = run_scenario(crash_storm(n_trials=10, seed=5),
                           lambda: FIFOScheduler(metric="loss", mode="min"),
                           pool_devices=4)
        res.recorder.events = [e for e in res.recorder.events
                               if e.type != EventType.DECISION]
        with pytest.raises(AssertionError, match="no STOP decision"):
            check_decision_provenance(res)

    def test_decisions_off_drains_silently(self):
        res = run_scenario(crash_storm(n_trials=10, seed=5),
                           lambda: FIFOScheduler(metric="loss", mode="min"),
                           pool_devices=4, decisions=False)
        assert res.recorder.of(EventType.DECISION) == []
        # nothing left festering in the scheduler's drain queue either
        assert res.runner.scheduler.pop_decisions() == []

    def test_decisions_full_includes_continue(self, tmp_path):
        sched = MedianStoppingRule(metric="loss", mode="min", grace_period=1,
                                   min_samples_required=2)
        store = ObjectStore()
        executor = SerialMeshExecutor(
            trainable_cls_resolver=lambda name: DecayTrainable,
            checkpoint_manager=CheckpointManager(store),
            total_devices=3, checkpoint_freq=1)
        rec = RecordingLogger()
        runner = TrialRunner(sched, executor, logger=rec, decisions="full",
                             stopping_criteria={"training_iteration": 6})
        # serial execution: only the LAST trial sees >= 2 reference trials;
        # make it the winner so its post-threshold verdicts are CONTINUE
        for i, q in enumerate([1.5, 1.6, 0.0]):
            runner.add_trial(Trial({"quality": q}, trial_id=f"f{i}",
                                   stopping_criteria={"training_iteration": 6}))
        runner.run()
        verdicts = {i["verdict"] for i in decision_infos(rec)}
        assert "CONTINUE" in verdicts  # default policy filters these out


# ---------------------------------------------------------------------------
# explain CLI — journal answers for every scheduler family
# ---------------------------------------------------------------------------

class TestExplainCLI:
    def _explain(self, capsys, *args):
        assert explain_main(list(args)) == 0
        return capsys.readouterr().out

    def test_fifo_stop_answer(self, tmp_path, capsys):
        jp = str(tmp_path / "ev.jsonl")
        run_qualities([0.1], FIFOScheduler(metric="loss", mode="min"),
                      max_iter=5, journal_path=jp)
        out = self._explain(capsys, "--journal", jp, "--trial", "t000")
        assert "trial t000: TERMINATED, 5 iterations" in out
        assert "training_iteration reached its bound (5 >= 5)" in out
        assert "fate: STOP by TrialRunner" in out

    def test_asha_stop_answer(self, tmp_path, capsys):
        jp = str(tmp_path / "ev.jsonl")
        sched = ASHAScheduler(metric="loss", mode="min", max_t=20,
                              grace_period=2, reduction_factor=3)
        trials, rec = run_qualities(list(np.linspace(0.0, 2.0, 16)), sched,
                                    max_iter=20, journal_path=jp)
        stopped = next(i for i in decision_infos(rec)
                       if i["verdict"] == "STOP"
                       and i["inputs"].get("reason") == "rung")
        an = ExperimentAnalysis.from_journal(jp)
        tid = next(t for t in an.trial_ids()
                   if any((d["info"]["inputs"] or {}).get("reason") == "rung"
                          and d["info"]["verdict"] == "STOP"
                          for d in an.decisions(t)))
        out = self._explain(capsys, "--journal", jp, "--trial", tid)
        assert "STOP by AsyncHyperBandScheduler" in out
        assert "rung@" in out and "vs cutoff" in out

    def test_hyperband_cut_answer(self, tmp_path, capsys):
        jp = str(tmp_path / "ev.jsonl")
        sched = HyperBandScheduler(metric="loss", mode="min", max_t=9, eta=3)
        run_qualities(list(np.linspace(0.0, 2.0, 9)), sched, max_iter=9,
                      devices=3, journal_path=jp)
        an = ExperimentAnalysis.from_journal(jp)
        tid = next(t for t in an.trial_ids()
                   if any(d["info"]["verdict"] == "STOP"
                          and (d["info"]["inputs"] or {}).get("reason") == "cut"
                          for d in an.decisions(t)))
        out = self._explain(capsys, "--journal", jp, "--trial", tid)
        assert "halving cut@" in out and "STOP by HyperBandScheduler" in out

    def test_median_stop_answer(self, tmp_path, capsys):
        jp = str(tmp_path / "ev.jsonl")
        sched = MedianStoppingRule(metric="loss", mode="min", grace_period=2,
                                   min_samples_required=2)
        run_qualities([0.0, 0.1, 0.2, 1.5, 1.6, 1.7], sched, max_iter=15,
                      journal_path=jp)
        an = ExperimentAnalysis.from_journal(jp)
        tid = next(t for t in an.trial_ids()
                   if any((d["info"]["inputs"] or {}).get("reason") == "median"
                          and d["info"]["verdict"] == "STOP"
                          for d in an.decisions(t)))
        out = self._explain(capsys, "--journal", jp, "--trial", tid)
        assert "best-so-far" in out and "vs median" in out

    def test_pbt_perturb_answer(self, tmp_path, capsys):
        jp = str(tmp_path / "ev.jsonl")
        sched = PopulationBasedTraining(
            metric="loss", mode="min", perturbation_interval=3,
            hyperparam_mutations={"quality": uniform(0.0, 2.0)},
            quantile_fraction=0.34, seed=0)
        run_qualities([0.0, 1.0, 2.0], sched, max_iter=15, devices=3,
                      journal_path=jp)
        an = ExperimentAnalysis.from_journal(jp)
        tid = next(t for t in an.trial_ids()
                   if any(d["info"]["verdict"] == "RESTART_WITH_CONFIG"
                          for d in an.decisions(t)))
        out = self._explain(capsys, "--journal", jp, "--trial", tid)
        assert "RESTART_WITH_CONFIG by PopulationBasedTraining" in out
        assert "exploit donor" in out

    def test_unknown_trial_and_pre_v3_journal(self, tmp_path, capsys):
        jp = str(tmp_path / "ev.jsonl")
        run_qualities([0.1], FIFOScheduler(metric="loss", mode="min"),
                      max_iter=3, journal_path=jp)
        out = self._explain(capsys, "--journal", jp, "--trial", "nope")
        assert "not in journal" in out

    def test_no_source_errors(self, tmp_path):
        with pytest.raises(SystemExit):
            explain_main([str(tmp_path)])  # empty dir: no events.jsonl

    def test_bundle_source(self, tmp_path, capsys, monkeypatch):
        monkeypatch.setenv("REPRO_FLIGHTREC_DIR", str(tmp_path / "fr"))
        res = run_scenario(crash_storm(n_trials=20, seed=4),
                           lambda: FIFOScheduler(metric="loss", mode="min"),
                           pool_devices=8, token="cli-bundle")
        path = res.flightrec.dump(res.runner, res.executor, reason="manual")
        tid = next(t.trial_id for t in res.trials
                   if t.status == TrialStatus.TERMINATED)
        out = self._explain(capsys, "--bundle", path, "--trial", tid)
        assert "bundle run-cli-bundle: reason=manual" in out
        assert f"trial {tid}: TERMINATED" in out
        assert "reached its bound" in out


# ---------------------------------------------------------------------------
# run_experiments wiring — journal + snapshot + dump-on-abort + explain
# ---------------------------------------------------------------------------

class TestExperimentWiring:
    def test_log_dir_gets_decisions_snapshot_and_explain(self, tmp_path,
                                                         capsys):
        log_dir = str(tmp_path / "exp")
        run_experiments(
            DecayTrainable, None,
            searcher=RandomSearcher({"quality": uniform(0.0, 1.0)},
                                    max_trials=3, seed=0),
            scheduler=FIFOScheduler(metric="loss", mode="min"),
            stop={"training_iteration": 4}, total_devices=2,
            checkpoint_freq=1, log_dir=log_dir, verbose=False)
        an = ExperimentAnalysis.from_journal(
            os.path.join(log_dir, "events.jsonl"))
        assert an.header["schema_version"] == 3
        assert an.header["decisions"] is True
        for tid in an.trial_ids():
            decs = an.decisions(tid)
            assert decs and decs[-1]["info"]["verdict"] == "STOP"
        # searcher+scheduler state checkpoint landed next to the journal
        state = json.load(open(os.path.join(log_dir, "search_state.json")))
        assert state["scheduler"]["type"] == "FIFOScheduler"
        assert state["searcher"]["type"] == "RandomSearcher"
        # searcher SUGGEST decisions journaled with their inputs
        suggests = [d for tid in an.trial_ids() for d in an.decisions(tid)
                    if d["info"]["verdict"] == "SUGGEST"]
        assert len(suggests) == 3
        assert all(d["info"]["inputs"]["strategy"] == "random"
                   for d in suggests)
        # the explain CLI discovers the journal from the log_dir
        assert explain_main([log_dir]) == 0
        out = capsys.readouterr().out
        assert "SUGGEST by RandomSearcher" in out
        assert "reached its bound" in out

    def test_abort_dumps_bundle(self, tmp_path):
        log_dir = str(tmp_path / "boom")

        class AlwaysCrash(Trainable):
            def setup(self, config):
                pass

            def step(self):
                raise RuntimeError("scripted")

        with pytest.raises(RuntimeError, match="max_experiment_failures"):
            run_experiments(
                AlwaysCrash, {"x": uniform(0, 1)}, num_samples=4,
                scheduler=FIFOScheduler(metric="loss", mode="min"),
                stop={"training_iteration": 3}, total_devices=2,
                max_experiment_failures=1, log_dir=log_dir, verbose=False)
        dumps = os.listdir(os.path.join(log_dir, "flightrec"))
        assert len(dumps) == 1 and dumps[0].endswith("-abort.json")
        b = json.load(open(os.path.join(log_dir, "flightrec", dumps[0])))
        assert b["reason"] == "abort" and b["status_counts"].get("ERROR")

    def test_decisions_off_writes_none(self, tmp_path):
        log_dir = str(tmp_path / "off")
        run_experiments(
            DecayTrainable, {"quality": uniform(0.0, 1.0)}, num_samples=2,
            scheduler=FIFOScheduler(metric="loss", mode="min"),
            stop={"training_iteration": 3}, total_devices=2,
            log_dir=log_dir, decisions=False, verbose=False)
        an = ExperimentAnalysis.from_journal(
            os.path.join(log_dir, "events.jsonl"))
        assert an.header["decisions"] is False
        assert all(not an.decisions(tid) for tid in an.trial_ids())


# ---------------------------------------------------------------------------
# SIGTERM acceptance — 100-trial storm, bundle + explain byte-identical
# ---------------------------------------------------------------------------

_SIGTERM_CHILD = textwrap.dedent("""\
    import os, signal, sys, time
    from repro.core import FIFOScheduler
    from repro.testing import crash_storm, run_scenario

    token = sys.argv[1]
    res = run_scenario(crash_storm(n_trials=100, seed=11),
                       lambda: FIFOScheduler(metric="loss", mode="min"),
                       pool_devices=8, token=token)
    armed = res.flightrec.install_signal_handler(res.runner, res.executor)
    assert armed, "main thread must own the SIGTERM handler"
    print("READY", flush=True)
    time.sleep(120)  # parent SIGTERMs long before this expires
""")


class TestSigtermAcceptance:
    def _run_child(self, tmp_path, sub, token):
        out_dir = str(tmp_path / sub)
        env = dict(os.environ, REPRO_FLIGHTREC_DIR=out_dir,
                   PYTHONPATH="src")
        script = str(tmp_path / "child.py")
        with open(script, "w") as f:
            f.write(_SIGTERM_CHILD)
        proc = subprocess.Popen([sys.executable, script, token], env=env,
                                cwd="/root/repo", stdout=subprocess.PIPE,
                                text=True)
        assert proc.stdout.readline().strip() == "READY"
        proc.send_signal(signal.SIGTERM)
        rc = proc.wait(timeout=60)
        assert rc == 143, f"SIGTERM exit must be 143, got {rc}"
        path = os.path.join(out_dir, f"run-{token}-00-sigterm.json")
        assert os.path.exists(path), os.listdir(out_dir)
        return path

    def test_sigterm_bundle_and_explain_byte_identical(self, tmp_path,
                                                       capsys):
        p1 = self._run_child(tmp_path, "one", "sigterm-det")
        p2 = self._run_child(tmp_path, "two", "sigterm-det")
        assert open(p1, "rb").read() == open(p2, "rb").read()
        b = json.load(open(p1))
        assert b["reason"] == "sigterm" and b["run_id"] == "run-sigterm-det"
        assert len(b["trials"]) == 100 and b["decisions"]
        # the explain CLI answers identically from either bundle
        outs = []
        for p in (p1, p2):
            assert explain_main(["--bundle", p]) == 0
            outs.append(capsys.readouterr().out)
        assert outs[0] == outs[1]
        assert "STOP by TrialRunner" in outs[0]


class TestReportProvenanceSection:
    def test_report_has_provenance_table(self, tmp_path):
        from repro.obs.report import build_report
        jp = str(tmp_path / "ev.jsonl")
        run_scenario(crash_storm(n_trials=20, seed=9),
                     lambda: FIFOScheduler(metric="loss", mode="min"),
                     pool_devices=8, token="rep-prov", journal_path=jp)
        html = build_report(journal_path=jp, metric="loss", mode="min")
        assert "Decision provenance" in html
        assert "DECISION records across" in html
        assert "STOP by TrialRunner" in html
