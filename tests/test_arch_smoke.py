"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED variant of the same family and runs one forward/train
step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.data import synthetic_batch
from repro.models import forward_train, init_params, param_count, prefill, decode_step
from repro.train import adamw, make_train_state, make_train_step

ARCHS = list_archs()


def test_registry_complete():
    assert sorted(ARCHS) == sorted([
        "deepseek-moe-16b", "gemma-2b", "granite-moe-3b-a800m",
        "h2o-danube-1.8b", "hubert-xlarge", "paligemma-3b", "qwen1.5-110b",
        "recurrentgemma-9b", "rwkv6-1.6b", "smollm-135m"])


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.kv_heads,
            cfg.d_ff, cfg.vocab_size) == expected
    assert cfg.source  # every config cites its source


def test_moe_configs():
    ds = get_config("deepseek-moe-16b").moe
    assert (ds.n_experts, ds.top_k, ds.n_shared) == (64, 6, 2)
    gr = get_config("granite-moe-3b-a800m").moe
    assert (gr.n_experts, gr.top_k) == (40, 8)


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_smoke_forward_and_train_step(arch):
    """Reduced variant (<=2-ish layers, d_model<=512, <=4 experts): one
    forward + one optimizer step; asserts shapes and finiteness."""
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512 and cfg.n_layers <= max(2, len(cfg.block_pattern or ()))
    if cfg.moe:
        assert cfg.moe.n_experts <= 4

    B, S = 2, 32
    params = init_params(jax.random.key(0), cfg)
    assert param_count(params) > 0
    batch = {k: jnp.asarray(v) for k, v in synthetic_batch(cfg, B, S).items()}
    loss, metrics = forward_train(params, batch, cfg)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert jnp.isfinite(metrics["accuracy"])

    opt = adamw(1e-3)
    state = make_train_state(jax.random.key(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    state, m = step(state, batch)
    assert int(state.step) == 1
    assert jnp.isfinite(m["total_loss"]), f"{arch}: train step NaN"
    assert jnp.isfinite(m["grad_norm"]) and float(m["grad_norm"]) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).supports_decode])
def test_reduced_smoke_decode(arch):
    """Prefill + one decode step for every decode-capable arch."""
    cfg = get_config(arch).reduced()
    B, S = 2, 16
    params = init_params(jax.random.key(0), cfg)
    if cfg.frontend == "vision_stub":
        batch = {"patch_embeds": jnp.zeros((B, cfg.n_prefix_embeds, cfg.frontend_dim)),
                 "tokens": jnp.ones((B, S), jnp.int32)}
    else:
        batch = {"tokens": jnp.ones((B, S), jnp.int32)}
    logits, caches = prefill(params, batch, cfg, max_len=S + 4)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    total = S + cfg.n_prefix_embeds if cfg.frontend == "vision_stub" else S
    logits2, caches = decode_step(params, caches, tok, jnp.asarray(total), cfg)
    assert logits2.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits2).all(), f"{arch}: decode NaN"


def test_encoder_only_has_no_decode():
    assert not get_config("hubert-xlarge").supports_decode


def test_long_context_support_flags():
    assert get_config("rwkv6-1.6b").supports_long_context
    assert get_config("recurrentgemma-9b").supports_long_context
    assert get_config("h2o-danube-1.8b").supports_long_context
    assert not get_config("gemma-2b").supports_long_context
    assert not get_config("qwen1.5-110b").supports_long_context
