"""Fault tolerance: experiment interruption + resume from durable checkpoints."""
import os

import numpy as np
import pytest

from repro.core import (FIFOScheduler, Trainable, TrialStatus, run_experiments)
from repro.core.experiment import load_experiment_state


class Slow(Trainable):
    steps_executed = 0  # class-wide step counter (reset per assertion site)

    def setup(self, config):
        self.x = 1.0
        self.lr = config["lr"]

    def step(self):
        Slow.steps_executed += 1
        self.x *= 0.9
        return {"loss": self.x + self.lr}

    def save(self):
        return {"x": self.x}

    def restore(self, s):
        self.x = s["x"]


def test_interrupt_and_resume(tmp_path):
    log_dir = str(tmp_path / "exp")

    # run interrupted after a few events (max_steps caps the event loop)
    run_experiments(Slow, {"lr": 0.1}, num_samples=3,
                    scheduler=FIFOScheduler(metric="loss", mode="min"),
                    stop={"training_iteration": 10}, total_devices=1,
                    checkpoint_freq=1, log_dir=log_dir, max_steps=12)
    trials = load_experiment_state(log_dir)
    assert trials, "state snapshot missing"
    unfinished = [t for t in trials if not t.status.is_finished()]
    finished = [t for t in trials if t.status.is_finished()]
    assert finished, "interruption should land after >=1 completed trial"

    # resume: all trials run to completion, finished ones keep their history
    an = run_experiments(Slow, None, resume=True,
                         scheduler=FIFOScheduler(metric="loss", mode="min"),
                         stop={"training_iteration": 10}, total_devices=1,
                         checkpoint_freq=1, log_dir=log_dir)
    assert len(an.trials) == 3
    assert all(t.status == TrialStatus.TERMINATED for t in an.trials)
    assert all(t.training_iteration == 10 for t in an.trials)


def test_resume_restores_from_disk_checkpoint(tmp_path):
    log_dir = str(tmp_path / "exp2")
    run_experiments(Slow, {"lr": 0.0}, num_samples=2,
                    scheduler=FIFOScheduler(metric="loss", mode="min"),
                    stop={"training_iteration": 8}, total_devices=2,
                    checkpoint_freq=2, log_dir=log_dir, max_steps=7)
    trials = load_experiment_state(log_dir)
    paused = [t for t in trials if t.status == TrialStatus.PAUSED]
    assert paused, "interruption must leave mid-flight trials PAUSED"
    for t in paused:
        assert t.checkpoint is not None, f"{t.trial_id} paused w/o checkpoint"
        assert t.checkpoint.path and os.path.exists(t.checkpoint.path), \
            f"{t.trial_id} checkpoint mirror missing from disk"
    # sum of journal-backed restore points: each trial resumes from its
    # newest mirror at-or-below the journal frontier, re-running only the
    # iterations above it
    expected_steps = sum(8 - t.checkpoint.training_iteration for t in paused)

    Slow.steps_executed = 0
    an = run_experiments(Slow, None, resume=True,
                         scheduler=FIFOScheduler(metric="loss", mode="min"),
                         stop={"training_iteration": 8}, total_devices=2,
                         checkpoint_freq=2, log_dir=log_dir)
    assert all(t.status == TrialStatus.TERMINATED for t in an.trials)
    # continuation, not re-execution: the resumed run does exactly the steps
    # above each trial's restored checkpoint — never the full 16 from scratch
    assert Slow.steps_executed == expected_steps, (
        f"resume ran {Slow.steps_executed} steps, wanted {expected_steps} "
        "(from-checkpoint continuation)")
    # loss continuity: final loss equals an uninterrupted 8-step run's
    for t in an.trials:
        np.testing.assert_allclose(t.last_result.value("loss"), 0.9 ** 8,
                                   rtol=1e-6)


def test_resume_requires_log_dir():
    with pytest.raises(ValueError):
        run_experiments(Slow, {"lr": 0.1}, resume=True,
                        stop={"training_iteration": 2})


def test_fresh_dir_resume_is_empty(tmp_path):
    assert load_experiment_state(str(tmp_path)) == []
