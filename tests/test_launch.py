"""Launch-layer coverage: shape specs, applicability matrix, input structs,
active-param accounting, mesh constants."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.launch.mesh import HW
from repro.launch.shapes import (SHAPES, applicable, dryrun_config, input_specs,
                                 skip_reason)


class TestShapes:
    def test_assigned_shapes_exact(self):
        assert (SHAPES["train_4k"].seq_len, SHAPES["train_4k"].global_batch) == (4096, 256)
        assert (SHAPES["prefill_32k"].seq_len, SHAPES["prefill_32k"].global_batch) == (32768, 32)
        assert (SHAPES["decode_32k"].seq_len, SHAPES["decode_32k"].global_batch) == (32768, 128)
        assert (SHAPES["long_500k"].seq_len, SHAPES["long_500k"].global_batch) == (524288, 1)

    def test_applicability_matrix(self):
        """10x4 = 40 pairs: 32 applicable + 8 documented skips."""
        n_app = n_skip = 0
        for arch in list_archs():
            cfg = get_config(arch)
            for shape in SHAPES.values():
                if applicable(cfg, shape):
                    n_app += 1
                else:
                    n_skip += 1
                    assert skip_reason(cfg, shape)
        assert (n_app, n_skip) == (32, 8)

    def test_encoder_skips_decode(self):
        cfg = get_config("hubert-xlarge")
        assert not applicable(cfg, SHAPES["decode_32k"])
        assert not applicable(cfg, SHAPES["long_500k"])
        assert applicable(cfg, SHAPES["prefill_32k"])

    def test_long_context_only_subquadratic(self):
        runs = {a for a in list_archs()
                if applicable(get_config(a), SHAPES["long_500k"])}
        assert runs == {"rwkv6-1.6b", "recurrentgemma-9b", "h2o-danube-1.8b"}


class TestInputSpecs:
    def test_train_structs_lm(self):
        cfg = dryrun_config(get_config("smollm-135m"))
        specs = input_specs(cfg, SHAPES["train_4k"])
        assert specs["batch"]["tokens"].shape == (256, 4096)
        assert specs["batch"]["labels"].dtype == jnp.int32

    def test_train_structs_vlm(self):
        cfg = dryrun_config(get_config("paligemma-3b"))
        specs = input_specs(cfg, SHAPES["train_4k"])
        assert specs["batch"]["patch_embeds"].shape == (256, 256, 1152)
        assert specs["batch"]["tokens"].shape == (256, 4096 - 256)

    def test_train_structs_audio(self):
        cfg = dryrun_config(get_config("hubert-xlarge"))
        specs = input_specs(cfg, SHAPES["train_4k"])
        assert specs["batch"]["features"].shape == (256, 4096, 512)

    def test_decode_structs_have_caches(self):
        cfg = dryrun_config(get_config("gemma-2b"))
        specs = input_specs(cfg, SHAPES["decode_32k"])
        assert specs["tokens"].shape == (128,)
        assert specs["pos"].shape == ()
        leaves = jax.tree_util.tree_leaves(specs["caches"])
        assert leaves and all(hasattr(l, "shape") for l in leaves)

    def test_window_cache_capped(self):
        """SWA caches are O(window), not O(seq): the long_500k enabler."""
        cfg = dryrun_config(get_config("h2o-danube-1.8b"))
        specs = input_specs(cfg, SHAPES["long_500k"])
        k_shapes = [l.shape for p, l in
                    jax.tree_util.tree_leaves_with_path(specs["caches"])
                    if getattr(p[-1], "key", None) == "k"]
        assert k_shapes and all(s[2] == cfg.sliding_window for s in k_shapes)

    def test_rwkv_state_o1(self):
        cfg = dryrun_config(get_config("rwkv6-1.6b"))
        specs = input_specs(cfg, SHAPES["long_500k"])
        total = sum(l.size for l in jax.tree_util.tree_leaves(specs["caches"]))
        # O(1) in seq: state bytes independent of the 524288 context
        assert total < 50e6

    def test_dryrun_config_is_bf16_remat(self):
        cfg = dryrun_config(get_config("smollm-135m"))
        assert cfg.param_dtype == "bfloat16" and cfg.remat


class TestActiveParams:
    def test_dense_equals_total(self):
        from repro.launch.dryrun import active_param_count
        from repro.models import init_params, param_count
        cfg = get_config("smollm-135m").reduced()
        assert active_param_count(cfg) == param_count(
            init_params(jax.random.key(0), cfg))

    def test_moe_counts_topk_fraction(self):
        import dataclasses
        from repro.launch.dryrun import active_param_count
        from repro.models import init_params, param_count
        base = get_config("deepseek-moe-16b").reduced()
        # reduced() clamps to 4 experts top-4 (frac 1): widen to top-1 of 4
        cfg = dataclasses.replace(base, moe=dataclasses.replace(base.moe, top_k=1))
        total = param_count(init_params(jax.random.key(0), cfg))
        active = active_param_count(cfg)
        assert active < total
        frac = cfg.moe.top_k / cfg.moe.n_experts
        assert total * frac <= active  # non-expert params keep it above frac


class TestHW:
    def test_v5e_constants(self):
        assert HW.PEAK_FLOPS_BF16 == 197e12
        assert HW.HBM_BW == 819e9
        assert HW.ICI_BW == 50e9
        assert HW.CHIPS_PER_POD == 256
