"""Elastic resource control plane (DESIGN.md §6): SlicePool resize matrix,
ResizePolicy behaviour, per-tier checkpoint-boundary resize with rollback,
and the k=1 credit-equivalence matrix — an elastic run with a sequential pool
must reproduce the serial executor's scheduler decisions exactly on
FIFO/ASHA/HyperBand/PBT."""
import os
from types import SimpleNamespace

import numpy as np
import pytest

from _hypothesis_stub import HAVE_HYPOTHESIS, given, settings, st
from repro.core import (ASHAScheduler, CheckpointManager, EventType,
                        FIFOScheduler, FairShare, GreedyFill,
                        HyperBandScheduler, Logger, MedianStoppingRule,
                        ObjectStore, PopulationBasedTraining,
                        ProcessMeshExecutor, Resources, ResourceBroker,
                        SerialMeshExecutor, TrainableFactory, Trial,
                        TrialRunner, TrialStatus, grid_search,
                        register_worker_factory, run_experiments)
from repro.dist.submesh import SlicePool

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))


def factory(name: str) -> TrainableFactory:
    return TrainableFactory(target=f"_worker_trainables:{name}",
                            sys_path=(TESTS_DIR,))


class Recorder(Logger):
    def __init__(self):
        self.events = []

    def on_event(self, trial, event):
        self.events.append(event)

    def of(self, kind):
        return [e for e in self.events if e.type == kind]


# ---------------------------------------------------------------------------------
# SlicePool resize matrix
# ---------------------------------------------------------------------------------

class TestSlicePoolResize:
    def test_grow_in_place_into_adjacent_free(self):
        pool = SlicePool(n_virtual=16)
        a = pool.acquire(4)
        grown = pool.resize(a, 8)
        assert (grown.start, grown.size) == (0, 8)
        assert pool.n_free == 8 and pool.n_resized_total == 1

    def test_grow_relocates_when_not_adjacent(self):
        pool = SlicePool(n_virtual=16)
        a = pool.acquire(4)
        b = pool.acquire(4)  # blocks a's in-place growth
        grown = pool.resize(a, 8)
        assert grown.start == 8 and grown.size == 8  # moved past b
        assert pool.n_free == 4
        pool.release(b)
        pool.release(grown)
        assert pool.n_free == 16 and pool.fragments() == 0

    def test_grow_impossible_is_atomic(self):
        pool = SlicePool(n_virtual=8)
        a = pool.acquire(4)
        b = pool.acquire(2)
        with pytest.raises(RuntimeError):
            pool.resize(a, 7)
        # failure left everything exactly as it was
        assert pool.n_free == 2
        pool.release(a)
        pool.release(b)
        assert pool.n_free == 8 and pool.fragments() == 0

    def test_shrink_trims_tail_and_coalesces(self):
        pool = SlicePool(n_virtual=16)
        a = pool.acquire(8)
        b = pool.acquire(8)
        small = pool.resize(a, 2)
        assert (small.start, small.size) == (0, 2)
        assert pool.n_free == 6 and pool.fragments() == 0  # [2, 8) one range
        c = pool.acquire(6)
        assert c.start == 2  # the trimmed tail is immediately reusable
        for s in (small, b, c):
            pool.release(s)
        assert pool.n_free == 16 and pool.fragments() == 0

    def test_try_grow_requires_adjacency(self):
        pool = SlicePool(n_virtual=12)
        a = pool.acquire(4)
        b = pool.acquire(4)
        assert pool.try_grow(a, 8) is None       # b sits in the way
        grown = pool.try_grow(b, 8)              # tail [8, 12) is adjacent
        assert grown is not None and (grown.start, grown.size) == (4, 8)
        with pytest.raises(ValueError):
            pool.try_grow(a, 4)                  # not a growth

    def test_acquire_at_exact_range(self):
        pool = SlicePool(n_virtual=8)
        a = pool.acquire(2)
        s = pool.acquire_at(4, 2)                # mid-range carve
        assert (s.start, s.size) == (4, 2)
        assert pool.fragments() == 1             # holes: [2,4) vs [6,8)
        with pytest.raises(RuntimeError):
            pool.acquire_at(4, 2)                # already held
        pool.release(s)
        pool.release(a)
        assert pool.fragments() == 0

    def test_stats_surface(self):
        pool = SlicePool(n_virtual=16)
        assert pool.utilization() == 0.0
        assert pool.largest_free_block() == 16 and pool.fragments() == 0
        a = pool.acquire(4)
        b = pool.acquire(4)
        pool.acquire(8)
        assert pool.utilization() == 1.0 and pool.largest_free_block() == 0
        pool.release(a)
        assert pool.largest_free_block() == 4 and pool.fragments() == 0
        assert pool.can_resize(b, 2) and pool.can_resize(b, 8)
        assert not pool.can_resize(b, 12)

    # -- acquire/release/resize walk: property-based (hypothesis), with a
    # seeded fallback so the invariant keeps running where hypothesis is
    # absent (tests/_hypothesis_stub.py skips the @given test there).

    @staticmethod
    def _run_walk(pool_size, ops):
        """Drive an op script against a pool, asserting the free-list
        invariants after every op: capacity conserved, held/free disjoint,
        largest block bounded, full coalesce on drain.  ``ops`` is a list of
        (kind, index, size): kind 0 releases held[index], 1 resizes
        held[index] to ``size``, 2 acquires ``size``."""
        pool = SlicePool(n_virtual=pool_size)
        held = []
        for kind, index, size in ops:
            if kind == 0 and held:
                held.remove(sl := held[index % len(held)])
                pool.release(sl)
            elif kind == 1 and held:
                sl = held[index % len(held)]
                if size != sl.size and (size < sl.size
                                        or pool.can_resize(sl, size)):
                    held.remove(sl)
                    held.append(pool.resize(sl, size))
            elif kind == 2:
                if pool.can_fit(size):
                    held.append(pool.acquire(size))
            assert pool.n_free == pool_size - sum(h.size for h in held)
            assert pool.largest_free_block() <= pool.n_free
            for h in held:
                for start, fsize in pool._free:
                    assert h.start + h.size <= start or start + fsize <= h.start
        for h in held:
            pool.release(h)
        assert pool.n_free == pool_size and pool.fragments() == 0

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
    @settings(max_examples=200, deadline=None)
    @given(ops=st.lists(
        st.tuples(st.integers(min_value=0, max_value=2),
                  st.integers(min_value=0, max_value=63),
                  st.integers(min_value=1, max_value=13)),
        max_size=300))
    def test_random_walk_with_resize_conserves_capacity(self, ops):
        """Property form of the old 5-seed walk: hypothesis explores (and
        shrinks) op interleavings instead of five fixed RNG streams."""
        self._run_walk(64, ops)

    @pytest.mark.parametrize("seed", range(3))
    def test_random_walk_seeded_fallback(self, seed):
        """No-hypothesis fallback: the same invariant walk on fixed seeds, so
        the coalescing regression matrix never goes dark."""
        rng = np.random.default_rng(seed)
        ops = [(int(rng.integers(0, 3)), int(rng.integers(0, 64)),
                int(rng.integers(1, 14))) for _ in range(300)]
        self._run_walk(64, ops)


# ---------------------------------------------------------------------------------
# Policies and broker clamping
# ---------------------------------------------------------------------------------

def _fake_runner(scheduler, trials=()):
    return SimpleNamespace(scheduler=scheduler, trials=list(trials))


class TestPolicies:
    def test_greedy_fill_doubles_after_grace(self):
        pool = SlicePool(n_virtual=16)
        sl = pool.acquire(2)
        runner = _fake_runner(ASHAScheduler(max_t=8, grace_period=3))
        young = SimpleNamespace(results=[], trial_id="t",
                                training_iteration=1)
        survivor = SimpleNamespace(results=[], trial_id="t",
                                   training_iteration=3)
        policy = GreedyFill()
        assert policy.propose(runner, young, pool, sl) is None  # pre-grace
        assert policy.propose(runner, survivor, pool, sl) == 4  # one doubling

    def test_greedy_fill_respects_cap_and_feasibility(self):
        pool = SlicePool(n_virtual=8)
        sl = pool.acquire(4)
        other = pool.acquire(4)
        runner = _fake_runner(FIFOScheduler())
        trial = SimpleNamespace(training_iteration=5)
        assert GreedyFill().propose(runner, trial, pool, sl) is None  # full pool
        pool.release(other)
        assert GreedyFill().propose(runner, trial, pool, sl) == 8
        assert GreedyFill(max_devices=4).propose(runner, trial, pool, sl) is None

    def test_fair_share_rebalances(self):
        pool = SlicePool(n_virtual=16)
        big = pool.acquire(12)
        small = pool.acquire(2)
        running = [SimpleNamespace(status=TrialStatus.RUNNING) for _ in range(2)]
        runner = _fake_runner(FIFOScheduler(), running)
        policy = FairShare()
        assert policy.propose(runner, running[0], pool, big) == 8    # shrink
        assert policy.propose(runner, running[1], pool, small) is None  # 2 free
        pool.resize(big, 8)
        assert policy.propose(runner, running[1], pool, small) == 8  # now grow

    def test_fair_share_counts_waiting_trials(self):
        pool = SlicePool(n_virtual=16)
        big = pool.acquire(16)
        trials = [SimpleNamespace(status=TrialStatus.RUNNING),
                  SimpleNamespace(status=TrialStatus.PENDING),
                  SimpleNamespace(status=TrialStatus.PAUSED),
                  SimpleNamespace(status=TrialStatus.TERMINATED)]
        runner = _fake_runner(FIFOScheduler(), trials)
        # 1 running + 2 waiting -> fair share 16 // 3 = 5 -> pow2 4
        assert FairShare().propose(runner, trials[0], pool, big) == 4


class TestDecisionIntervals:
    def test_declared_granularities(self):
        assert FIFOScheduler().decision_interval() == 0
        assert ASHAScheduler(max_t=8).decision_interval() == 1
        assert HyperBandScheduler(max_t=8).decision_interval() == 1
        assert MedianStoppingRule().decision_interval() == 1
        assert PopulationBasedTraining(
            perturbation_interval=5).decision_interval() == 5

    @pytest.mark.parametrize("scheduler,expected", [
        (FIFOScheduler(metric="loss", mode="min"), 4),
        (ASHAScheduler(metric="loss", mode="min", max_t=8), 1),
        (PopulationBasedTraining(metric="loss", mode="min",
                                 perturbation_interval=3), 1),
    ])
    def test_broker_clamps_lookahead(self, scheduler, expected):
        """Exactness rule: full lookahead only for run-to-completion
        schedulers; anything that can stop/perturb is clamped to 1."""
        ex = SerialMeshExecutor(lambda n: None, CheckpointManager(ObjectStore()))
        broker = ResourceBroker(lookahead=4)
        TrialRunner(scheduler, ex, broker=broker)
        assert broker.effective_lookahead == expected
        assert ex.lookahead == expected


# ---------------------------------------------------------------------------------
# Per-tier resize: grow path, state continuity, rollback fallback
# ---------------------------------------------------------------------------------

@pytest.mark.timeout(300)
class TestInHostElastic:
    @pytest.mark.parametrize("executor", ["serial", "concurrent"])
    def test_greedy_grow_preserves_state(self, executor):
        from _worker_trainables import SliceCounter

        an = run_experiments(
            SliceCounter, {"x": 1},
            scheduler=FIFOScheduler(metric="loss", mode="min"),
            stop={"training_iteration": 6},
            total_devices=8,
            slice_pool=SlicePool(n_virtual=8),
            resources_per_trial=Resources(devices=2),
            executor=executor, elastic="greedy", checkpoint_freq=0,
        )
        t = an.trials[0]
        assert t.status == TrialStatus.TERMINATED
        # contiguous results and counter state across every SAVE/RESTORE hop
        assert [r.training_iteration for r in t.results] == [1, 2, 3, 4, 5, 6]
        assert [r.metrics["n"] for r in t.results] == [1, 2, 3, 4, 5, 6]
        devs = [r.metrics["devices"] for r in t.results]
        assert devs[0] == 2 and devs[-1] == 8 and devs == sorted(devs), devs

    @pytest.mark.parametrize("executor", ["serial", "concurrent"])
    def test_failed_rebuild_falls_back_to_old_slice(self, executor):
        from _worker_trainables import GrowAllergic

        rec = Recorder()
        pool = SlicePool(n_virtual=8)
        if executor == "serial":
            ex = SerialMeshExecutor(lambda n: GrowAllergic,
                                    CheckpointManager(ObjectStore()),
                                    total_devices=8, slice_pool=pool)
        else:
            from repro.core import ConcurrentMeshExecutor
            ex = ConcurrentMeshExecutor(lambda n: GrowAllergic,
                                        CheckpointManager(ObjectStore()),
                                        total_devices=8, slice_pool=pool)
        broker = ResourceBroker(policy=GreedyFill())
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                             logger=rec,
                             stopping_criteria={"training_iteration": 5},
                             broker=broker)
        trial = Trial({"max_ok": 2}, resources=Resources(devices=2),
                      stopping_criteria={"training_iteration": 5})
        runner.add_trial(trial)
        runner.run()
        assert trial.status == TrialStatus.TERMINATED, trial.error
        assert [r.training_iteration for r in trial.results] == [1, 2, 3, 4, 5]
        # every grow attempt was rolled back; the trial never left 2 devices
        assert all(r.metrics["devices"] == 2 for r in trial.results)
        assert rec.of(EventType.RESIZE_FAILED) and broker.n_resize_failed > 0
        assert broker.n_resized == 0
        assert trial.resources.devices == 2
        assert pool.n_free == 8 and pool.fragments() == 0

    def test_fair_share_shrinks_to_admit_waiting_trial(self):
        """A big runner is trimmed at its boundary so a queued trial can
        launch — rebalance across RUNNING trials, not just greedy growth."""
        from _worker_trainables import SliceCounter

        rec = Recorder()
        pool = SlicePool(n_virtual=8)
        ex = SerialMeshExecutor(lambda n: SliceCounter,
                                CheckpointManager(ObjectStore()),
                                total_devices=8, slice_pool=pool)
        broker = ResourceBroker(policy=FairShare())
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                             logger=rec,
                             stopping_criteria={"training_iteration": 6},
                             broker=broker)
        hog = Trial({}, resources=Resources(devices=8),
                    stopping_criteria={"training_iteration": 6})
        waiter = Trial({}, resources=Resources(devices=4),
                       stopping_criteria={"training_iteration": 6})
        runner.add_trial(hog)
        runner.add_trial(waiter)
        runner.run()
        assert hog.status == waiter.status == TrialStatus.TERMINATED
        assert broker.n_resized >= 1 and rec.of(EventType.RESIZED)
        assert waiter.results  # it actually ran
        # the hog was shrunk from 8 down to a fair share at some boundary
        hog_devs = [r.metrics["devices"] for r in hog.results]
        assert hog_devs[0] == 8 and min(hog_devs) <= 4, hog_devs
        assert pool.n_free == 8


@pytest.mark.timeout(600)
class TestProcessElastic:
    def test_in_place_resize_same_process(self):
        """RESIZE over the pipe rebuilds the trainable inside the warm child:
        same pid before/after, counter state carried over the spill surface,
        slice doubled by the broker."""
        pool = SlicePool(n_virtual=8)
        ex = ProcessMeshExecutor(
            factory_resolver=lambda n: factory("SliceCounter"),
            checkpoint_manager=CheckpointManager(ObjectStore()),
            total_devices=8, slice_pool=pool, checkpoint_freq=1)
        broker = ResourceBroker(policy=GreedyFill())
        rec = Recorder()
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                             logger=rec,
                             stopping_criteria={"training_iteration": 6},
                             broker=broker)
        trial = Trial({}, resources=Resources(devices=2),
                      stopping_criteria={"training_iteration": 6})
        runner.add_trial(trial)
        pids = set()
        while runner.step():
            pid = ex.worker_pid(trial.trial_id)
            if pid:
                pids.add(pid)
        assert trial.status == TrialStatus.TERMINATED, trial.error
        assert len(pids) == 1, f"resize must not respawn the process: {pids}"
        assert [r.metrics["n"] for r in trial.results] == [1, 2, 3, 4, 5, 6]
        devs = [r.metrics["devices"] for r in trial.results]
        assert devs[0] == 2 and devs[-1] == 8, devs
        assert broker.n_resized >= 2 and len(rec.of(EventType.RESIZED)) >= 2
        assert trial.resources.devices == 8
        assert pool.n_free == 8

    def test_child_rebuild_failure_falls_back(self):
        """A child-side RESIZE failure is non-fatal: the old trainable keeps
        serving in the same process and the pool swap is rolled back."""
        pool = SlicePool(n_virtual=8)
        ex = ProcessMeshExecutor(
            factory_resolver=lambda n: factory("GrowAllergic"),
            checkpoint_manager=CheckpointManager(ObjectStore()),
            total_devices=8, slice_pool=pool, checkpoint_freq=0)
        broker = ResourceBroker(policy=GreedyFill())
        rec = Recorder()
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                             logger=rec,
                             stopping_criteria={"training_iteration": 5},
                             broker=broker)
        trial = Trial({"max_ok": 2}, resources=Resources(devices=2),
                      stopping_criteria={"training_iteration": 5})
        runner.add_trial(trial)
        runner.run()
        assert trial.status == TrialStatus.TERMINATED, trial.error
        assert all(r.metrics["devices"] == 2 for r in trial.results)
        assert broker.n_resize_failed > 0 and rec.of(EventType.RESIZE_FAILED)
        assert trial.resources.devices == 2 and pool.n_free == 8

    def test_lookahead_credits_fifo_stream_exact(self):
        """k=4 on FIFO: the worker pipelines STEPs, yet per-trial results are
        exactly the serial stream (extra in-flight results past the stop
        boundary are fenced as stale), and the CREDITS grant is logged."""
        register_worker_factory("SliceCounter", factory("SliceCounter"))
        rec_events = []

        class _Rec(Logger):
            def on_event(self, trial, event):
                rec_events.append(event)

        ex = ProcessMeshExecutor(
            factory_resolver=lambda n: factory("SliceCounter"),
            checkpoint_manager=CheckpointManager(ObjectStore()),
            total_devices=4, checkpoint_freq=0)
        broker = ResourceBroker(lookahead=4)
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                             logger=_Rec(),
                             stopping_criteria={"training_iteration": 8},
                             broker=broker)
        trials = [Trial({}, resources=Resources(devices=1),
                        stopping_criteria={"training_iteration": 8})
                  for _ in range(3)]
        for t in trials:
            runner.add_trial(t)
        runner.run()
        assert broker.effective_lookahead == 4
        for t in trials:
            assert t.status == TrialStatus.TERMINATED, t.error
            assert [r.training_iteration for r in t.results] == list(range(1, 9))
        credits = [e for e in rec_events if e.type == EventType.CREDITS]
        assert credits and credits[0].info["granted"] == 4

    @pytest.mark.parametrize("executor", ["concurrent", "process"])
    def test_resize_under_lookahead_backlog_keeps_window(self, executor):
        """Resize while k=4 un-consumed results sit in the bus: the credit
        window must self-maintain (no inflation past k, no collapse) and the
        per-trial stream must stay exact through the resize."""
        register_worker_factory("SliceCounter", factory("SliceCounter"))
        from _worker_trainables import SliceCounter

        an = run_experiments(
            SliceCounter, {"x": 1},
            scheduler=FIFOScheduler(metric="loss", mode="min"),
            stop={"training_iteration": 10},
            total_devices=8,
            slice_pool=SlicePool(n_virtual=8),
            resources_per_trial=Resources(devices=2),
            executor=executor, elastic="greedy", lookahead=4,
            checkpoint_freq=1,
        )
        t = an.trials[0]
        assert t.status == TrialStatus.TERMINATED, t.error
        assert [r.training_iteration for r in t.results] == list(range(1, 11))
        assert [r.metrics["n"] for r in t.results] == list(range(1, 11))
        devs = [r.metrics["devices"] for r in t.results]
        assert devs[0] == 2 and devs[-1] == 8 and devs == sorted(devs), devs


# ---------------------------------------------------------------------------------
# k=1 credit equivalence: elastic process tier == serial tier, whole matrix
# ---------------------------------------------------------------------------------

SCHEDULERS = {
    "fifo": lambda: FIFOScheduler(metric="loss", mode="min"),
    "asha": lambda: ASHAScheduler(metric="loss", mode="min", max_t=6,
                                  grace_period=2, reduction_factor=2),
    "hyperband": lambda: HyperBandScheduler(metric="loss", mode="min",
                                            max_t=4, eta=2),
    "pbt": lambda: PopulationBasedTraining(
        metric="loss", mode="min", perturbation_interval=2,
        hyperparam_mutations={"lr": [0.005, 0.02, 0.08]}, seed=0),
}


@pytest.mark.timeout(600)
class TestCreditEquivalenceMatrix:
    """With a capacity-1 pool every tier executes trials sequentially, so the
    event stream — and therefore every scheduler decision — is deterministic.
    An elastic run (broker on, lookahead requested 4, clamped to 1 for every
    scheduler that can stop/perturb) must reproduce the serial executor's
    trial statuses and result streams exactly."""

    @pytest.mark.parametrize("name", list(SCHEDULERS))
    def test_elastic_k1_matches_serial(self, name):
        from _worker_trainables import LrCounter

        def sweep(executor, elastic):
            register_worker_factory("LrCounter", factory("LrCounter"))
            return run_experiments(
                LrCounter,
                {"lr": grid_search([0.005, 0.02, 0.08])},
                scheduler=SCHEDULERS[name](),
                stop={"training_iteration": 6},
                total_devices=1,
                slice_pool=SlicePool(n_virtual=1),
                resources_per_trial=Resources(devices=1),
                checkpoint_freq=1,
                executor=executor,
                elastic="greedy" if elastic else None,
                lookahead=4 if elastic else 1,
                seed=0,
            )

        serial = sweep("serial", elastic=False)
        elastic = sweep("process", elastic=True)
        assert elastic.best_value() == pytest.approx(serial.best_value())
        # Same grid order both runs; PBT mutates configs, so pair by position.
        assert len(elastic.trials) == len(serial.trials)
        for t, ref in zip(elastic.trials, serial.trials):
            assert t.config["lr"] == pytest.approx(ref.config["lr"]), name
            assert t.status == ref.status, (name, t.trial_id, t.error)
            assert ([r.training_iteration for r in t.results]
                    == [r.training_iteration for r in ref.results]), name
            for mine, theirs in zip(t.results, ref.results):
                assert mine.metrics["loss"] == pytest.approx(
                    theirs.metrics["loss"]), name
