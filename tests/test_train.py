"""Optimizers, schedules, train step, data pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.data import DataConfig, SyntheticLMDataset
from repro.models import ModelConfig
from repro.train import (TrainState, adamw, clip_by_global_norm,
                         cosine_schedule, global_norm, linear_warmup_cosine,
                         make_train_state, make_train_step, sgd)


class TestOptimizers:
    def test_adamw_matches_reference_step(self):
        """One AdamW step against the textbook update."""
        p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
        g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
        lr, b1, b2, eps, wd = 0.01, 0.9, 0.999, 1e-8, 0.1
        opt = adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=wd, grad_clip=None)
        st_ = opt.init(p)
        new_p, st_ = opt.update(g, st_, p)
        m = (1 - b1) * g["w"]
        v = (1 - b2) * g["w"] ** 2
        mhat, vhat = m / (1 - b1), v / (1 - b2)
        expect = p["w"] - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p["w"])
        np.testing.assert_allclose(new_p["w"], expect, rtol=1e-6)

    def test_sgd_momentum_matches_reference(self):
        p = {"w": jnp.asarray([1.0])}
        g = {"w": jnp.asarray([0.5])}
        opt = sgd(0.1, momentum=0.9)
        st_ = opt.init(p)
        p1, st_ = opt.update(g, st_, p)
        np.testing.assert_allclose(p1["w"], 1.0 - 0.1 * 0.5, rtol=1e-6)
        p2, st_ = opt.update(g, st_, p1)
        mom = 0.9 * 0.5 + 0.5
        np.testing.assert_allclose(p2["w"], p1["w"] - 0.1 * mom, rtol=1e-6)

    def test_grad_clip(self):
        tree = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
        clipped, norm = clip_by_global_norm(tree, 1.0)
        np.testing.assert_allclose(norm, 5.0, rtol=1e-6)
        np.testing.assert_allclose(global_norm(clipped), 1.0, rtol=1e-5)

    def test_quadratic_convergence(self):
        """AdamW drives a quadratic to its minimum."""
        opt = adamw(0.1, weight_decay=0.0, grad_clip=None)
        p = {"x": jnp.asarray(5.0)}
        st_ = opt.init(p)
        for _ in range(200):
            g = jax.grad(lambda q: (q["x"] - 2.0) ** 2)(p)
            p, st_ = opt.update(g, st_, p)
        assert abs(float(p["x"]) - 2.0) < 0.05


class TestSchedules:
    def test_warmup_then_decay(self):
        s = linear_warmup_cosine(1.0, warmup=10, total_steps=110)
        assert float(s(jnp.asarray(0))) == 0.0
        assert float(s(jnp.asarray(5))) == pytest.approx(0.5)
        assert float(s(jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
        assert float(s(jnp.asarray(110))) == pytest.approx(0.1, abs=0.01)

    @given(st.integers(0, 1000))
    @settings(max_examples=30, deadline=None)
    def test_cosine_bounded(self, step):
        s = cosine_schedule(1.0, 500, final_frac=0.1)
        v = float(s(jnp.asarray(step)))
        assert 0.0999 <= v <= 1.0001


class TestTrainStep:
    CFG = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=64,
                      n_heads=2, n_kv_heads=2, d_ff=128, vocab_size=64).validate()

    def _batch(self, i=0):
        data = SyntheticLMDataset(DataConfig(global_batch=8, seq_len=32,
                                             vocab_size=64, noise=0.05))
        return {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}

    def test_loss_decreases(self):
        opt = adamw(3e-3)
        state = make_train_state(jax.random.key(0), self.CFG, opt)
        step = jax.jit(make_train_step(self.CFG, opt))
        losses = []
        for i in range(30):
            state, m = step(state, self._batch(i))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] * 0.8

    def test_microbatch_equals_full_batch(self):
        opt = adamw(1e-3)
        b = self._batch()
        s0 = make_train_state(jax.random.key(0), self.CFG, opt)
        full = jax.jit(make_train_step(self.CFG, opt))
        micro = jax.jit(make_train_step(self.CFG, opt, microbatch=4))
        s1, m1 = full(s0, b)
        s2, m2 = micro(make_train_state(jax.random.key(0), self.CFG, opt), b)
        np.testing.assert_allclose(float(m1["total_loss"]),
                                   float(m2["total_loss"]), rtol=1e-5)
        # params should closely agree (grad averaging is exact up to fp assoc.)
        d = jax.tree_util.tree_map(lambda a, b_: float(jnp.abs(a - b_).max()),
                                   s1.params, s2.params)
        assert max(jax.tree_util.tree_leaves(d)) < 1e-5

    def test_step_counter_and_remat(self):
        import dataclasses
        cfg = dataclasses.replace(self.CFG, remat=True)
        opt = adamw(1e-3)
        state = make_train_state(jax.random.key(0), cfg, opt)
        step = jax.jit(make_train_step(cfg, opt))
        state, m = step(state, self._batch())
        assert int(state.step) == 1 and jnp.isfinite(m["total_loss"])


class TestDataPipeline:
    def test_deterministic_across_restarts(self):
        cfg = DataConfig(global_batch=4, seq_len=16, vocab_size=100, seed=7)
        a = SyntheticLMDataset(cfg).batch_at(13)
        b = SyntheticLMDataset(cfg).batch_at(13)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_shards_partition_global_batch(self):
        full = SyntheticLMDataset(DataConfig(global_batch=8, seq_len=16,
                                             vocab_size=50, seed=1))
        shard_sizes = []
        for s in range(4):
            sh = SyntheticLMDataset(DataConfig(global_batch=8, seq_len=16,
                                               vocab_size=50, seed=1,
                                               shard_index=s, num_shards=4))
            shard_sizes.append(sh.batch_at(0)["tokens"].shape[0])
        assert shard_sizes == [2, 2, 2, 2]

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLMDataset(DataConfig(global_batch=2, seq_len=16,
                                          vocab_size=50))
        b = d.batch_at(0)
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])

    def test_learnable_structure(self):
        """Next token is the permutation of the current one (mostly)."""
        d = SyntheticLMDataset(DataConfig(global_batch=4, seq_len=64,
                                          vocab_size=32, noise=0.0, seed=3))
        b = d.batch_at(0)
        toks = b["tokens"]
        match = (d.perm[toks[:, :-1]] == toks[:, 1:]).mean()
        assert match == 1.0

    def test_invalid_shards_raise(self):
        with pytest.raises(ValueError):
            SyntheticLMDataset(DataConfig(global_batch=5, seq_len=8,
                                          vocab_size=10, num_shards=2))


class TestHardwareProfile:
    """The one-shot ``_profile`` contract (DESIGN.md §9)."""

    CFG = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=64,
                      n_heads=2, n_kv_heads=2, d_ff=128, vocab_size=64).validate()

    def _trainable(self, **hp):
        from repro.train.trainable import make_model_trainable
        cls = make_model_trainable(self.CFG, batch=4, seq_len=32,
                                   steps_per_iter=3, total_steps=10)
        return cls({"lr": 1e-3, **hp})

    def test_first_step_carries_profile_once(self):
        tr = self._trainable()
        out = tr.step()
        p = out["_profile"]
        assert p["first_step_s"] >= p["steady_step_s"] > 0
        assert p["compile_s"] >= 0
        assert p["param_count"] > 0
        assert p["batch"] == 4 and p["seq_len"] == 32
        # one-shot: the next iteration is clean
        assert "_profile" not in tr.step()

    def test_profile_false_disables(self):
        tr = self._trainable(profile=False)
        assert "_profile" not in tr.step()

    def test_rebuild_rearms_profile(self):
        tr = self._trainable()
        tr.step()
        assert tr.reset_config({"lr": 5e-4})  # PBT mutation path
        assert "_profile" in tr.step()

    def test_roofline_tag(self):
        tr = self._trainable(profile_roofline=True)
        p = tr.step()["_profile"]
        assert p["predicted_step_s"] > 0
        assert p["dominant"] in ("compute", "memory", "collective")
        assert p["achieved_vs_predicted"] > 0
        assert p["arg_bytes"] > 0 and p["temp_bytes"] > 0
