"""GP-EI searcher: sample efficiency on smooth objectives."""
import numpy as np
import pytest

from repro.core.search.gp import GPSearcher, _GP
from repro.core.search.basic import RandomSearcher
from repro.core.search.space import choice, loguniform, uniform


def run_searcher(s, objective, n):
    best = np.inf
    for i in range(n):
        cfg = s.suggest(f"t{i}")
        if cfg is None:
            break
        loss = objective(cfg)
        s.observe(f"t{i}", cfg, loss, final=True)
        best = min(best, loss)
    return best


class TestGP:
    def test_gp_regression_interpolates(self):
        X = np.asarray([[0.0], [0.5], [1.0]])
        y = np.asarray([1.0, 0.0, 1.0])
        gp = _GP(X, y, length_scale=0.3)
        mean, std = gp.predict(np.asarray([[0.5], [0.0]]))
        assert abs(mean[0] - 0.0) < 0.05 and abs(mean[1] - 1.0) < 0.05
        mean_mid, std_mid = gp.predict(np.asarray([[0.25]]))
        assert std_mid[0] > std[0]  # more uncertain away from data

    def test_beats_random_on_smooth_objective(self):
        space = {"x": uniform(0.0, 1.0), "lr": loguniform(1e-4, 1e0)}

        def obj(cfg):
            return (cfg["x"] - 0.3) ** 2 + (np.log10(cfg["lr"]) + 2) ** 2 * 0.1

        gp_best, rnd_best = [], []
        for seed in range(3):
            gp = GPSearcher(space, n_startup_trials=8, seed=seed)
            rnd = RandomSearcher(space, seed=seed)
            gp_best.append(run_searcher(gp, obj, 40))
            rnd_best.append(run_searcher(rnd, obj, 40))
        assert np.mean(gp_best) < np.mean(rnd_best) * 0.5
        assert np.mean(gp_best) < 0.01

    def test_handles_mixed_space(self):
        space = {"x": uniform(0, 1), "c": choice(["a", "b"])}
        gp = GPSearcher(space, n_startup_trials=3, seed=0)
        for i in range(10):
            cfg = gp.suggest(f"t{i}")
            assert cfg["c"] in ("a", "b") and 0 <= cfg["x"] <= 1
            gp.observe(f"t{i}", cfg, cfg["x"], final=True)

    def test_requires_continuous_dim(self):
        with pytest.raises(ValueError):
            GPSearcher({"c": choice(["a", "b"])})

    def test_max_trials(self):
        gp = GPSearcher({"x": uniform(0, 1)}, max_trials=2)
        assert gp.suggest("a") is not None
        assert gp.suggest("b") is not None
        assert gp.suggest("c") is None
