"""Control-plane observability (repro.obs, DESIGN.md §8).

Instrument/tracer unit coverage, the determinism contract (two identical
VirtualClock scenario runs export byte-identical Chrome traces), span
propagation across the process-worker pipe protocol, the metrics JSONL
snapshot stream, and the ConsoleLogger final-flush satellite fix.
"""
import json
import os

import pytest

from repro.core import (CheckpointManager, ConsoleLogger, EventType,
                        FIFOScheduler, JSONLLogger, ObjectStore,
                        ProcessMeshExecutor, Resources, Result,
                        TrainableFactory, Trial, TrialEvent, TrialRunner,
                        TrialStatus, VirtualClock)
from repro.obs import (NULL_OBS, NULL_TRACER, Counter, Gauge, Histogram,
                       MetricsRegistry, Observability, Tracer)
from repro.testing import crash_storm, run_scenario

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))


# -- instruments ------------------------------------------------------------------------

class TestMetrics:
    def test_counter(self):
        c = Counter("x")
        c.inc()
        c.inc(4)
        assert c.value == 5
        assert c.snapshot() == 5

    def test_gauge_last_write_wins(self):
        g = Gauge("x")
        g.set(3.0)
        g.set(1.5)
        assert g.snapshot() == 1.5

    def test_histogram_aggregates(self):
        h = Histogram("x")
        for v in (1.0, 2.0, 3.0, 10.0):
            h.observe(v)
        s = h.snapshot()
        assert s["count"] == 4
        assert s["sum"] == 16.0
        assert s["min"] == 1.0 and s["max"] == 10.0
        assert s["mean"] == 4.0

    def test_histogram_percentile_conservative(self):
        h = Histogram("x")
        for v in (1.0, 1.0, 1.0, 100.0):
            h.observe(v)
        # Upper-boundary estimate: p50 from the [1,2) bucket, p100 exact max.
        assert 1.0 <= h.percentile(50) <= 2.0
        assert h.percentile(100) == 100.0
        assert Histogram("empty").percentile(99) == 0.0
        assert Histogram("empty").snapshot()["count"] == 0

    def test_registry_create_on_first_use_and_kind_guard(self):
        r = MetricsRegistry()
        c = r.counter("a.b")
        assert r.counter("a.b") is c
        with pytest.raises(TypeError):
            r.gauge("a.b")
        assert r.get("nope") is None
        r.histogram("h")
        assert r.names() == ["a.b", "h"]

    def test_snapshot_line_is_canonical_json(self):
        r = MetricsRegistry()
        r.counter("z").inc()
        r.counter("a").inc(2)
        line = r.snapshot_line(123.0)
        rec = json.loads(line)
        assert rec == {"t": 123.0, "schema_version": 1,
                       "metrics": {"a": 2, "z": 1}}
        # Fixed separators + sorted keys: the byte form is reproducible.
        assert line == r.snapshot_line(123.0)


# -- tracer -----------------------------------------------------------------------------

class TestTracer:
    def test_span_ctx_stamps_from_injected_clock(self):
        vc = VirtualClock()
        tr = Tracer(clock=vc)
        with tr.span("step", "t-1", cat="train", iteration=3) as sp:
            vc.sleep(2.0)
            sp.arg("note", "ok")
        (s,) = tr.spans
        assert (s.name, s.trace, s.cat, s.proc) == ("step", "t-1", "train", "host")
        assert s.ts == vc._epoch and s.dur == 2.0
        assert s.args == {"iteration": 3, "note": "ok"}

    def test_span_records_error_on_exception(self):
        tr = Tracer(clock=VirtualClock())
        with pytest.raises(ValueError):
            with tr.span("build", "t-1"):
                raise ValueError("boom")
        assert tr.spans[0].args["error"] == "ValueError"

    def test_begin_end_and_end_all(self):
        vc = VirtualClock()
        tr = Tracer(clock=vc)
        tr.begin(("trial", "t-1"), "trial", "t-1", cat="lifecycle")
        tr.begin(("trial", "t-2"), "trial", "t-2", cat="lifecycle")
        vc.sleep(5.0)
        tr.end(("trial", "t-1"), status="TERMINATED")
        tr.end(("trial", "t-1"))  # double-end: no-op
        tr.end_all(status="ABANDONED")
        spans = tr.spans
        assert len(spans) == 2
        assert spans[0].args["status"] == "TERMINATED" and spans[0].dur == 5.0
        assert spans[1].args["status"] == "ABANDONED"

    def test_non_scalar_args_dropped(self):
        tr = Tracer(clock=VirtualClock())
        tr.record("x", "t-1", 0.0, 1.0, good=1, bad=object(), arr=[1, 2])
        assert tr.spans[0].args == {"good": 1}

    def test_adopt_wire_tuples(self):
        tr = Tracer(clock=VirtualClock())
        tr.adopt("t-9", [("step", 1.0, 0.5, "train", "worker", {"iteration": 2})])
        (s,) = tr.spans
        assert s.trace == "t-9" and s.proc == "worker" and s.dur == 0.5

    def test_disabled_tracer_is_inert(self):
        tr = NULL_TRACER
        assert not tr.enabled
        ctx = tr.span("x", "t")
        assert ctx is tr.span("y", "t")  # shared no-op ctx, no allocation
        with ctx as sp:
            sp.arg("a", 1)
        tr.record("x", "t", 0.0, 1.0)
        tr.begin("k", "x", "t")
        tr.end("k")
        tr.adopt("t", [("x", 0.0, 1.0, "", "host", {})])
        assert tr.spans == []

    def test_chrome_export_shape(self, tmp_path):
        vc = VirtualClock()
        tr = Tracer(clock=vc)
        tr.record("sched", "", vc.time(), 0.001, cat="sched")
        with tr.span("step", "t-1", cat="train"):
            vc.sleep(1.0)
        path = tr.export_chrome(str(tmp_path / "trace.json"))
        doc = json.load(open(path))
        evs = doc["traceEvents"]
        meta = [e for e in evs if e["ph"] == "M"]
        xs = [e for e in evs if e["ph"] == "X"]
        assert {m["name"] for m in meta} == {"process_name", "thread_name"}
        assert len(xs) == 2
        # Control-plane span rides tid 0; trial span gets its own row.
        by_name = {e["name"]: e for e in xs}
        assert by_name["sched"]["tid"] == 0
        assert by_name["step"]["tid"] == 1
        # µs ints, rebased to the earliest span, dur floored at 1.
        assert by_name["sched"]["ts"] == 0 and by_name["sched"]["dur"] == 1000
        assert by_name["step"]["dur"] == 1_000_000


class TestNullObs:
    def test_null_obs_is_shared_and_inert(self):
        assert NULL_OBS.active is False
        assert NULL_OBS.metrics is None
        assert NULL_OBS.tracer is NULL_TRACER
        NULL_OBS.on_event(TrialEvent(EventType.RESULT, "t-1"))
        assert NULL_OBS.maybe_snapshot(None) is False
        NULL_OBS.close(None)  # idempotent no-op


# -- determinism: byte-identical traces ---------------------------------------------------

def _storm_trace(executor: str, token: str) -> str:
    obs = Observability(trace=True, metrics=True)
    scenario = crash_storm(n_trials=40, seed=3)
    res = run_scenario(scenario,
                       lambda: FIFOScheduler(metric="loss", mode="min"),
                       executor=executor, pool_devices=8,
                       obs=obs, token=token)
    obs.close(res.executor)
    assert any(t.num_failures > 0 for t in res.trials)  # storm engaged
    return obs.tracer.chrome_json()


class TestTraceDeterminism:
    @pytest.mark.parametrize("executor", ["serial", "concurrent"])
    def test_identical_runs_export_identical_bytes(self, executor):
        a = _storm_trace(executor, token="det")
        b = _storm_trace(executor, token="det")
        assert a == b
        doc = json.loads(a)
        names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
        # The full lifecycle taxonomy shows up in a crash storm.
        assert {"trial", "schedule.decision", "slice.acquire", "build",
                "step", "ckpt.save", "restart"} <= names

    def test_restarted_trial_spans_share_one_trace(self):
        obs = Observability(trace=True)
        scenario = crash_storm(n_trials=20, seed=3)
        res = run_scenario(scenario,
                           lambda: FIFOScheduler(metric="loss", mode="min"),
                           executor="concurrent", pool_devices=8,
                           obs=obs, token="retr")
        obs.close(res.executor)
        crashed = [t for t in res.trials
                   if t.num_failures > 0 and t.status == TrialStatus.TERMINATED]
        assert crashed
        tid = crashed[0].trial_id
        spans = [s for s in obs.tracer.spans if s.trace == tid]
        lives = [s for s in spans if s.name == "trial"]
        # One lifecycle span per (re)launch, all on the same trace row.
        assert len(lives) == crashed[0].num_failures + 1
        assert lives[0].args["status"] == "REQUEUED"
        assert lives[-1].args["status"] == "TERMINATED"
        assert [s.name for s in spans if s.name == "restart"]
        restores = [s for s in spans if s.name == "ckpt.restore"]
        assert restores and all(s.cat == "ckpt" for s in restores)


# -- process tier: spans cross the pipe ----------------------------------------------------

class TestProcessTierSpans:
    def test_child_spans_nest_inside_parent_trial_span(self):
        obs = Observability(trace=True, metrics=True)
        factory = TrainableFactory(target="_worker_trainables:Counter",
                                   sys_path=(TESTS_DIR,))
        from repro.dist.submesh import SlicePool
        ex = ProcessMeshExecutor(
            factory_resolver=lambda _n: factory,
            checkpoint_manager=CheckpointManager(ObjectStore()),
            total_devices=4, slice_pool=SlicePool(n_virtual=4),
            checkpoint_freq=1, obs=obs)
        runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                             stopping_criteria={"training_iteration": 3},
                             obs=obs)
        t = Trial({}, resources=Resources(devices=1),
                  stopping_criteria={"training_iteration": 3})
        runner.add_trial(t)
        trials = runner.run()
        obs.close(ex)
        assert trials[0].status == TrialStatus.TERMINATED

        spans = [s for s in obs.tracer.spans if s.trace == t.trial_id]
        host = [s for s in spans if s.proc == "host"]
        child = [s for s in spans if s.proc == "worker"]
        assert {"trial", "schedule.decision", "slice.acquire"} <= \
            {s.name for s in host}
        assert {"build", "step", "ckpt.save"} <= {s.name for s in child}
        steps = [s for s in child if s.name == "step"]
        assert len(steps) == 3
        assert all(s.args.get("pid") for s in child if s.name == "build")
        # Child spans join the parent trace and nest inside its lifecycle
        # span (same host, wall time on both sides of the pipe).
        (life,) = [s for s in host if s.name == "trial"]
        eps = 0.05
        for s in child:
            assert s.ts >= life.ts - eps
            assert s.ts + s.dur <= life.ts + life.dur + eps
        # ckpt bytes crossed the pipe into the metrics registry.
        assert obs.metrics.histogram("ckpt.bytes").count >= 1


# -- metrics stream + loggers -------------------------------------------------------------

class TestMetricsStream:
    def test_snapshot_stream_and_final_snapshot(self, tmp_path):
        mpath = str(tmp_path / "metrics.jsonl")
        obs = Observability(metrics=mpath, metrics_interval=30.0)
        scenario = crash_storm(n_trials=40, seed=1)
        res = run_scenario(scenario,
                           lambda: FIFOScheduler(metric="loss", mode="min"),
                           executor="concurrent", pool_devices=8,
                           obs=obs, token="ms")
        obs.close(res.executor)
        recs = [json.loads(l) for l in open(mpath)]
        assert len(recs) >= 2  # periodic snapshots + the close() snapshot
        for rec in recs:
            assert rec["schema_version"] == 1
            assert "metrics" in rec
        final = recs[-1]["metrics"]
        assert final["events.result"] > 0
        assert final["bus.published"] > 0
        assert final["bus.fanin_us"]["count"] > 0
        assert final["sched.choose_us"]["count"] > 0
        assert final["pool.acquire_us"]["count"] > 0
        assert final["ckpt.save_us"]["count"] > 0
        assert final["trials.restarts"] > 0
        # Snapshot timestamps ride the virtual axis, strictly increasing.
        ts = [rec["t"] for rec in recs]
        assert ts == sorted(ts) and ts[0] >= res.clock._epoch

    def test_maybe_snapshot_throttles_on_clock(self, tmp_path):
        vc = VirtualClock()
        obs = Observability(metrics=str(tmp_path / "m.jsonl"),
                            metrics_interval=10.0, clock=vc)
        assert obs.maybe_snapshot(None) is True   # first call always writes
        assert obs.maybe_snapshot(None) is False  # inside the window
        vc.sleep(10.0)
        assert obs.maybe_snapshot(None) is True


class TestConsoleLoggerFlush:
    def test_final_flush_emits_throttled_result(self, capsys):
        vc = VirtualClock()
        lg = ConsoleLogger(interval_s=5.0, clock=vc)
        t = Trial({})
        vc.sleep(10.0)
        lg.on_result(t, Result(t.trial_id, 1, {"loss": 1.0}))   # prints
        vc.sleep(1.0)
        lg.on_result(t, Result(t.trial_id, 2, {"loss": 0.5}))   # throttled
        lg.on_experiment_end([t])  # final flush INSIDE the 5s window
        out = [l for l in capsys.readouterr().out.splitlines() if l]
        assert "iter=1" in out[0]
        assert "iter=2" in out[1]  # the throttled last status still lands
        assert "experiment done" in out[-1]

    def test_flush_is_idempotent_and_quiet_without_pending(self, capsys):
        lg = ConsoleLogger(clock=VirtualClock())
        lg.flush()
        lg.flush()
        assert capsys.readouterr().out == ""

    def test_status_table_with_metrics(self, capsys):
        obs = Observability(metrics=True)
        obs.metrics.counter("events.result").inc(7)
        obs.metrics.histogram("sched.choose_us").observe(12.0)
        lg = ConsoleLogger(clock=VirtualClock(), obs=obs)
        lg.flush()
        out = capsys.readouterr().out
        assert "control-plane status" in out
        assert "results=7" in out
        assert "choose=12.0us" in out
        assert ConsoleLogger(clock=VirtualClock()).status_table() == ""


class TestJSONLHeader:
    def test_run_header_round_trip(self, tmp_path):
        vc = VirtualClock()
        path = str(tmp_path / "e.jsonl")
        lg = JSONLLogger(path, clock=vc, run_id="run-42", executor="serial")
        t = Trial({"lr": 0.1})
        lg.on_result(t, Result(t.trial_id, 1, {"loss": 0.5}))
        lg.close()
        header = json.loads(open(path).readline())
        assert header == {"event": "run_header",
                          "schema_version": JSONLLogger.SCHEMA_VERSION,
                          "run_id": "run-42", "clock": "VirtualClock",
                          "executor": "serial", "decisions": True,
                          "t": vc._epoch}

    def test_old_readers_stay_compatible(self, tmp_path):
        """A v1-era reader that filters on the ``event`` field skips the
        header record and unknown fields without breaking."""
        path = str(tmp_path / "e.jsonl")
        lg = JSONLLogger(path)
        t = Trial({"lr": 0.1})
        lg.on_result(t, Result(t.trial_id, 1, {"loss": 0.5}))
        t.set_status(TrialStatus.TERMINATED)
        lg.on_trial_complete(t)
        lg.close()
        results = [r for r in map(json.loads, open(path))
                   if r["event"] == "result"]
        assert len(results) == 1 and results[0]["metrics"]["loss"] == 0.5
        assert lg.run_id.startswith("run-")
