"""Durable-resume equivalence (ISSUE 10 tentpole, DESIGN.md §12).

A sweep interrupted mid-flight and resumed from its on-disk artifacts —
journal, ``search_state.json`` snapshot, checkpoint mirrors — must finish
**bit-identical** to the same sweep run uninterrupted: same trial table, same
per-trial decision stream (source, verdict, iteration, inputs, and the
virtual-clock timestamp ``t``), same ``summary_json``.  For ASHA, HyperBand
AND PBT, across several interruption points, including a double interrupt
(kill the resumed run and resume again).

The interruption here is a cooperative ``runner.step()`` cutoff inside one
process (tests/test_resume_kill9.py covers the true-SIGKILL tier); what makes
it representative is that the cutoff lands between arbitrary journal records,
so the resume path exercises torn tails, unsnapshotted journal suffixes and
checkpoint mirrors ahead of the journal frontier.

On mismatch, the clean and resumed log dirs are copied to
``$REPRO_RESUME_ARTIFACT_DIR`` (when set) so CI can upload them.
"""
import json
import os
import shutil

import pytest

from repro.core.schedulers.asha import AsyncHyperBandScheduler
from repro.core.schedulers.hyperband import HyperBandScheduler
from repro.core.schedulers.pbt import PopulationBasedTraining
from repro.obs.analysis import ExperimentAnalysis
from repro.testing.scenarios import Scenario, run_scenario

STEP_S = [0.5, 0.7, 0.9, 1.1, 1.3, 1.7, 1.9, 2.3]

SCHEDULERS = {
    "asha": lambda: AsyncHyperBandScheduler(
        metric="loss", mode="min", max_t=9, grace_period=1,
        reduction_factor=3),
    "hyperband": lambda: HyperBandScheduler(
        metric="loss", mode="min", max_t=9, eta=3),
    "pbt": lambda: PopulationBasedTraining(
        metric="loss", mode="min", perturbation_interval=3,
        hyperparam_mutations={"lr": [0.001, 0.004, 0.008, 0.02]}, seed=7),
}

# Cooperative-interrupt points (runner steps).  Early (most trials PENDING),
# mid-sweep (rungs/brackets part-filled; PBT mid-exploit window), and late
# (some trials TERMINATED, exploits of finished donors still ahead).
KILL_POINTS = {"asha": (9, 23, 41), "hyperband": (13, 29), "pbt": (19, 47, 71)}


def scenario(name):
    configs = [{"lr": 0.001 * (i + 1), "step_s": STEP_S[i],
                "jitter_s": 0.25} for i in range(8)]
    return Scenario(name=name, configs=configs, stop_iteration=9,
                    max_failures=0)


def sweep(kind, log_dir, **kw):
    return run_scenario(scenario(f"eqv-{kind}"), SCHEDULERS[kind],
                        executor="concurrent", pool_devices=8,
                        token=f"eqv-{kind}", log_dir=log_dir,
                        search_state_interval=3.0, keep_last=50, **kw)


def table(res):
    return sorted((t.trial_id, t.status.value, t.training_iteration,
                   round(t.best_value("loss", "min") or -1.0, 9))
                  for t in res.trials)


def decisions(log_dir):
    """Per-trial decision streams: (source, verdict, iteration, inputs, t)."""
    out = {}
    with open(os.path.join(log_dir, "events.jsonl")) as f:
        for line in f:
            try:
                obj = json.loads(line)
            except ValueError:
                continue
            if obj.get("event") == "decision":
                info = dict(obj.get("info") or {})
                out.setdefault(obj.get("trial_id"), []).append(
                    (info.get("source"), info.get("verdict"),
                     info.get("iteration"),
                     json.dumps(info.get("inputs"), sort_keys=True),
                     obj.get("t")))
    return out


def summary(log_dir):
    return ExperimentAnalysis.from_journal(
        os.path.join(log_dir, "events.jsonl")).summary_json(
            metric="loss", mode="min")


def save_artifacts(*dirs):
    dest = os.environ.get("REPRO_RESUME_ARTIFACT_DIR")
    if not dest:
        return
    os.makedirs(dest, exist_ok=True)
    for d in dirs:
        shutil.copytree(d, os.path.join(dest, os.path.basename(d)),
                        dirs_exist_ok=True)


def assert_equivalent(clean_res, clean_dir, resumed_res, resumed_dir, label):
    problems = []
    if table(clean_res) != table(resumed_res):
        problems.append(f"trial table differs:\n  clean : {table(clean_res)}"
                        f"\n  resume: {table(resumed_res)}")
    dc, dr = decisions(clean_dir), decisions(resumed_dir)
    for tid in sorted(set(dc) | set(dr)):
        if dc.get(tid) != dr.get(tid):
            problems.append(f"decision stream differs for {tid}:"
                            f"\n  clean : {dc.get(tid)}"
                            f"\n  resume: {dr.get(tid)}")
    if summary(clean_dir) != summary(resumed_dir):
        problems.append("summary_json differs")
    if problems:
        save_artifacts(clean_dir, resumed_dir)
        pytest.fail(f"[{label}] resumed run is not bit-identical:\n"
                    + "\n".join(problems))


@pytest.fixture(scope="module")
def clean_runs(tmp_path_factory):
    """One uninterrupted reference sweep per scheduler."""
    out = {}
    for kind in SCHEDULERS:
        d = str(tmp_path_factory.mktemp(f"clean_{kind}"))
        out[kind] = (sweep(kind, d), d)
    return out


@pytest.mark.parametrize("kind", list(SCHEDULERS))
def test_resume_bit_identical(kind, clean_runs, tmp_path):
    clean_res, clean_dir = clean_runs[kind]
    for kill in KILL_POINTS[kind]:
        d = str(tmp_path / f"kill{kill}")
        sweep(kind, d, interrupt_after_steps=kill)
        resumed = sweep(kind, d, resume=True)
        assert_equivalent(clean_res, clean_dir, resumed, d,
                          f"{kind} kill@{kill}")


@pytest.mark.parametrize("kind", ["asha", "pbt"])
def test_double_interrupt(kind, clean_runs, tmp_path):
    """Kill the sweep, resume, kill the resumed run, resume again."""
    clean_res, clean_dir = clean_runs[kind]
    d = str(tmp_path / "twice")
    sweep(kind, d, interrupt_after_steps=23)
    sweep(kind, d, resume=True, interrupt_after_steps=8)
    resumed = sweep(kind, d, resume=True)
    assert_equivalent(clean_res, clean_dir, resumed, d,
                      f"{kind} double-interrupt")


def test_resume_without_journal_raises(tmp_path):
    with pytest.raises(ValueError):
        sweep("asha", str(tmp_path / "empty"), resume=True)
