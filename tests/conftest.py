import os

# Tests see the real single-CPU device world (the 512-device override belongs
# ONLY to launch/dryrun.py). Keep allocations small and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.key(0)
