import os

# Tests see the real single-CPU device world (the 512-device override belongs
# ONLY to launch/dryrun.py). Keep allocations small and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest


def pytest_configure(config):
    # pytest-timeout is optional (requirements-dev.txt); register the marker
    # so collection stays warning-free when the plugin is absent.
    config.addinivalue_line(
        "markers", "timeout(seconds): per-test timeout (pytest-timeout)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.key(0)
