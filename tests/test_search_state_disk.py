"""search_state.json disk round-trips (DESIGN.md §12, ISSUE 10 acceptance).

Every scheduler and searcher in the matrix must survive the full durable
path — ``SearchStateSnapshotter.snapshot`` → bytes on disk →
``load_search_state`` → ``load_state_dict`` into a *fresh* instance — and
then continue identically: same next verdict, same next suggestion, same RNG
stream.  The in-memory ``state_dict`` round-trips in test_provenance.py
already pin the schema; this file pins the file format (atomic write, the
watermark field, type tags) and the through-disk continuation contract that
``prepare_resume`` relies on.
"""
import json

import numpy as np
import pytest

from repro.core import (ASHAScheduler, FIFOScheduler, GPSearcher,
                        GridSearcher, HyperBandScheduler, MedianStoppingRule,
                        PopulationBasedTraining, RandomSearcher, Result,
                        SchedulerDecision, TPESearcher, Trial, uniform)
from repro.obs.flightrec import SearchStateSnapshotter, load_search_state

from test_provenance import run_qualities


def snap_to_disk(tmp_path, scheduler=None, searcher=None, watermark=42):
    """Snapshot through the real writer and read back through the real loader."""
    path = str(tmp_path / "search_state.json")
    snap = SearchStateSnapshotter(path, interval_s=0.0,
                                  watermark_fn=lambda: watermark)
    snap.snapshot(scheduler, searcher)
    state = load_search_state(path)
    assert state is not None, "snapshot did not land on disk"
    assert state["journal_records"] == watermark
    return state


class TestSchedulerDiskRoundtrip:
    def test_fifo(self, tmp_path):
        state = snap_to_disk(tmp_path, scheduler=FIFOScheduler())
        assert state["scheduler"]["type"] == "FIFOScheduler"
        s2 = FIFOScheduler()
        s2.load_state_dict(state["scheduler"]["state"])
        assert s2.state_dict() == {}

    def test_asha(self, tmp_path):
        mk = lambda: ASHAScheduler(metric="loss", mode="min", max_t=10,
                                   grace_period=1, reduction_factor=2)
        s1 = mk()
        trials = [Trial({}, trial_id=f"a{i}") for i in range(4)]
        for t in trials:
            s1.on_trial_add(None, t)
        for i, t in enumerate(trials[:3]):
            s1.on_result(None, t, Result(t.trial_id, 1, {"loss": 0.1 * i}))
        state = snap_to_disk(tmp_path, scheduler=s1)
        s2 = mk()
        s2.load_state_dict(state["scheduler"]["state"])
        r = Result("a3", 1, {"loss": 9.0})
        assert s2.on_result(None, trials[3], r) \
            == s1.on_result(None, trials[3], r) == SchedulerDecision.STOP

    def test_median(self, tmp_path):
        mk = lambda: MedianStoppingRule(metric="loss", mode="min",
                                        grace_period=1,
                                        min_samples_required=2)
        s1 = mk()
        run_qualities([0.0, 0.1, 2.0], s1, max_iter=8, devices=3)
        state = snap_to_disk(tmp_path, scheduler=s1)
        s2 = mk()
        s2.load_state_dict(state["scheduler"]["state"])
        # a laggard far above the running median: within grace on its first
        # result, stopped on its second — identically in both instances
        lag = Trial({}, trial_id="lag")
        s1.on_trial_add(None, lag), s2.on_trial_add(None, lag)
        for it, want in [(2, SchedulerDecision.CONTINUE),
                         (3, SchedulerDecision.STOP)]:
            r = Result("lag", it, {"loss": 99.0})
            assert s2.on_result(None, lag, r) == s1.on_result(None, lag, r) \
                == want

    def test_hyperband(self, tmp_path):
        mk = lambda: HyperBandScheduler(metric="loss", mode="min", max_t=9,
                                        eta=3)
        s1 = mk()
        trials, _ = run_qualities(list(np.linspace(0.0, 2.0, 9)), s1,
                                  max_iter=9, devices=3)
        state = snap_to_disk(tmp_path, scheduler=s1)
        s2 = mk()
        s2.load_state_dict(state["scheduler"]["state"], trials=trials)
        assert json.dumps(s2.state_dict(), sort_keys=True, default=str) \
            == json.dumps(s1.state_dict(), sort_keys=True, default=str)
        assert s2.n_stopped == s1.n_stopped

    def test_pbt_rng_stream(self, tmp_path):
        mk = lambda: PopulationBasedTraining(
            metric="loss", mode="min", perturbation_interval=3,
            hyperparam_mutations={"quality": uniform(0.0, 2.0)}, seed=0)
        s1 = mk()
        run_qualities([0.0, 1.0, 2.0], s1, max_iter=9, devices=3)
        state = snap_to_disk(tmp_path, scheduler=s1)
        s2 = mk()
        s2.load_state_dict(state["scheduler"]["state"])
        # the restored RNG continues the exact stream the original would have
        assert s2._explore({"quality": 1.0}) == s1._explore({"quality": 1.0})


class TestSearcherDiskRoundtrip:
    def test_random(self, tmp_path):
        space = {"x": uniform(0.0, 1.0)}
        s1 = RandomSearcher(space, seed=5)
        for i in range(3):
            s1.suggest(f"r{i}")
        state = snap_to_disk(tmp_path, searcher=s1)
        assert state["searcher"]["type"] == "RandomSearcher"
        s2 = RandomSearcher(space, seed=0)  # seed overwritten by load
        s2.load_state_dict(state["searcher"]["state"])
        assert s2.suggest("r3") == s1.suggest("r3")

    def test_grid(self, tmp_path):
        space = {"x": uniform(0.0, 1.0)}
        s1 = GridSearcher(space, num_samples=5, seed=6)
        for i in range(2):
            s1.suggest(f"g{i}")
        state = snap_to_disk(tmp_path, searcher=s1)
        s2 = GridSearcher(space, num_samples=5, seed=6)
        s2.load_state_dict(state["searcher"]["state"])
        assert s2.suggest("g2") == s1.suggest("g2")

    @pytest.mark.parametrize("cls,kw", [(GPSearcher, {"n_startup_trials": 2}),
                                        (TPESearcher, {"n_startup_trials": 2})])
    def test_model_searchers(self, tmp_path, cls, kw):
        space = {"x": uniform(0.0, 1.0)}
        s1 = cls(space, seed=7, **kw)
        for i in range(3):
            s1.observe(f"o{i}", {"x": 0.2 * (i + 1)}, 1.0 - 0.3 * i, True)
        state = snap_to_disk(tmp_path, searcher=s1)
        s2 = cls(space, seed=0, **kw)
        s2.load_state_dict(state["searcher"]["state"])
        assert s2.suggest("n0") == s1.suggest("n0")


class TestFileContract:
    def test_corrupt_file_degrades_to_none(self, tmp_path):
        p = tmp_path / "search_state.json"
        p.write_text("{ torn mid-wri")
        assert load_search_state(str(p)) is None

    def test_missing_file_is_none(self, tmp_path):
        assert load_search_state(str(tmp_path / "nope.json")) is None

    def test_watermark_absent_without_fn(self, tmp_path):
        path = str(tmp_path / "s.json")
        SearchStateSnapshotter(path, interval_s=0.0).snapshot(FIFOScheduler())
        assert load_search_state(path)["journal_records"] is None

    def test_snapshot_is_single_complete_json_doc(self, tmp_path):
        path = str(tmp_path / "s.json")
        snap = SearchStateSnapshotter(path, interval_s=0.0,
                                      watermark_fn=lambda: 7)
        sched = ASHAScheduler(metric="loss", mode="min", max_t=4)
        for _ in range(3):  # repeated writes replace, never append
            snap.snapshot(sched)
        with open(path) as f:
            doc = json.load(f)  # raises if torn/appended
        assert doc["scheduler"]["type"] == type(sched).__name__
