"""True-SIGKILL durable resume (ISSUE 10 acceptance, DESIGN.md §12).

The in-process equivalence tier interrupts cooperatively; this tier does what
the tentpole actually promises to survive: a controller killed with
``SIGKILL`` — no atexit, no flushed buffers, a possibly torn journal tail.
Each case runs ``python -m repro.testing.kill9`` three times:

  1. clean child → runs the sweep uninterrupted, writes ``final.json``
  2. killed child → same sweep, ``os.kill(getpid(), SIGKILL)`` mid-flight
  3. resumed child → ``--resume`` from the survivor artifacts, writes
     ``final.json``

and requires the two ``final.json`` files byte-identical and the decision
streams (including virtual timestamps) equal, for ASHA, HyperBand and PBT.

On mismatch the child log dirs are copied to ``$REPRO_RESUME_ARTIFACT_DIR``
(when set) for CI upload.
"""
import json
import os
import shutil
import signal
import subprocess
import sys

import pytest

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


def run_child(log_dir, scheduler, *extra, expect_kill=False):
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.testing.kill9", "--log-dir", log_dir,
         "--scheduler", scheduler, *extra],
        env=env, capture_output=True, text=True, timeout=300)
    if expect_kill:
        assert proc.returncode == -signal.SIGKILL, (
            f"child should have SIGKILLed itself, got rc={proc.returncode}\n"
            f"stderr: {proc.stderr[-2000:]}")
    else:
        assert proc.returncode == 0, (
            f"child failed rc={proc.returncode}\n"
            f"stderr: {proc.stderr[-2000:]}")
    return proc


def decisions(log_dir):
    out = {}
    with open(os.path.join(log_dir, "events.jsonl")) as f:
        for line in f:
            try:
                obj = json.loads(line)
            except ValueError:
                continue
            if obj.get("event") == "decision":
                info = dict(obj.get("info") or {})
                out.setdefault(obj.get("trial_id"), []).append(
                    (info.get("source"), info.get("verdict"),
                     info.get("iteration"),
                     json.dumps(info.get("inputs"), sort_keys=True),
                     obj.get("t")))
    return out


def save_artifacts(*dirs):
    dest = os.environ.get("REPRO_RESUME_ARTIFACT_DIR")
    if not dest:
        return
    os.makedirs(dest, exist_ok=True)
    for d in dirs:
        shutil.copytree(d, os.path.join(dest, "kill9-" + os.path.basename(d)),
                        dirs_exist_ok=True)


@pytest.mark.parametrize("scheduler,kill_after",
                         [("asha", 12), ("hyperband", 9), ("pbt", 17)])
def test_kill9_resume_bit_identical(tmp_path, scheduler, kill_after):
    clean = str(tmp_path / f"{scheduler}_clean")
    killed = str(tmp_path / f"{scheduler}_killed")

    run_child(clean, scheduler)
    run_child(killed, scheduler, "--kill-after", str(kill_after),
              expect_kill=True)
    # The SIGKILLed controller must have left durable artifacts behind.
    assert os.path.exists(os.path.join(killed, "events.jsonl"))
    assert not os.path.exists(os.path.join(killed, "final.json"))
    run_child(killed, scheduler, "--resume")

    with open(os.path.join(clean, "final.json"), "rb") as f:
        final_clean = f.read()
    with open(os.path.join(killed, "final.json"), "rb") as f:
        final_resumed = f.read()
    dc, dr = decisions(clean), decisions(killed)
    problems = []
    if final_clean != final_resumed:
        problems.append("final.json differs (trial table / summary)")
    for tid in sorted(set(dc) | set(dr)):
        if dc.get(tid) != dr.get(tid):
            problems.append(f"decision stream differs for {tid}:"
                            f"\n  clean : {dc.get(tid)}"
                            f"\n  resume: {dr.get(tid)}")
    if problems:
        save_artifacts(clean, killed)
        pytest.fail(f"[{scheduler} kill9@{kill_after}] resumed run is not "
                    "bit-identical:\n" + "\n".join(problems))
