"""Journal-backed ExperimentAnalysis, LiveReporter, and the HTML run report
(DESIGN.md §9): parsing contracts (v2 header / header-less v1 / truncated
tails), decision-timeline reconstruction against scripted faults, and the
byte-determinism acceptance — two identical-token VirtualClock runs must
produce byte-identical summaries and report bodies.
"""
import io
import json
import os

import pytest

from repro.core import (EventType, FIFOScheduler, Result, Trial, TrialEvent,
                        TrialStatus)
from repro.core.loggers import JSONLLogger, LiveReporter
from repro.obs import Observability
from repro.obs.analysis import ExperimentAnalysis
from repro.obs.report import build_report
from repro.testing import crash_storm, run_scenario


def _v2_lines():
    """A hand-built v2 journal: header, two trials, faults, a profile."""
    return [
        json.dumps({"event": "run_header", "schema_version": 2,
                    "run_id": "run-x", "clock": "VirtualClock",
                    "executor": "concurrent", "t": 0.0}),
        json.dumps({"event": "result", "trial_id": "a", "iteration": 1,
                    "config": {"lr": 0.1}, "metrics": {"loss": 1.0}, "t": 1.0}),
        json.dumps({"event": "restarted", "trial_id": "a", "seq": 7,
                    "info": {"num_failures": 1}, "t": 1.5}),
        json.dumps({"event": "result", "trial_id": "a", "iteration": 2,
                    "config": {"lr": 0.1}, "metrics": {"loss": 0.5}, "t": 2.0}),
        json.dumps({"event": "profile", "trial_id": "a", "seq": 8,
                    "info": {"steady_step_s": 0.01, "dominant": "compute"},
                    "t": 2.0}),
        json.dumps({"event": "result", "trial_id": "b", "iteration": 1,
                    "config": {"lr": 0.2}, "metrics": {"loss": 0.8}, "t": 1.0}),
        json.dumps({"event": "complete", "trial_id": "a",
                    "status": "TERMINATED", "iterations": 2, "t": 2.1}),
        json.dumps({"event": "complete", "trial_id": "b",
                    "status": "ERROR", "iterations": 1, "t": 1.2}),
    ]


def _v3_lines():
    """A hand-built v3 journal: v2 shape + decisions flag + DECISION records."""
    return [
        json.dumps({"event": "run_header", "schema_version": 3,
                    "run_id": "run-y", "clock": "VirtualClock",
                    "executor": "concurrent", "decisions": True, "t": 0.0}),
        json.dumps({"event": "result", "trial_id": "a", "iteration": 1,
                    "config": {"lr": 0.1}, "metrics": {"loss": 1.0}, "t": 1.0}),
        json.dumps({"event": "decision", "trial_id": "a", "seq": 9,
                    "info": {"source": "scheduler",
                             "by": "AsyncHyperBandScheduler",
                             "verdict": "STOP", "iteration": 1,
                             "inputs": {"reason": "rung", "milestone": 1,
                                        "cutoff": -0.5, "score": -1.0,
                                        "n_rung": 4, "rf": 4}}, "t": 1.0}),
        json.dumps({"event": "complete", "trial_id": "a",
                    "status": "TERMINATED", "iterations": 1, "t": 1.1}),
    ]


class TestJournalParsing:
    def test_v2_journal_with_header(self):
        an = ExperimentAnalysis.from_lines(_v2_lines())
        assert an.header["schema_version"] == 2
        assert an.header["clock"] == "VirtualClock"
        assert len(an) == 2
        a = an.get("a")
        assert a.status == "TERMINATED" and a.iterations == 2
        assert a.config == {"lr": 0.1}
        assert a.series["loss"] == [(1.0, 1, 1.0), (2.0, 2, 0.5)]
        assert a.count("restarted") == 1
        assert a.profile["dominant"] == "compute"
        assert an.status_counts() == {"ERROR": 1, "TERMINATED": 1}

    def test_headerless_v1_journal(self):
        an = ExperimentAnalysis.from_lines(_v2_lines()[1:])
        assert an.header is None
        assert len(an) == 2
        assert an.best_trial("loss", "min").trial_id == "a"
        # summary still serializes (header fields null, not a crash)
        s = an.summary(metric="loss", mode="min")
        assert s["schema_version"] is None and s["n_trials"] == 2

    def test_truncated_tail_never_raises(self):
        lines = _v2_lines()
        # a crashed producer: last line cut mid-record + binary junk
        lines[-1] = lines[-1][: len(lines[-1]) // 2]
        lines.append("\x00\x01 not json at all")
        an = ExperimentAnalysis.from_lines(lines)
        assert an.n_skipped_lines == 2
        assert an.get("a").status == "TERMINATED"
        assert an.get("b").status is None  # its complete record was the cut one
        assert "(in flight)" in an.status_counts()

    def test_unknown_records_and_keys_tolerated(self):
        lines = _v2_lines() + [
            json.dumps({"event": "future_thing", "trial_id": "a",
                        "info": {"x": 1}, "extra_key": True, "t": 9.0}),
            json.dumps({"event": "no_trial_id_record", "payload": 1}),
        ]
        an = ExperimentAnalysis.from_lines(lines)
        assert an.get("a").count("future_thing") == 1

    def test_v3_journal_decisions(self):
        an = ExperimentAnalysis.from_lines(_v3_lines())
        assert an.header["schema_version"] == 3
        assert an.header["decisions"] is True
        decs = an.decisions("a")
        assert len(decs) == 1
        info = decs[0]["info"]
        assert info["verdict"] == "STOP" and info["inputs"]["reason"] == "rung"
        # merged into the decision timeline alongside fault events
        assert [e["kind"] for e in an.decision_timeline("a")] == ["decision"]

    def test_v2_reader_tolerates_decision_records(self):
        """A v2-headered stream carrying DECISION records (e.g. a mixed or
        concatenated journal) parses benignly: unknown-record tolerance."""
        lines = _v2_lines()[:-2] + [_v3_lines()[2]] + _v2_lines()[-2:]
        an = ExperimentAnalysis.from_lines(lines)
        assert an.header["schema_version"] == 2
        assert an.get("a").count("decision") == 1
        assert an.n_skipped_lines == 0

    def test_v3_reader_tolerates_v2_and_v1_streams(self):
        """The v3-era reader on pre-decision streams: no decisions, no crash,
        and the missing ``decisions`` header flag reads as absent."""
        v2 = ExperimentAnalysis.from_lines(_v2_lines())
        assert v2.header.get("decisions") is None
        assert v2.decisions("a") == []
        v1 = ExperimentAnalysis.from_lines(_v2_lines()[1:])  # header-less
        assert v1.header is None and v1.decisions("a") == []

    def test_best_trial_and_dataframe(self):
        an = ExperimentAnalysis.from_lines(_v2_lines())
        assert an.best_trial("loss", "min").trial_id == "a"
        assert an.best_trial("loss", "max").trial_id == "a"  # 1.0 beats 0.8
        df = an.dataframe(metric="loss")
        assert df["trial_id"] == ["a", "b"]
        assert df["restarts"] == [1, 0]
        assert df["last_loss"] == [0.5, 0.8]

    def test_diff_same_token_alignment(self):
        a = ExperimentAnalysis.from_lines(_v2_lines())
        lines = _v2_lines()
        # flip trial b's terminal status
        lines[-1] = json.dumps({"event": "complete", "trial_id": "b",
                                "status": "TERMINATED", "iterations": 1,
                                "t": 1.2})
        b = ExperimentAnalysis.from_lines(lines)
        d = a.diff(b, metric="loss")
        assert d["n_common"] == 2
        assert d["only_in_self"] == [] and d["only_in_other"] == []
        assert d["changed"] == {"b": {"status": ["ERROR", "TERMINATED"]}}
        # self-diff is empty
        assert a.diff(a, metric="loss")["changed"] == {}


class TestScenarioJournal:
    """run_scenario(journal_path=...) leaves an analysis-readable artifact."""

    def _run(self, tmp_path, token, n_trials=40):
        jp = str(tmp_path / f"{token}.jsonl")
        res = run_scenario(
            crash_storm(n_trials=n_trials, seed=7),
            lambda: FIFOScheduler(metric="loss", mode="min"),
            executor="concurrent", pool_devices=8,
            token=token, journal_path=jp)
        return res, jp

    def test_decision_timeline_matches_scripted_faults(self, tmp_path):
        res, jp = self._run(tmp_path, "an-tl")
        an = ExperimentAnalysis.from_journal(jp)
        assert len(an) == len(res.trials)
        # journal-reconstructed restart counts == live Trial bookkeeping
        for t in res.trials:
            r = an.get(t.trial_id)
            assert r is not None
            assert r.count("restarted") == t.num_failures - (
                1 if t.status == TrialStatus.ERROR else 0), t.trial_id
            assert r.status == t.status.value
            tl = an.decision_timeline(t.trial_id)
            # v3: fault events merged with typed DECISION provenance records
            assert all(e["kind"] in ("restarted", "decision") for e in tl)
            # timeline is time-ordered
            assert [e["t"] for e in tl] == sorted(e["t"] for e in tl)
            if t.status == TrialStatus.TERMINATED:
                decs = an.decisions(t.trial_id)
                assert decs and decs[-1]["info"]["verdict"] == "STOP"
        # the storm scripted crashes -> some trial actually restarted
        assert any(an.get(t.trial_id).count("restarted") for t in res.trials)
        # errored trials got terminal complete records too
        errored = [t for t in res.trials if t.status == TrialStatus.ERROR]
        assert errored and all(an.get(t.trial_id).status == "ERROR"
                               for t in errored)

    def test_same_token_runs_byte_identical(self, tmp_path):
        """Acceptance: identical-token VirtualClock runs -> byte-identical
        analysis summaries AND byte-identical HTML report bodies."""
        _, jp1 = self._run(tmp_path, "an-det")
        an1 = ExperimentAnalysis.from_journal(jp1)
        jp2 = str(tmp_path / "second.jsonl")
        run_scenario(crash_storm(n_trials=40, seed=7),
                     lambda: FIFOScheduler(metric="loss", mode="min"),
                     executor="concurrent", pool_devices=8,
                     token="an-det", journal_path=jp2)
        an2 = ExperimentAnalysis.from_journal(jp2)
        s1 = an1.summary_json(metric="loss", mode="min")
        s2 = an2.summary_json(metric="loss", mode="min")
        assert s1 == s2
        h1 = build_report(analysis=an1, metric="loss", mode="min")
        h2 = build_report(analysis=an2, metric="loss", mode="min")
        assert h1 == h2
        # and the diff agrees: nothing changed between the runs
        d = an1.diff(an2, metric="loss")
        assert d["changed"] == {} and not d["only_in_self"]


class TestReport:
    def test_report_renders_all_sections(self, tmp_path):
        jp = str(tmp_path / "events.jsonl")
        tp = str(tmp_path / "trace.json")
        mp = str(tmp_path / "metrics.jsonl")
        obs = Observability(trace=tp, metrics=mp, metrics_interval=60.0)
        res = run_scenario(crash_storm(n_trials=30, seed=1),
                           lambda: FIFOScheduler(metric="loss", mode="min"),
                           executor="concurrent", pool_devices=8,
                           obs=obs, token="an-report", journal_path=jp)
        obs.close(res.executor)
        html = build_report(journal_path=jp, trace_path=tp, metrics_path=mp,
                            metric="loss", mode="min")
        assert html.startswith("<!DOCTYPE html>")
        assert html.endswith("</body></html>\n")
        for needle in ("loss per trial", "Trial lifecycle", "Best config",
                       "scheduler decisions", "Control-plane metrics",
                       "<svg", "TERMINATED"):
            assert needle in html, needle
        # self-contained: no external fetches, no scripts
        assert "<script" not in html and "http://" not in html
        assert html.count("<svg") == html.count("</svg>")

    def test_report_cli_discovers_log_dir(self, tmp_path, capsys):
        from repro.launch.report import main
        jp = str(tmp_path / "events.jsonl")
        lg = JSONLLogger(jp)
        t = Trial({"lr": 0.1})
        for i in range(3):
            lg.on_result(t, Result(t.trial_id, i + 1, {"loss": 1.0 / (i + 1)}))
        t.set_status(TrialStatus.TERMINATED)
        lg.on_trial_complete(t)
        lg.close()
        assert main([str(tmp_path), "--mode", "min"]) == 0
        out = tmp_path / "report.html"
        assert out.exists() and "<svg" in out.read_text()

    def test_report_cli_requires_journal(self, tmp_path):
        from repro.launch.report import main
        with pytest.raises(SystemExit):
            main([str(tmp_path)])  # empty dir: no journal to be found


class TestLiveReporter:
    def _feed(self, rep, trial_id="t1", iters=3):
        t = Trial({"lr": 0.1}, trial_id=trial_id)
        for i in range(1, iters + 1):
            r = Result(t.trial_id, i, {"loss": 1.0 / i})
            t.record_result(r)
            rep.on_result(t, r)
        return t

    def test_renders_trial_table(self):
        buf = io.StringIO()
        rep = LiveReporter(metric="loss", stream=buf, interval_s=0.0)
        t = self._feed(rep)
        t.set_status(TrialStatus.TERMINATED)
        rep.on_trial_complete(t)
        rep.on_experiment_end([t])
        out = buf.getvalue()
        assert "t1" in out and "TERMINATED" in out
        assert "loss" in out and "0.333" in out
        assert "trials: 1" in out

    def test_throttle_caps_renders(self):
        from repro.core.clock import VirtualClock
        clock = VirtualClock()
        buf = io.StringIO()
        rep = LiveReporter(metric="loss", stream=buf, interval_s=5.0,
                           clock=clock)
        t = Trial({"lr": 0.1}, trial_id="t2")
        for i in range(1, 50):
            rep.on_result(t, Result(t.trial_id, i, {"loss": 1.0}))
        # clock never advanced past the interval: exactly the initial render
        assert buf.getvalue().count("trials: 1") == 1

    def test_fault_columns(self):
        buf = io.StringIO()
        rep = LiveReporter(metric="loss", stream=buf, interval_s=0.0)
        t = self._feed(rep, "t3")
        rep.on_event(t, TrialEvent(EventType.RESTARTED, t.trial_id,
                                   info={"num_failures": 1}))
        rep.on_experiment_end([t])
        assert "t3" in buf.getvalue()

    def test_max_rows_elision(self):
        buf = io.StringIO()
        rep = LiveReporter(metric="loss", stream=buf, interval_s=0.0,
                           max_rows=5)
        for i in range(9):
            self._feed(rep, f"trial-{i:02d}", iters=1)
        rep.on_experiment_end([])
        assert "more trial(s) not shown" in buf.getvalue()
