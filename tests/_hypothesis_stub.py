"""Optional-dependency shim: import hypothesis when available, otherwise
provide ``pytest.importorskip``-style fallbacks so test COLLECTION never
hard-errors — property tests degrade to individual skips and the rest of the
module keeps running.

Usage (instead of ``from hypothesis import given, settings, strategies as st``):

    from _hypothesis_stub import HAVE_HYPOTHESIS, given, settings, st
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped(*args, **kwargs):
                pass
            skipped.__name__ = getattr(fn, "__name__", "skipped")
            return skipped
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Accepts any strategy-building call chain at collection time."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
