"""Clock seam (DESIGN.md §7): WallClock veneer, VirtualClock park/advance
semantics, cooperative primitives (event/semaphore/queue/join), deadlock
detection, and the monotonic-deadline fix in BusDrivenExecutor."""
import queue
import threading

import pytest

from repro.core import (EventBus, EventType, TrialEvent, VirtualClock,
                        WallClock, get_default_clock, set_default_clock,
                        use_clock)
from repro.core.clock import Clock


class TestWallClock:
    def test_axes_and_primitives(self):
        wc = WallClock()
        assert wc.time() > 1_000_000_000
        m0 = wc.monotonic()
        wc.sleep(0.01)
        assert wc.monotonic() >= m0 + 0.005
        ev = wc.event()
        ev.set()
        assert ev.wait(0.1)
        sem = wc.semaphore(1)
        assert sem.acquire(blocking=False)
        assert not sem.acquire(blocking=False)
        q = queue.Queue()
        assert wc.queue_get(q, timeout=0.01) is None
        q.put(7)
        assert wc.queue_get(q, timeout=0.01) == 7

    def test_default_clock_roundtrip(self):
        base = get_default_clock()
        vc = VirtualClock()
        with use_clock(vc):
            assert get_default_clock() is vc
        assert get_default_clock() is base
        prev = set_default_clock(vc)
        assert prev is base
        assert set_default_clock(None) is vc
        assert get_default_clock() is base


class TestVirtualClockSingleThread:
    def test_sleep_advances_instantly(self):
        vc = VirtualClock()
        t0 = vc.monotonic()
        vc.sleep(3600.0)  # an hour of virtual time, microseconds of real
        assert vc.monotonic() == pytest.approx(t0 + 3600.0)
        assert vc.time() == pytest.approx(vc._epoch + t0 + 3600.0)

    def test_wait_for_timeout_moves_time(self):
        vc = VirtualClock()
        assert vc.wait_for(lambda: False, timeout=5.0) is False
        assert vc.monotonic() == pytest.approx(5.0)
        assert vc.wait_for(lambda: True, timeout=5.0) is True
        assert vc.monotonic() == pytest.approx(5.0)  # no time spent

    def test_queue_get_timeout_vs_item(self):
        vc = VirtualClock()
        q = queue.Queue()
        assert vc.queue_get(q, timeout=2.0) is None
        assert vc.monotonic() == pytest.approx(2.0)
        q.put("x")
        assert vc.queue_get(q, timeout=2.0) == "x"
        assert vc.monotonic() == pytest.approx(2.0)  # item was already there


class TestVirtualClockThreads:
    def test_sleep_ordering_is_deterministic(self):
        """Three sleepers with distinct deadlines wake in deadline order, and
        the creator thread observes the final time after joining them."""
        vc = VirtualClock()
        wake_order = []

        def sleeper(name, dt):
            with vc.running():
                vc.sleep(dt)
                wake_order.append((name, vc.monotonic()))

        threads = [threading.Thread(target=sleeper, args=(n, d), daemon=True)
                   for n, d in [("a", 3.0), ("b", 1.0), ("c", 2.0)]]
        for t in threads:
            t.start()
        for t in threads:
            assert vc.join_thread(t, timeout=10.0)
        assert [n for n, _ in wake_order] == ["b", "c", "a"]
        assert [round(at, 3) for _, at in wake_order] == [1.0, 2.0, 3.0]

    def test_event_wakes_virtual_waiter(self):
        vc = VirtualClock()
        ev = vc.event()
        seen = []

        def waiter():
            with vc.running():
                seen.append(ev.wait(timeout=100.0))

        t = threading.Thread(target=waiter, daemon=True)
        t.start()
        vc.sleep(1.0)  # both parked -> virtual second passes
        ev.set()
        assert vc.join_thread(t, timeout=10.0)
        assert seen == [True]
        assert vc.monotonic() < 100.0  # woke on the set, not the timeout

    def test_semaphore_park_and_release(self):
        vc = VirtualClock()
        sem = vc.semaphore(0)
        got = []

        def worker():
            with vc.running():
                got.append(sem.acquire(timeout=50.0))

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        vc.sleep(2.0)
        sem.release()
        assert vc.join_thread(t, timeout=10.0)
        assert got == [True]

    def test_join_timeout_returns_false(self):
        vc = VirtualClock()
        release = vc.event()

        def worker():
            with vc.running():
                release.wait(timeout=1000.0)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        assert vc.join_thread(t, timeout=5.0) is False  # virtual 5s, real ms
        release.set()
        assert vc.join_thread(t, timeout=10.0) is True

    def test_all_parked_without_deadline_is_deadlock(self):
        vc = VirtualClock()
        ev = vc.event()

        def worker():
            with vc.running():
                ev.wait()  # no timeout

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        # Creator thread parks forever too -> nobody can ever run again.
        with pytest.raises(RuntimeError, match="deadlock"):
            vc.wait_for(lambda: False, timeout=None)
        ev.set()  # let the worker exit
        vc.join_thread(t, timeout=5.0)


class TestBusOnClock:
    def test_publish_stamps_virtual_timestamp(self):
        vc = VirtualClock()
        bus = EventBus(clock=vc)
        vc.sleep(42.0)
        ev = bus.publish(TrialEvent(EventType.RESULT, "t0"))
        assert ev.timestamp == pytest.approx(vc._epoch + 42.0)
        # pre-stamped events are left alone
        ev2 = bus.publish(TrialEvent(EventType.RESULT, "t0", timestamp=7.0))
        assert ev2.timestamp == 7.0
        assert ev2.seq == ev.seq + 1

    def test_bus_get_parks_on_virtual_time(self):
        vc = VirtualClock()
        bus = EventBus(clock=vc)
        assert bus.get(timeout=3.0) is None
        assert vc.monotonic() == pytest.approx(3.0)

        def producer():
            with vc.running():
                vc.sleep(5.0)
                bus.publish(TrialEvent(EventType.RESULT, "t1"))

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        got = bus.get(timeout=60.0)  # must wake on publish at t=8, not t=63
        assert got is not None and got.trial_id == "t1"
        assert vc.monotonic() == pytest.approx(8.0)
        vc.join_thread(t, timeout=5.0)


class TestMonotonicDeadlines:
    def test_get_next_event_survives_wall_jump(self):
        """BusDrivenExecutor deadline math reads clock.monotonic(), so a wall
        timestamp jump (NTP step / suspended laptop) can neither instantly
        expire nor strand a bounded wait."""
        from repro.core import CheckpointManager, ObjectStore
        from repro.core.executor import BusDrivenExecutor

        class JumpyClock(Clock):
            """time() leaps hours ahead; monotonic() ticks honestly."""

            def __init__(self):
                self._mono = 0.0

            def time(self):
                return 1e9 + self._mono + 7200.0  # wall is 2h in the future

            def monotonic(self):
                self._mono += 0.05
                return self._mono

            def queue_get(self, q, timeout):
                # bounded waits land here; consume monotonic time only
                self._mono += min(timeout, 0.2)
                try:
                    return q.get_nowait()
                except Exception:
                    return None

            def kick(self, channel=None):
                pass

        clock = JumpyClock()
        ex = BusDrivenExecutor(lambda name: None,
                               CheckpointManager(ObjectStore()), clock=clock)
        ex._workers["t0"] = object()  # a live worker forces the wait loop
        start = clock._mono
        assert ex.get_next_event(timeout=1.0) is None
        elapsed = clock._mono - start
        # With time.time() arithmetic the 2h wall jump would have expired the
        # wait instantly (elapsed ~0) — monotonic math consumes the full budget.
        assert 0.9 <= elapsed <= 3.0
