"""End-to-end behaviour tests for the paper's system claims.

Paper claims validated here:
 1. Narrow-waist sufficiency — all six Table-1 algorithms drive REAL model
    training through the identical interface (function- or class-based).
 2. Intermediate-result control — early stopping, pause/resume, and PBT's
    clone-and-mutate all work through on_result/choose_trial_to_run alone.
 3. Scaling — trials parallelize up to the resource limit and trial slices
    come from the mesh SlicePool (the two-level scheduler analogue).
 4. Beyond-paper — the VmapExecutor preserves identical scheduling semantics
    while stepping all trials as one SPMD program.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (ASHAScheduler, CheckpointManager, FIFOScheduler,
                        HyperBandScheduler, MedianStoppingRule, ObjectStore,
                        PopulationBasedTraining, Resources, Trial,
                        TrialRunner, TrialStatus, SerialMeshExecutor,
                        TPESearcher, loguniform, run_experiments, uniform)
from repro.core.vmap_executor import VectorTrainableSpec, VmapExecutor
from repro.dist.submesh import SlicePool
from repro.models import ModelConfig
from repro.train.trainable import ModelTrainable, make_model_trainable

TINY = ModelConfig(arch_id="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=2, n_kv_heads=2, d_ff=128, vocab_size=64).validate()


def tiny_trainable(**kw):
    defaults = dict(batch=4, seq_len=32, steps_per_iter=2, total_steps=60)
    defaults.update(kw)
    return make_model_trainable(TINY, **defaults)


SCHEDULERS = {
    "fifo": lambda: FIFOScheduler(metric="loss", mode="min"),
    "asha": lambda: ASHAScheduler(metric="loss", mode="min", max_t=6,
                                  grace_period=2, reduction_factor=2),
    "hyperband": lambda: HyperBandScheduler(metric="loss", mode="min",
                                            max_t=4, eta=2),
    "median": lambda: MedianStoppingRule(metric="loss", mode="min",
                                         grace_period=2, min_samples_required=2),
    "pbt": lambda: PopulationBasedTraining(
        metric="loss", mode="min", perturbation_interval=2,
        hyperparam_mutations={"lr": loguniform(1e-4, 1e-1)}, seed=0),
}


@pytest.mark.parametrize("name", list(SCHEDULERS))
def test_all_six_algorithms_on_real_model_training(name):
    """Claim 1+2: every scheduler runs real JAX model training end-to-end
    through the same narrow interface."""
    an = run_experiments(
        tiny_trainable(),
        {"lr": loguniform(1e-3, 1e-1)},
        scheduler=SCHEDULERS[name](),
        num_samples=4,
        stop={"training_iteration": 6},
        total_devices=4,
        checkpoint_freq=1,
        seed=0,
    )
    assert an.best_value() is not None and np.isfinite(an.best_value())
    finished = [t for t in an.trials if t.status == TrialStatus.TERMINATED]
    assert finished, f"{name}: no trial finished"


def test_tpe_searcher_on_real_model():
    an = run_experiments(
        tiny_trainable(),
        searcher=TPESearcher({"lr": loguniform(1e-4, 1e-1)}, metric="loss",
                             mode="min", n_startup_trials=3, max_trials=6),
        stop={"training_iteration": 3},
        total_devices=4,
    )
    assert len(an.trials) == 6
    assert an.best_value() is not None


def test_pbt_clones_model_parameters():
    """Claim 2: PBT's exploit copies a donor's model params mid-training."""
    pbt = PopulationBasedTraining(
        metric="loss", mode="min", perturbation_interval=2,
        hyperparam_mutations={"lr": loguniform(1e-4, 1e-1)},
        quantile_fraction=0.34, seed=1)
    an = run_experiments(
        tiny_trainable(),
        {"lr": loguniform(1e-5, 1e-1)},
        scheduler=pbt, num_samples=4,
        stop={"training_iteration": 8},
        total_devices=4, checkpoint_freq=1, seed=1)
    assert pbt.n_exploits >= 1
    cloned = [t for t in an.trials if "cloned_from" in t.scheduler_state]
    assert cloned, "no trial recorded a clone event"


def test_slice_pool_placement():
    """Claim 3: trials acquire mesh slices; occupancy bounds parallelism."""
    pool = SlicePool(n_virtual=8)
    an = run_experiments(
        tiny_trainable(),
        {"lr": uniform(1e-3, 1e-2)},
        num_samples=6,
        stop={"training_iteration": 2},
        resources_per_trial=Resources(cpu=1, devices=4),
        total_devices=8,
        slice_pool=pool,
    )
    assert all(t.status == TrialStatus.TERMINATED for t in an.trials)
    assert pool.n_free == 8  # everything released


def test_checkpoint_pause_resume_exact():
    """Pause/resume through checkpoints is lossless for real train state."""
    cls = tiny_trainable()
    a = cls({"lr": 1e-2})
    for _ in range(3):
        ra = a.step()
    snap = a.save()
    b = cls({"lr": 1e-2})
    b.restore(snap)
    # stepping both should produce identical metrics (same data stream pos)
    ma, mb = a.step(), b.step()
    assert ma["step"] == mb["step"]
    np.testing.assert_allclose(ma["loss"], mb["loss"], rtol=1e-5)


def test_vmap_executor_matches_serial_semantics():
    """Claim 4: VmapExecutor yields per-trial results like the serial path."""
    def init_fn(seed, hypers):
        return {"x": jnp.asarray(1.0)}

    def step_fn(state, hypers):
        x = state["x"] * (1.0 - hypers["lr"])
        return {"x": x}, {"loss": x}

    spec = VectorTrainableSpec(init_fn, step_fn, ("lr",))
    ex = VmapExecutor(spec, CheckpointManager(ObjectStore()), n_lanes=4)
    runner = TrialRunner(FIFOScheduler(metric="loss", mode="min"), ex,
                         stopping_criteria={"training_iteration": 5})
    lrs = [0.1, 0.2, 0.3, 0.4]
    for lr in lrs:
        runner.add_trial(Trial({"lr": lr},
                               stopping_criteria={"training_iteration": 5}))
    trials = runner.run()
    for t, lr in zip(trials, lrs):
        expect = (1 - lr) ** 5
        np.testing.assert_allclose(t.last_result.value("loss"), expect, rtol=1e-5)
    assert all(t.training_iteration == 5 for t in trials)


def test_vmap_executor_with_asha_early_stops():
    def init_fn(seed, hypers):
        return {"x": jnp.asarray(1.0)}

    def step_fn(state, hypers):
        x = state["x"] * 0.9
        return {"x": x}, {"loss": x + hypers["q"]}

    spec = VectorTrainableSpec(init_fn, step_fn, ("q",))
    ex = VmapExecutor(spec, CheckpointManager(ObjectStore()), n_lanes=8)
    sched = ASHAScheduler(metric="loss", mode="min", max_t=16,
                          grace_period=2, reduction_factor=2)
    runner = TrialRunner(sched, ex, stopping_criteria={"training_iteration": 16})
    for i, q in enumerate(np.linspace(0, 2, 8)):
        runner.add_trial(Trial({"q": float(q)},
                               stopping_criteria={"training_iteration": 16}))
    trials = runner.run()
    total = sum(t.training_iteration for t in trials)
    assert total < 8 * 16, "ASHA must early-stop lanes"
    best = min(trials, key=lambda t: t.config["q"])
    assert best.training_iteration == 16


def test_experiment_analysis_table():
    an = run_experiments(
        tiny_trainable(), {"lr": uniform(1e-3, 1e-2)}, num_samples=2,
        stop={"training_iteration": 2}, total_devices=2)
    table = an.results_table()
    assert len(table) == 2
    assert all({"trial_id", "status", "iterations", "best", "config"} <= set(r)
               for r in table)
    assert an.total_iterations() == sum(r["iterations"] for r in table)
