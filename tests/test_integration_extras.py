"""Coverage for the §Perf-added features: pallas model paths, padded vocab,
dp_only strategy, scatter MoE, bf16 moments, batch-spec prefix fallback."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import (ModelConfig, MoEConfig, forward_encode, forward_train,
                          init_params)
from repro.models.moe import apply_moe_layer, init_moe_layer
from repro.train import adamw, make_train_state, make_train_step

V = 100


def lm_cfg(**kw):
    base = dict(arch_id="t", family="dense", n_layers=2, d_model=64,
                n_heads=2, n_kv_heads=2, d_ff=128, vocab_size=V)
    base.update(kw)
    return ModelConfig(**base).validate()


class TestPallasModelPaths:
    """Models with kernel_impl='pallas' / attn_impl='pallas' (interpret mode)
    must match the jnp paths — kernels as a real system layer."""

    def _compare(self, cfg_jnp, cfg_pl, atol=2e-3):
        params = init_params(jax.random.key(0), cfg_jnp)
        toks = jax.random.randint(jax.random.key(1), (2, 64), 0, V)
        a = forward_encode(params, {"tokens": toks}, cfg_jnp)
        b = forward_encode(params, {"tokens": toks}, cfg_pl)
        np.testing.assert_allclose(a, b, atol=atol)

    def test_flash_attention_in_model(self):
        cfg = lm_cfg(attn_impl="naive")
        self._compare(cfg, dataclasses.replace(cfg, attn_impl="pallas"))

    def test_flash_attention_swa_in_model(self):
        cfg = lm_cfg(attn_impl="naive", sliding_window=16)
        self._compare(cfg, dataclasses.replace(cfg, attn_impl="pallas"))

    def test_rwkv6_pallas_scan_in_model(self):
        cfg = lm_cfg(family="ssm", n_heads=2, rwkv_head_dim=32)
        self._compare(cfg, dataclasses.replace(cfg, kernel_impl="pallas"))

    def test_rglru_pallas_scan_in_model(self):
        cfg = lm_cfg(family="hybrid", n_layers=3, n_kv_heads=1,
                     block_pattern=("rglru", "rglru", "local_attn"),
                     sliding_window=16, rglru_d_rnn=64)
        self._compare(cfg, dataclasses.replace(cfg, kernel_impl="pallas"))


class TestPaddedVocab:
    def test_loss_matches_unpadded(self):
        import math
        cfg = lm_cfg(padded_vocab=128)
        params = init_params(jax.random.key(0), cfg)
        assert params["embed"]["tok"].shape[0] == 128
        toks = jax.random.randint(jax.random.key(1), (2, 32), 0, V)
        loss, m = forward_train(params, {"tokens": toks, "labels": toks}, cfg)
        # at init, CE ~= ln(real vocab), NOT ln(padded vocab)
        assert abs(float(loss) - math.log(V)) < 0.4

    def test_argmax_never_in_padding(self):
        cfg = lm_cfg(padded_vocab=128)
        params = init_params(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (2, 32), 0, V)
        logits = forward_encode(params, {"tokens": toks}, cfg)
        assert int(jnp.argmax(logits, -1).max()) < V

    def test_trains(self):
        cfg = lm_cfg(padded_vocab=128)
        opt = adamw(3e-3)
        state = make_train_state(jax.random.key(0), cfg, opt)
        step = jax.jit(make_train_step(cfg, opt))
        toks = jax.random.randint(jax.random.key(1), (4, 32), 0, V)
        batch = {"tokens": toks, "labels": toks}
        l0 = None
        for _ in range(10):
            state, m = step(state, batch)
            l0 = l0 or float(m["loss"])
        assert float(m["loss"]) < l0


class TestScatterMoE:
    def test_matches_einsum_no_drops(self):
        moe = MoEConfig(n_experts=4, top_k=2, d_expert=32, group_size=32,
                        capacity_factor=4.0)
        cfg_e = lm_cfg(family="moe", moe=moe)
        cfg_s = dataclasses.replace(
            cfg_e, moe=dataclasses.replace(moe, impl="scatter"))
        p = init_moe_layer(jax.random.key(0), cfg_e)
        x = jax.random.normal(jax.random.key(1), (2, 64, 64))
        out_e, aux_e = apply_moe_layer(p, x, cfg_e)
        out_s, aux_s = apply_moe_layer(p, x, cfg_s)
        np.testing.assert_allclose(out_e, out_s, atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(aux_e, aux_s, atol=1e-5)

    def test_scatter_with_drops_finite_grads(self):
        moe = MoEConfig(n_experts=4, top_k=2, d_expert=16, group_size=16,
                        capacity_factor=0.5, impl="scatter")
        cfg = lm_cfg(family="moe", moe=moe)
        p = init_moe_layer(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (1, 64, 64))

        def loss(p):
            o, a = apply_moe_layer(p, x, cfg)
            return (o ** 2).mean() + 0.01 * a

        g = jax.grad(loss)(p)
        for leaf in jax.tree_util.tree_leaves(g):
            assert jnp.isfinite(leaf).all()

    def test_rank_within_expert(self):
        from repro.models.moe import _rank_within_expert
        e = jnp.asarray([[2, 0, 2, 1, 0, 2]])
        rank = _rank_within_expert(e)
        np.testing.assert_array_equal(rank[0], [0, 0, 1, 0, 1, 2])


class TestBF16Moments:
    def test_state_dtype_and_convergence(self):
        cfg = lm_cfg()
        opt = adamw(3e-3, moment_dtype="bfloat16")
        state = make_train_state(jax.random.key(0), cfg, opt)
        for leaf in jax.tree_util.tree_leaves(state.opt_state["m"]):
            assert leaf.dtype == jnp.bfloat16
        step = jax.jit(make_train_step(cfg, opt))
        toks = jax.random.randint(jax.random.key(1), (4, 32), 0, V)
        batch = {"tokens": toks, "labels": toks}
        losses = []
        for _ in range(15):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] * 0.8


class TestStrategyAndBatchSpecs:
    def test_dp_only_replicates_tp(self):
        from repro.dist.sharding import sharding_strategy, spec_for
        class MockMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        class K:
            def __init__(self, key):
                self.key = key
        with sharding_strategy("dp_only"):
            spec = spec_for([K("mlp"), K("w_gate")], (64, 128), MockMesh())
            assert spec == P(("data",), None)  # no model-axis sharding

    def test_batch_prefix_fallback(self):
        from repro.dist.sharding import batch_specs, sharding_strategy
        class MockMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        batch = {"tokens": jax.ShapeDtypeStruct((32, 8), np.int32)}
        with sharding_strategy("dp_only"):
            specs = batch_specs(batch, MockMesh())
        # 32 doesn't divide 256 but divides 16: shard over ("data",)
        assert specs["tokens"] == P(("data",), None)

    def test_unknown_strategy_rejected(self):
        from repro.dist.sharding import sharding_strategy
        with pytest.raises(ValueError):
            with sharding_strategy("nope"):
                pass
