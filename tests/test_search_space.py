"""Search-space DSL: sampling bounds, grid expansion, TPE behaviour."""
import math

import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core.search.space import (Categorical, GridSearch, LogUniform,
                                     Normal, QRandInt, RandInt, Uniform,
                                     choice, grid_search, loguniform, normal,
                                     qrandint, randint, sample_from,
                                     sample_space, space_signature, uniform)
from repro.core.search.variants import (count_grid_variants, format_variant_tag,
                                        generate_variants)
from repro.core.search.tpe import TPESearcher
from repro.core.search.basic import GridSearcher, RandomSearcher


class TestDomains:
    def test_uniform_bounds_validation(self):
        with pytest.raises(ValueError):
            uniform(1.0, 1.0)
        with pytest.raises(ValueError):
            loguniform(0.0, 1.0)
        with pytest.raises(ValueError):
            randint(5, 5)

    @given(st.floats(-100, 100), st.floats(0.001, 100), st.integers(0, 2**31 - 1))
    @settings(max_examples=50, deadline=None)
    def test_uniform_within_bounds(self, low, width, seed):
        rng = np.random.default_rng(seed)
        d = uniform(low, low + width)
        v = d.sample(rng)
        assert low <= v < low + width

    @given(st.floats(1e-6, 1.0), st.floats(1.5, 1e6), st.integers(0, 2**31 - 1))
    @settings(max_examples=50, deadline=None)
    def test_loguniform_within_bounds(self, low, ratio, seed):
        rng = np.random.default_rng(seed)
        d = loguniform(low, low * ratio)
        v = d.sample(rng)
        assert low <= v <= low * ratio * (1 + 1e-9)

    @given(st.integers(-50, 50), st.integers(1, 100), st.integers(0, 2**31 - 1))
    @settings(max_examples=50, deadline=None)
    def test_randint_within_bounds(self, low, width, seed):
        rng = np.random.default_rng(seed)
        v = randint(low, low + width).sample(rng)
        assert low <= v < low + width
        assert isinstance(v, int)

    def test_choice_returns_member(self):
        rng = np.random.default_rng(0)
        vals = ["a", "b", "c"]
        for _ in range(20):
            assert choice(vals).sample(rng) in vals

    def test_qrandint_quantized(self):
        rng = np.random.default_rng(0)
        for _ in range(20):
            assert qrandint(0, 100, q=10).sample(rng) % 10 == 0


class TestSampleSpace:
    def test_constants_pass_through(self):
        rng = np.random.default_rng(0)
        out = sample_space({"a": 1, "b": "x", "c": uniform(0, 1)}, rng)
        assert out["a"] == 1 and out["b"] == "x" and 0 <= out["c"] < 1

    def test_nested(self):
        rng = np.random.default_rng(0)
        out = sample_space({"opt": {"lr": loguniform(1e-4, 1e-1)}}, rng)
        assert 1e-4 <= out["opt"]["lr"] <= 1e-1

    def test_sample_from_sees_other_values(self):
        rng = np.random.default_rng(0)
        out = sample_space({"a": uniform(1, 2),
                            "b": sample_from(lambda cfg: cfg["a"] * 10)}, rng)
        assert out["b"] == out["a"] * 10

    def test_grid_in_sample_space_raises(self):
        with pytest.raises(ValueError):
            sample_space({"a": grid_search([1, 2])}, np.random.default_rng(0))

    def test_signature_sorted_flat(self):
        sig = space_signature({"b": 1, "a": {"z": 2, "y": 3}})
        assert sig == ["a/y", "a/z", "b"]


class TestVariants:
    def test_grid_cross_product(self):
        space = {"lr": grid_search([0.1, 0.01, 0.001]),
                 "act": grid_search(["relu", "tanh"])}
        variants = list(generate_variants(space))
        assert len(variants) == 6 == count_grid_variants(space)
        assert len({(v["lr"], v["act"]) for v in variants}) == 6

    def test_num_samples_resamples_stochastic(self):
        space = {"lr": uniform(0, 1), "g": grid_search([1, 2])}
        variants = list(generate_variants(space, num_samples=3, seed=0))
        assert len(variants) == 6
        lrs = {v["lr"] for v in variants}
        assert len(lrs) == 6  # all distinct draws

    def test_deterministic_by_seed(self):
        space = {"lr": uniform(0, 1)}
        a = [v["lr"] for v in generate_variants(space, num_samples=5, seed=42)]
        b = [v["lr"] for v in generate_variants(space, num_samples=5, seed=42)]
        assert a == b

    def test_tag(self):
        assert "lr=0.1" in format_variant_tag({"lr": 0.1, "b": 2})


class TestSearchers:
    def test_random_exhausts(self):
        s = RandomSearcher({"lr": uniform(0, 1)}, max_trials=3)
        cfgs = [s.suggest(f"t{i}") for i in range(4)]
        assert cfgs[3] is None and all(c is not None for c in cfgs[:3])

    def test_grid_searcher(self):
        s = GridSearcher({"lr": grid_search([1, 2, 3])})
        got = [s.suggest(f"t{i}") for i in range(4)]
        assert [g["lr"] for g in got[:3]] == [1, 2, 3] and got[3] is None

    def test_tpe_concentrates_near_optimum(self):
        """TPE on f(x) = (x-0.3)^2 should sample near 0.3 after startup."""
        space = {"x": uniform(0.0, 1.0)}
        tpe = TPESearcher(space, metric="loss", mode="min",
                          n_startup_trials=8, seed=0)
        history = []
        for i in range(60):
            cfg = tpe.suggest(f"t{i}")
            loss = (cfg["x"] - 0.3) ** 2
            tpe.observe(f"t{i}", cfg, loss, final=True)
            history.append(cfg["x"])
        late = np.asarray(history[-20:])
        early = np.asarray(history[:8])
        assert np.abs(late - 0.3).mean() < np.abs(early - 0.3).mean()
        assert np.abs(late - 0.3).mean() < 0.15

    def test_tpe_categorical_and_int(self):
        space = {"c": choice(["good", "bad"]), "n": randint(1, 10)}
        tpe = TPESearcher(space, metric="loss", mode="min",
                          n_startup_trials=5, seed=0)
        for i in range(40):
            cfg = tpe.suggest(f"t{i}")
            loss = (0.0 if cfg["c"] == "good" else 1.0) + abs(cfg["n"] - 5) * 0.1
            tpe.observe(f"t{i}", cfg, loss, final=True)
        late = [tpe.suggest(f"x{i}") for i in range(10)]
        assert sum(1 for c in late if c["c"] == "good") >= 7
