"""Multi-device EXECUTION check (not just compile): a sharded train step runs
on 8 host-platform devices and produces numerics identical to single-device.

Runs in a subprocess because the device-count flag must be set before jax
initializes (the main test process keeps 1 device).
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
from functools import partial
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.dist import sharding as S
from repro.launch.mesh import make_mesh
from repro.train import adamw, make_train_state, make_train_step
from repro.data import DataConfig, SyntheticLMDataset

assert len(jax.devices()) == 8, jax.devices()
cfg = dataclasses.replace(get_config("smollm-135m").reduced(), remat=True)
opt = adamw(1e-3)
data = SyntheticLMDataset(DataConfig(global_batch=8, seq_len=64,
                                     vocab_size=cfg.vocab_size, noise=0.05))
batches = [ {k: jnp.asarray(v) for k, v in data.batch_at(i).items()} for i in range(5) ]

def run(mesh_shape, axes, strategy):
    mesh = make_mesh(mesh_shape, axes)
    state = make_train_state(jax.random.key(0), cfg, opt)
    with S.sharding_strategy(strategy), S.activation_policy(mesh):
        st_sh = S.make_shardings(S.train_state_specs(state, mesh, cfg), mesh)
        b_sh = S.make_shardings(S.batch_specs(batches[0], mesh), mesh)
        step = jax.jit(make_train_step(cfg, opt),
                       in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None))
        losses = []
        for b in batches:
            state, m = step(state, b)
            losses.append(float(m["loss"]))
    return losses

# single device reference
ref = run((1, 1), ("data", "model"), "fsdp_tp")
# 4-way data x 2-way model, FSDP+TP
fsdp_tp = run((4, 2), ("data", "model"), "fsdp_tp")
# 8-way pure data parallel
dp = run((4, 2), ("data", "model"), "dp_only")

print("ref     :", ["%.5f" % l for l in ref])
print("fsdp_tp :", ["%.5f" % l for l in fsdp_tp])
print("dp_only :", ["%.5f" % l for l in dp])
np.testing.assert_allclose(ref, fsdp_tp, rtol=2e-3)
np.testing.assert_allclose(ref, dp, rtol=2e-3)
assert ref[-1] < ref[0], "did not learn"
print("MULTIDEVICE_EXEC_OK")
"""


@pytest.mark.timeout(560)
def test_sharded_train_step_executes_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "MULTIDEVICE_EXEC_OK" in proc.stdout
