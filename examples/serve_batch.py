"""Batched serving with a KV cache: prefill a batch of prompts, then decode —
runs gemma-2b (reduced) and rwkv6 (reduced, O(1)-state) side by side.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init_params, param_count, prefill
from repro.train.serve_step import sample_tokens


def serve(arch: str, batch=2, prompt_len=32, new_tokens=12):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.key(0), cfg)
    prompts = jax.random.randint(jax.random.key(1), (batch, prompt_len),
                                 0, cfg.vocab_size)
    t0 = time.time()
    logits, caches = prefill(params, {"tokens": prompts}, cfg,
                             max_len=prompt_len + new_tokens)
    decode = jax.jit(lambda c, t, p: decode_step(params, c, t, p, cfg))
    tok = sample_tokens(logits, jax.random.key(2), temperature=0.8)
    out = [tok]
    for i in range(new_tokens - 1):
        logits, caches = decode(caches, tok, jnp.asarray(prompt_len + i))
        tok = sample_tokens(logits, jax.random.fold_in(jax.random.key(2), i), 0.8)
        out.append(tok)
    wall = time.time() - t0
    gen = jnp.stack(out, axis=1)
    state_desc = ("recurrent state (O(1) in context)" if cfg.family == "ssm"
                  else f"KV cache (cap {prompt_len + new_tokens})")
    print(f"{arch:24s} {param_count(params):>9,} params  {state_desc}")
    print(f"  generated {gen.shape} in {wall:.1f}s; row0: {gen[0].tolist()}")


if __name__ == "__main__":
    serve("gemma-2b")
    serve("rwkv6-1.6b")
