"""Quickstart — the paper's §4.3 minimal example, runnable as-is.

A function-based trainable (cooperative API), a 3x2 grid search, and an
asynchronous-HyperBand scheduler:

    PYTHONPATH=src python examples/quickstart.py

For real sweeps on a device mesh, the launcher adds placement and the
elastic control plane on top of the same call, e.g.

    PYTHONPATH=src python -m repro.launch.tune --arch smollm-135m --reduced \\
        --scheduler asha --executor process --elastic greedy

which lets ASHA survivors absorb the mesh slices of early-stopped trials at
their next checkpoint boundary (and `--lookahead K` pipelines K results per
worker on FIFO throughput sweeps).  See DESIGN.md §6.
"""
import numpy as np

from repro.core import ASHAScheduler, grid_search, run_experiments


def my_train_func(tune):
    """An ordinary training loop + three cooperative calls (paper Fig. 2a)."""
    lr = tune.params["lr"]
    activation = tune.params["activation"]
    # toy objective: quadratic in log-lr, 'relu' slightly better than 'tanh'
    quality = (np.log10(lr) + 2.0) ** 2 + (0.0 if activation == "relu" else 0.05)
    x = 1.0
    for step in range(50):
        x *= 0.9
        if tune.should_checkpoint():
            tune.record_checkpoint({"x": x, "step": step})
        tune.report(loss=quality + x)


if __name__ == "__main__":
    analysis = run_experiments(
        my_train_func,
        {
            "lr": grid_search([0.01, 0.001, 0.0001]),
            "activation": grid_search(["relu", "tanh"]),
        },
        scheduler=ASHAScheduler(metric="loss", mode="min", max_t=50,
                                grace_period=5, reduction_factor=2),
        stop={"training_iteration": 50},
        verbose=True,
    )
    print("\nbest config:", analysis.best_config())
    print("best loss:  ", round(analysis.best_value(), 4))
    for row in analysis.results_table():
        print(f"  {row['trial_id']}: {row['status']:10s} "
              f"iters={row['iterations']:2d} best={row['best']:.4f} {row['config']}")
