"""End-to-end driver: hyperparameter search over REAL transformer training.

Tunes (lr, weight_decay, warmup) of a llama-architecture model (SmolLM-135M
family) on the synthetic LM pipeline with ASHA early stopping, then reruns the
best config to convergence.  Reduced scale by default so it completes on CPU
in a few minutes; ``--full`` uses the real 135M config (TPU-scale).

    PYTHONPATH=src python examples/tune_transformer.py [--full] [--samples 8]
"""
import argparse

from repro.configs import get_config
from repro.core import ASHAScheduler, loguniform, randint, run_experiments
from repro.train.trainable import make_model_trainable


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="full 135M config")
    ap.add_argument("--samples", type=int, default=8)
    ap.add_argument("--max-iters", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    if not args.full:
        cfg = cfg.reduced()
    trainable = make_model_trainable(
        cfg, batch=8, seq_len=64, steps_per_iter=4,
        total_steps=args.max_iters * 4)

    space = {
        "lr": loguniform(3e-4, 3e-2),
        "weight_decay": loguniform(1e-3, 3e-1),
        "warmup": randint(2, 20),
    }
    analysis = run_experiments(
        trainable, space,
        scheduler=ASHAScheduler(metric="loss", mode="min",
                                max_t=args.max_iters, grace_period=3,
                                reduction_factor=3),
        num_samples=args.samples,
        stop={"training_iteration": args.max_iters},
        verbose=True,
    )
    print("\n== search results ==")
    for row in analysis.results_table():
        cfgs = {k: round(v, 5) if isinstance(v, float) else v
                for k, v in row["config"].items() if k != "model_cfg"}
        print(f"  {row['trial_id']}: iters={row['iterations']:2d} "
              f"best={row['best']:.4f} {cfgs}")
    best = analysis.best_config()
    print("\nbest:", {k: v for k, v in best.items() if k != "model_cfg"})

    print("\n== retraining best config to completion ==")
    tr = trainable(best)
    for i in range(args.max_iters * 2):
        m = tr.step()
        if i % 4 == 0:
            print(f"  iter {i:3d}: loss={m['loss']:.4f} acc={m['accuracy']:.3f} "
                  f"({m['steps_per_s']:.1f} steps/s)")
    print(f"final loss: {m['loss']:.4f}")


if __name__ == "__main__":
    main()
