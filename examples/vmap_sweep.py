"""Beyond-paper: a hyperparameter sweep as ONE SPMD program.

Eight trials of a small LM are stacked into a single vmapped train step and
scheduled by ASHA — identical scheduling semantics to the serial executor, at
a multiple of the trial throughput (benchmarks/bench_vmap.py quantifies it).

    PYTHONPATH=src python examples/vmap_sweep.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ASHAScheduler, CheckpointManager, ObjectStore, Trial,
                        TrialRunner)
from repro.core.vmap_executor import VectorTrainableSpec, VmapExecutor
from repro.data import DataConfig, SyntheticLMDataset
from repro.models import ModelConfig, forward_train, init_params

CFG = ModelConfig(arch_id="sweep", family="dense", n_layers=2, d_model=64,
                  n_heads=2, n_kv_heads=2, d_ff=128, vocab_size=128).validate()


def main():
    data = SyntheticLMDataset(DataConfig(global_batch=4, seq_len=32,
                                         vocab_size=CFG.vocab_size, noise=0.05))
    batches = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[jax.tree_util.tree_map(jnp.asarray, data.batch_at(i)) for i in range(8)])

    def init_fn(seed, hypers):
        params = init_params(jax.random.key(seed), CFG)
        mom = jax.tree_util.tree_map(jnp.zeros_like, params)
        return {"p": params, "m": mom, "i": jnp.zeros((), jnp.int32)}

    def step_fn(state, hypers):
        batch = jax.tree_util.tree_map(lambda x: x[state["i"] % 8], batches)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: forward_train(p, batch, CFG), has_aux=True)(state["p"])
        m = jax.tree_util.tree_map(lambda mo, g: 0.9 * mo + g, state["m"], grads)
        p = jax.tree_util.tree_map(lambda w, mo: w - hypers["lr"] * mo,
                                   state["p"], m)
        return {"p": p, "m": m, "i": state["i"] + 1}, {"loss": metrics["loss"]}

    spec = VectorTrainableSpec(init_fn, step_fn, ("lr",), steps_per_iter=2)
    executor = VmapExecutor(spec, CheckpointManager(ObjectStore()), n_lanes=8)
    runner = TrialRunner(
        ASHAScheduler(metric="loss", mode="min", max_t=10, grace_period=3,
                      reduction_factor=2),
        executor, stopping_criteria={"training_iteration": 10})
    for i, lr in enumerate(np.logspace(-3.5, -0.5, 8)):
        runner.add_trial(Trial({"lr": float(lr), "init_seed": i},
                               stopping_criteria={"training_iteration": 10}))
    trials = runner.run()
    print("lane-stacked ASHA sweep (8 trials, one vmapped step):")
    for t in trials:
        print(f"  {t.trial_id}: lr={t.config['lr']:.5f} iters={t.training_iteration:2d} "
              f"best={t.best_value('loss', 'min'):.4f} [{t.status.value}]")
    budget = sum(t.training_iteration for t in trials)
    print(f"budget spent: {budget}/{8*10} iterations "
          f"({100*budget/80:.0f}% — ASHA early-stopped the rest)")


if __name__ == "__main__":
    main()
