"""Population-Based Training on a real model: the scheduler clones the best
trial's *model parameters* mid-training and perturbs its learning rate — the
paper's §3 "clone or mutate model parameters in the middle of training"
requirement, exercised through the narrow interface alone.

    PYTHONPATH=src python examples/pbt_population.py
"""
from repro.configs import get_config
from repro.core import PopulationBasedTraining, loguniform, run_experiments
from repro.train.trainable import make_model_trainable


def main():
    cfg = get_config("smollm-135m").reduced()
    trainable = make_model_trainable(cfg, batch=8, seq_len=64,
                                     steps_per_iter=3, total_steps=60)
    pbt = PopulationBasedTraining(
        metric="loss", mode="min",
        perturbation_interval=4,
        hyperparam_mutations={"lr": loguniform(1e-4, 1e-1)},
        quantile_fraction=0.25,
        seed=0,
    )
    analysis = run_experiments(
        trainable,
        {"lr": loguniform(1e-5, 1e-1)},  # deliberately wide: some trials start badly
        scheduler=pbt,
        num_samples=6,
        stop={"training_iteration": 16},
        checkpoint_freq=1,
        verbose=True,
    )
    print(f"\nexploit/explore events: {pbt.n_exploits}")
    for t in analysis.trials:
        lr = t.config["lr"]
        cloned = t.scheduler_state.get("cloned_from", "-")
        print(f"  {t.trial_id}: final lr={lr:.5f} best={t.best_value('loss','min'):.4f} "
              f"cloned_from={cloned}")
    print("best loss:", round(analysis.best_value(), 4))


if __name__ == "__main__":
    main()
