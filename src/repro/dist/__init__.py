"""repro.dist — trial placement and sharding (DESIGN.md §2–§3).

Two layers:

* :mod:`repro.dist.submesh` — the ``SlicePool``: carves the global device list
  into contiguous per-trial sub-meshes (the cluster-placement analogue of the
  paper's two-level scheduler).
* :mod:`repro.dist.sharding` — the rule-based PartitionSpec engine: maps
  parameter/optimizer/batch/cache pytrees onto a mesh via named rule templates
  with head-aware and divisibility fallbacks.
"""
from . import sharding, submesh
from .sharding import (activation_policy, batch_specs, cache_specs, constrain,
                       make_shardings, param_specs, sharding_strategy, spec_for,
                       train_state_specs)
from .submesh import MeshSlice, SlicePool

__all__ = [
    "sharding", "submesh", "SlicePool", "MeshSlice",
    "spec_for", "param_specs", "train_state_specs", "batch_specs",
    "cache_specs", "make_shardings", "constrain", "sharding_strategy",
    "activation_policy",
]
