"""Rule-based PartitionSpec engine (DESIGN.md §3).

Parameters are plain nested dicts, so placement is decided from the *tree
path* of each leaf: ``spec_for(path, shape, mesh)`` looks the leaf name up in
a table of named rule templates and resolves abstract roles onto concrete
mesh axes.

Roles (resolved per active strategy, see ``sharding_strategy``):

* ``"fsdp"``  — shard over the data axes (all mesh axes except ``model``),
  expressed as an axis *tuple* so multi-pod meshes map to ``("pod","data")``.
* ``"tp"``    — shard over the ``model`` axis (tensor parallelism).
* ``"expert"``— shard over the ``model`` axis (expert parallelism; MoE layers
  trade TP for EP, so both roles target the same axis).
* ``None``    — replicate this dim.

A rule template names roles for the *trailing* dims of a leaf; leading dims
(the scan-stacked layer axis) replicate.  Each leaf carries an ordered list
of templates; the first whose every sharded dim is divisible by its axes'
total size wins.  If none fits, the first template is taken and the failing
dims are dropped to ``None`` individually — the "divisibility-drop" contract:
sharding degrades per-dim, it never errors and never produces an invalid
spec.

Head-aware attention rules (``_head_aware_rules``) additionally refuse to
tensor-shard q/k/v/o projections when ``n_heads`` / ``n_kv_heads`` do not
divide the model-axis size — splitting inside a head would break GQA/MQA
grouping, so such projections fall back to FSDP-only.

Strategies: ``fsdp_tp`` (default; FSDP over data axes + TP over model) and
``dp_only`` (model axis unused — pure data parallelism; the batch may then
also shard over the idle model axis).
"""
from __future__ import annotations

import contextlib
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "spec_for", "param_specs", "train_state_specs", "batch_specs",
    "cache_specs", "make_shardings", "constrain", "sharding_strategy",
    "activation_policy", "STRATEGIES",
]

STRATEGIES = ("fsdp_tp", "dp_only")

_MODEL_AXIS = "model"

# -- strategy / activation-policy context ------------------------------------------

_state = {"strategy": "fsdp_tp", "act_mesh": None, "seq_parallel": False}


@contextlib.contextmanager
def sharding_strategy(name: str):
    """Select the active strategy for every spec_* call in the block."""
    if name not in STRATEGIES:
        raise ValueError(f"unknown sharding strategy {name!r}; "
                         f"choose from {STRATEGIES}")
    prev = _state["strategy"]
    _state["strategy"] = name
    try:
        yield
    finally:
        _state["strategy"] = prev


@contextlib.contextmanager
def activation_policy(mesh, seq_parallel: bool = False):
    """Enable ``constrain`` inside model code: activations traced in the block
    are pinned to batch (and optionally sequence) sharding on ``mesh``."""
    prev = (_state["act_mesh"], _state["seq_parallel"])
    _state["act_mesh"] = mesh
    _state["seq_parallel"] = bool(seq_parallel)
    try:
        yield
    finally:
        _state["act_mesh"], _state["seq_parallel"] = prev


# -- mesh helpers -------------------------------------------------------------------

def _axis_sizes(mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def _data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != _MODEL_AXIS)


def _model_size(mesh) -> int:
    return _axis_sizes(mesh).get(_MODEL_AXIS, 1)


def _resolve_role(role: Optional[str], mesh):
    """Map an abstract role to a PartitionSpec entry under the active strategy."""
    strategy = _state["strategy"]
    if role is None:
        return None
    if role == "fsdp":
        axes = _data_axes(mesh)
        return axes if axes else None
    if role in ("tp", "expert"):
        if strategy == "dp_only" or _MODEL_AXIS not in mesh.axis_names:
            return None
        return _MODEL_AXIS
    raise ValueError(f"unknown sharding role {role!r}")


def _entry_size(entry, sizes: Dict[str, int]) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        return math.prod(sizes.get(a, 1) for a in entry)
    return sizes.get(entry, 1)


# -- rule tables --------------------------------------------------------------------

# name -> ordered fallback templates (roles for trailing dims).  No template
# replicates everything: when none fits, the divisibility-drop fallback takes
# the FIRST template and nulls failing dims individually, which preserves any
# dim that still divides (e.g. TP survives an odd fan-out).
_RULES: Dict[str, List[Tuple[Optional[str], ...]]] = {
    # embeddings: vocab over model first, fall back to feature-only FSDP
    "tok": [("tp", "fsdp"), (None, "fsdp")],
    # untied LM head (d_model, vocab)
    "w": [("fsdp", "tp"), ("fsdp", None)],
    # gated MLP
    "w_gate": [("fsdp", "tp"), ("fsdp", None)],
    "w_up": [("fsdp", "tp"), ("fsdp", None)],
    "w_down": [("tp", "fsdp"), (None, "fsdp")],
    # plain MLP
    "w_in": [("fsdp", "tp"), ("fsdp", None)],
    "w_out": [("tp", "fsdp"), (None, "fsdp")],
    # MoE router (d_model, n_experts)
    "router": [("fsdp", None)],
    # frontend projections
    "proj": [("fsdp", "tp"), ("fsdp", None)],
}

# expert-parallel overrides when "experts" appears on the path:
# (n_experts, d_model, d_expert) for w_gate/w_up, (n_experts, d_expert, d_model)
# for w_down — experts over the model axis, fan-in FSDP over data.
_EXPERT_RULES: Dict[str, List[Tuple[Optional[str], ...]]] = {
    "w_gate": [("expert", "fsdp", None), (None, "fsdp", None)],
    "w_up": [("expert", "fsdp", None), (None, "fsdp", None)],
    "w_down": [("expert", None, "fsdp"), (None, None, "fsdp")],
}

_ATTN_NAMES = ("wq", "wk", "wv", "wo")


def _head_aware_rules(name: str, path_keys: Sequence[str], cfg,
                      mesh) -> List[Tuple[Optional[str], ...]]:
    """Templates for attention projections, refusing TP when heads don't
    divide the model axis (splitting inside a head breaks GQA grouping)."""
    msize = _model_size(mesh)
    if name in ("wq", "wo"):
        heads = cfg.n_heads
    else:  # wk / wv
        heads = cfg.n_kv_heads or cfg.n_heads
    splittable = msize <= 1 or heads % msize == 0
    if name == "wo":  # (n_heads*hd, d_model): heads on the fan-in dim
        return [("tp", "fsdp")] if splittable else [(None, "fsdp")]
    return [("fsdp", "tp")] if splittable else [("fsdp", None)]


def _path_keys(path: Sequence[Any]) -> List[str]:
    keys = []
    for k in path:
        if hasattr(k, "key"):
            keys.append(str(k.key))
        elif hasattr(k, "name"):
            keys.append(str(k.name))
        elif hasattr(k, "idx"):
            keys.append(str(k.idx))
        else:
            keys.append(str(k))
    return keys


def _rules_for(keys: List[str], shape: Tuple[int, ...], cfg,
               mesh) -> List[Tuple[Optional[str], ...]]:
    name = keys[-1] if keys else ""
    if len(shape) <= 1:  # scalars, norm scales, biases: replicate
        return [()]
    if "experts" in keys and name in _EXPERT_RULES:
        return _EXPERT_RULES[name]
    if name in _ATTN_NAMES and cfg is not None:
        return _head_aware_rules(name, keys, cfg, mesh)
    if name in _ATTN_NAMES:  # no cfg: assume divisible
        return [("tp", "fsdp")] if name == "wo" else [("fsdp", "tp")]
    if name in _RULES:
        return _RULES[name]
    # unknown >=2-dim leaf (recurrent-block params etc.): generic matmul rule
    return [("fsdp", "tp"), ("fsdp", None)]


def spec_for(path: Sequence[Any], shape: Tuple[int, ...], mesh,
             cfg=None) -> P:
    """PartitionSpec for one leaf, by path-based rule lookup + divisibility
    fallback.  ``path`` is a jax key path (or anything with .key/.name)."""
    keys = _path_keys(path)
    shape = tuple(shape)
    if not shape:
        return P()
    sizes = _axis_sizes(mesh)
    templates = _rules_for(keys, shape, cfg, mesh)

    def resolve(rule):
        """Roles for trailing dims -> full per-dim entries, or None if a
        sharded dim is not divisible."""
        entries: List[Any] = [None] * (len(shape) - len(rule))
        entries += [_resolve_role(r, mesh) for r in rule]
        for dim, entry in enumerate(entries):
            if entry is not None and shape[dim] % _entry_size(entry, sizes):
                return None
        return entries

    chosen = None
    for rule in templates:
        if len(rule) > len(shape):
            continue
        resolved = resolve(rule)
        if resolved is not None:
            chosen = resolved
            break
    if chosen is None:
        # divisibility-drop: take the first template that fits the leaf's
        # rank, null out failing dims individually
        rule = next((r for r in templates if len(r) <= len(shape)), ())
        entries = [None] * (len(shape) - len(rule))
        entries += [_resolve_role(r, mesh) for r in rule]
        chosen = [e if (e is None or shape[d] % _entry_size(e, sizes) == 0)
                  else None for d, e in enumerate(entries)]
    return P(*chosen)


# -- tree-level spec builders -------------------------------------------------------

def param_specs(params: Any, mesh, cfg=None) -> Any:
    """PartitionSpec tree mirroring a parameter pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for(path, leaf.shape, mesh, cfg), params)


def train_state_specs(state: Any, mesh, cfg=None) -> Any:
    """Specs for a full TrainState (params + optimizer moments + counters).

    Optimizer moments mirror the param tree under an ``m``/``v``/``mom``
    prefix, so the same path rules apply; scalar counters replicate.
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for(path, getattr(leaf, "shape", ()), mesh, cfg),
        state)


def _batch_axis_candidates(mesh) -> List[Tuple[str, ...]]:
    """Ordered axis tuples to try for the batch dim: the full data-parallel
    tuple first, then right-trimmed prefixes (the "prefix fallback")."""
    axes = [a for a in _data_axes(mesh) if _axis_sizes(mesh).get(a, 1) > 1]
    if _state["strategy"] == "dp_only" and _model_size(mesh) > 1:
        axes = axes + [_MODEL_AXIS]  # model axis is idle: use it for DP
    cands = []
    while axes:
        cands.append(tuple(axes))
        axes = axes[:-1]
    cands.append(())
    return cands


def _batch_dim_entry(n: int, mesh):
    sizes = _axis_sizes(mesh)
    for cand in _batch_axis_candidates(mesh):
        if not cand:
            return None
        if n % math.prod(sizes.get(a, 1) for a in cand) == 0:
            return cand
    return None


def batch_specs(batch: Any, mesh) -> Any:
    """Shard dim 0 (the global batch) over the data axes; replicate the rest.
    Axes of size 1 are omitted (no sharding benefit on a trivial mesh)."""

    def one(leaf) -> P:
        shape = tuple(getattr(leaf, "shape", ()))
        if not shape:
            return P()
        return P(_batch_dim_entry(shape[0], mesh), *([None] * (len(shape) - 1)))

    return jax.tree_util.tree_map(one, batch)


def cache_specs(caches: Any, mesh, global_batch: int) -> Any:
    """Decode-cache specs: shard the batch dim over the data axes.

    Cache leaves are segment-stacked, so the batch dim (when a leaf has one)
    is always dim 1: (n_layers, B, cap, K, hd) for k/v, (n_layers, B, ...)
    for recurrent states.  ``global_batch`` is required to match as a
    cross-check — layer-stacking means dim sizes alone are ambiguous (a
    position ring (n_layers, cap) could collide).  ``kpos`` rings carry no
    batch dim and replicate by name.
    """

    def one(path, leaf) -> P:
        shape = tuple(getattr(leaf, "shape", ()))
        if not shape:
            return P()
        entries: List[Any] = [None] * len(shape)
        name = _path_keys(path)[-1] if path else ""
        if name != "kpos" and len(shape) >= 2 and shape[1] == global_batch:
            entries[1] = _batch_dim_entry(shape[1], mesh)
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, caches)


def make_shardings(specs: Any, mesh) -> Any:
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


# -- in-model activation constraints ------------------------------------------------

def constrain(x: jax.Array) -> jax.Array:
    """Pin an activation's sharding under the ambient ``activation_policy``.

    No policy active -> identity, so model code is unconditionally
    instrumented and single-device tests pay nothing.  Batch dim shards over
    the data axes; the sequence dim additionally shards over ``model`` when
    the policy enables sequence parallelism — each only if divisible.
    """
    mesh = _state["act_mesh"]
    if mesh is None:
        return x
    shape = x.shape
    if not shape:
        return x
    entries: List[Any] = [_batch_dim_entry(shape[0], mesh)]
    entries += [None] * (len(shape) - 1)
    if (_state["seq_parallel"] and len(shape) >= 2
            and _state["strategy"] != "dp_only"
            and _model_size(mesh) > 1 and shape[1] % _model_size(mesh) == 0):
        entries[1] = _MODEL_AXIS
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
