"""SlicePool — contiguous sub-mesh allocation for trials (DESIGN.md §2).

The runner treats TPU devices like the paper treats cluster nodes: a trial
asks for ``Resources(devices=k)`` and the executor hands it a ``MeshSlice``
of ``k`` contiguous devices from the pool.  Contiguity matters on real
hardware (ICI locality on a torus); here it is first-fit over a linearized
device order with coalescing on release, i.e. the classic free-list
allocator, which keeps fragmentation bounded for the power-of-two slice
sizes trials actually request.

Two modes:

* device mode — ``SlicePool(devices=[...])`` allocates real ``jax.Device``
  objects; ``MeshSlice.make_mesh`` builds a ``jax.sharding.Mesh`` over them.
* virtual mode — ``SlicePool(n_virtual=256)`` tracks capacity only (CPU
  testing / benchmarks); ``make_mesh`` tiles the host's devices to the
  requested size so mesh-shape logic stays exercised on one CPU.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence, Tuple

__all__ = ["MeshSlice", "SlicePool"]


def balanced_shape(size: int, n_axes: int) -> Tuple[int, ...]:
    """Factor ``size`` into ``n_axes`` dims, as square as possible, largest
    first — e.g. 8 over 2 axes -> (4, 2).  Used when a trial mesh has more
    axis names than the slice has natural dimensions."""
    if n_axes <= 0:
        raise ValueError("n_axes must be >= 1")
    dims = [1] * n_axes
    rem = size
    # peel prime factors largest-first onto the currently-smallest axis
    factors: List[int] = []
    d = 2
    while d * d <= rem:
        while rem % d == 0:
            factors.append(d)
            rem //= d
        d += 1
    if rem > 1:
        factors.append(rem)
    for f in sorted(factors, reverse=True):
        dims[dims.index(min(dims))] *= f
    return tuple(sorted(dims, reverse=True))


@dataclass(frozen=True)
class MeshSlice:
    """A contiguous range of the pool's device order.

    ``devices`` is None in virtual mode.  Slices are value objects — the pool
    identifies them by ``(start, size)`` on release.
    """
    start: int
    size: int
    devices: Optional[Tuple[Any, ...]] = None

    def make_mesh(self, axis_names: Sequence[str],
                  shape: Optional[Tuple[int, ...]] = None):
        """A real ``jax.sharding.Mesh`` over this slice's devices.

        ``shape`` defaults to a balanced factorization of ``size`` over
        ``axis_names`` (one axis -> ``(size,)``).  In virtual mode the host's
        devices are tiled to ``size`` so the mesh is still constructible on a
        single-CPU test machine.
        """
        import jax
        import numpy as np

        axis_names = tuple(axis_names)
        if shape is None:
            shape = balanced_shape(self.size, len(axis_names))
        if math.prod(shape) != self.size:
            raise ValueError(f"mesh shape {shape} does not cover slice of "
                             f"size {self.size}")
        if self.devices is not None:
            devs = list(self.devices)
        else:
            host = jax.devices()
            devs = (host * ((self.size + len(host) - 1) // len(host)))[: self.size]
        return jax.sharding.Mesh(np.asarray(devs, dtype=object).reshape(shape),
                                 axis_names)


class SlicePool:
    """First-fit contiguous allocator over a linear device order.

    Free ranges are kept sorted by start offset; ``release`` merges with
    adjacent free ranges so a fully-drained pool always coalesces back to one
    range (``can_fit(n_total)`` is the invariant the tests check).
    """

    def __init__(self, devices: Optional[Sequence[Any]] = None,
                 n_virtual: Optional[int] = None):
        if (devices is None) == (n_virtual is None):
            raise ValueError("pass exactly one of devices= or n_virtual=")
        self._devices = tuple(devices) if devices is not None else None
        self.n_total = len(self._devices) if self._devices is not None else int(n_virtual)
        if self.n_total <= 0:
            raise ValueError("pool must hold at least one device")
        self._free: List[Tuple[int, int]] = [(0, self.n_total)]  # (start, size)
        self._held: dict = {}  # start -> size, for double-release detection
        self.n_acquired_total = 0  # lifetime acquire count (occupancy metrics)
        self.n_resized_total = 0   # lifetime elastic resize count

    # -- queries -----------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return sum(size for _, size in self._free)

    def can_fit(self, size: int) -> bool:
        if size <= 0:
            raise ValueError(f"slice size must be positive, got {size}")
        return any(sz >= size for _, sz in self._free)

    def fragments(self) -> int:
        """Post-coalesce holes: disjoint free ranges beyond the first.

        Release always coalesces adjacent free ranges, so a single free range
        (wherever it sits) can host any contiguous request up to ``n_free`` —
        that is a *healthy* pool and counts as 0.  Each additional disjoint
        range is a hole that makes ``largest_free_block() < n_free``, i.e.
        real external fragmentation the broker and Console report on.
        """
        return max(0, len(self._free) - 1)

    def utilization(self) -> float:
        """Fraction of devices currently allocated to trials (0.0 - 1.0)."""
        return (self.n_total - self.n_free) / self.n_total

    def largest_free_block(self) -> int:
        """Largest contiguous request that would succeed right now."""
        return max((size for _, size in self._free), default=0)

    def can_resize(self, sl: MeshSlice, new_size: int) -> bool:
        """Would ``resize(sl, new_size)`` succeed?  Shrinks always do; grows
        need a block of ``new_size`` in the free list *as it looks with
        ``sl`` released* — relocation frees the old range first, so the old
        slice coalesced with its free neighbours counts too."""
        if self._held.get(sl.start) != sl.size:
            raise ValueError(f"slice [{sl.start}, {sl.start + sl.size}) is not "
                             "currently held")
        if new_size <= 0:
            return False
        if new_size <= sl.size:
            return True
        merged = sl.size
        for start, size in self._free:
            if start + size == sl.start or start == sl.start + sl.size:
                merged += size
            elif size >= new_size:
                return True  # relocation into a disjoint free block
        return merged >= new_size

    # -- allocate / release -------------------------------------------------------
    def acquire(self, size: int) -> MeshSlice:
        if size <= 0:
            raise ValueError(f"slice size must be positive, got {size}")
        for i, (start, sz) in enumerate(self._free):
            if sz >= size:
                if sz == size:
                    del self._free[i]
                else:
                    self._free[i] = (start + size, sz - size)
                self._held[start] = size
                self.n_acquired_total += 1
                devs = (self._devices[start:start + size]
                        if self._devices is not None else None)
                return MeshSlice(start=start, size=size, devices=devs)
        raise RuntimeError(
            f"SlicePool cannot fit a slice of {size} devices "
            f"(free={self.n_free}/{self.n_total} in {len(self._free)} fragments)")

    def release(self, sl: MeshSlice) -> None:
        if self._held.get(sl.start) != sl.size:
            raise ValueError(f"slice [{sl.start}, {sl.start + sl.size}) is not "
                             "currently held (double release?)")
        del self._held[sl.start]
        self._insert_free(sl.start, sl.size)

    def _insert_free(self, start: int, size: int) -> None:
        """Insert a freed range sorted, then coalesce with neighbours."""
        import bisect
        idx = bisect.bisect_left(self._free, (start, size))
        self._free.insert(idx, (start, size))
        merged: List[Tuple[int, int]] = []
        for s, sz in self._free:
            if merged and merged[-1][0] + merged[-1][1] == s:
                merged[-1] = (merged[-1][0], merged[-1][1] + sz)
            else:
                merged.append((s, sz))
        self._free = merged

    def _slice_at(self, start: int, size: int) -> MeshSlice:
        devs = self._devices[start:start + size] if self._devices is not None else None
        return MeshSlice(start=start, size=size, devices=devs)

    def acquire_at(self, start: int, size: int) -> MeshSlice:
        """Carve an exact range out of the free list (no first-fit search).

        The rollback half of an elastic resize: a failed rebuild must put the
        trial back on the precise device range its live mesh still covers, not
        on whatever first-fit would pick.
        """
        if size <= 0:
            raise ValueError(f"slice size must be positive, got {size}")
        for i, (fs, fsz) in enumerate(self._free):
            if fs <= start and start + size <= fs + fsz:
                del self._free[i]
                if fs < start:
                    self._free.insert(i, (fs, start - fs))
                    i += 1
                if start + size < fs + fsz:
                    self._free.insert(i, (start + size, fs + fsz - (start + size)))
                self._held[start] = size
                return self._slice_at(start, size)
        raise RuntimeError(f"range [{start}, {start + size}) is not free")

    # -- elastic resize -----------------------------------------------------------
    def try_grow(self, sl: MeshSlice, new_size: int) -> Optional[MeshSlice]:
        """In-place growth only: extend ``sl`` into the free range that starts
        exactly at its end.  Returns the grown slice, or None when the
        adjacent range can't supply the delta (caller may then relocate via
        ``resize``).  Never moves devices the trial already holds."""
        if self._held.get(sl.start) != sl.size:
            raise ValueError(f"slice [{sl.start}, {sl.start + sl.size}) is not "
                             "currently held")
        delta = new_size - sl.size
        if delta <= 0:
            raise ValueError(f"try_grow needs new_size > current "
                             f"({new_size} <= {sl.size})")
        end = sl.start + sl.size
        for i, (start, size) in enumerate(self._free):
            if start == end and size >= delta:
                if size == delta:
                    del self._free[i]
                else:
                    self._free[i] = (start + delta, size - delta)
                self._held[sl.start] = new_size
                self.n_resized_total += 1
                return self._slice_at(sl.start, new_size)
        return None

    def resize(self, sl: MeshSlice, new_size: int) -> MeshSlice:
        """Grow or shrink a held slice, preferring in-place moves.

        Shrink trims the tail back into the free list (always succeeds).
        Grow extends into the adjacent free range when possible, otherwise
        relocates to a first-fit block of ``new_size`` — the caller must
        rebuild the trial's mesh either way, so relocation costs nothing
        extra.  Raises ``RuntimeError`` when no placement exists; the held
        slice is unchanged in that case (the operation is atomic).
        """
        if self._held.get(sl.start) != sl.size:
            raise ValueError(f"slice [{sl.start}, {sl.start + sl.size}) is not "
                             "currently held")
        if new_size <= 0:
            raise ValueError(f"slice size must be positive, got {new_size}")
        if new_size == sl.size:
            return sl
        if new_size < sl.size:  # trim the tail
            self._held[sl.start] = new_size
            self._insert_free(sl.start + new_size, sl.size - new_size)
            self.n_resized_total += 1
            return self._slice_at(sl.start, new_size)
        grown = self.try_grow(sl, new_size)
        if grown is not None:
            return grown
        # Relocate: release, then first-fit via acquire (which may land on
        # the coalesced union of the old range and a neighbour).  If nothing
        # fits, carve the exact old range back out — always possible, nothing
        # else allocated in between — so failure leaves the pool untouched.
        del self._held[sl.start]
        self._insert_free(sl.start, sl.size)
        try:
            moved = self.acquire(new_size)
        except RuntimeError:
            restored = self.acquire_at(sl.start, sl.size)
            assert restored.start == sl.start and restored.size == sl.size
            raise RuntimeError(
                f"SlicePool cannot resize slice [{sl.start}, {sl.start + sl.size}) "
                f"to {new_size} devices (free={self.n_free}/{self.n_total}, "
                f"largest block={self.largest_free_block()})") from None
        self.n_acquired_total -= 1  # an internal move, not a new placement
        self.n_resized_total += 1
        return moved

    def __repr__(self) -> str:
        return (f"SlicePool(total={self.n_total}, free={self.n_free}, "
                f"holes={self.fragments()}, "
                f"util={self.utilization():.0%})")
