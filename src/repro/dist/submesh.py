"""SlicePool — contiguous sub-mesh allocation for trials (DESIGN.md §2).

The runner treats TPU devices like the paper treats cluster nodes: a trial
asks for ``Resources(devices=k)`` and the executor hands it a ``MeshSlice``
of ``k`` contiguous devices from the pool.  Contiguity matters on real
hardware (ICI locality on a torus); here it is first-fit over a linearized
device order with coalescing on release, i.e. the classic free-list
allocator, which keeps fragmentation bounded for the power-of-two slice
sizes trials actually request.

Two modes:

* device mode — ``SlicePool(devices=[...])`` allocates real ``jax.Device``
  objects; ``MeshSlice.make_mesh`` builds a ``jax.sharding.Mesh`` over them.
* virtual mode — ``SlicePool(n_virtual=256)`` tracks capacity only (CPU
  testing / benchmarks); ``make_mesh`` tiles the host's devices to the
  requested size so mesh-shape logic stays exercised on one CPU.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence, Tuple

__all__ = ["MeshSlice", "SlicePool"]


def balanced_shape(size: int, n_axes: int) -> Tuple[int, ...]:
    """Factor ``size`` into ``n_axes`` dims, as square as possible, largest
    first — e.g. 8 over 2 axes -> (4, 2).  Used when a trial mesh has more
    axis names than the slice has natural dimensions."""
    if n_axes <= 0:
        raise ValueError("n_axes must be >= 1")
    dims = [1] * n_axes
    rem = size
    # peel prime factors largest-first onto the currently-smallest axis
    factors: List[int] = []
    d = 2
    while d * d <= rem:
        while rem % d == 0:
            factors.append(d)
            rem //= d
        d += 1
    if rem > 1:
        factors.append(rem)
    for f in sorted(factors, reverse=True):
        dims[dims.index(min(dims))] *= f
    return tuple(sorted(dims, reverse=True))


@dataclass(frozen=True)
class MeshSlice:
    """A contiguous range of the pool's device order.

    ``devices`` is None in virtual mode.  Slices are value objects — the pool
    identifies them by ``(start, size)`` on release.
    """
    start: int
    size: int
    devices: Optional[Tuple[Any, ...]] = None

    def make_mesh(self, axis_names: Sequence[str],
                  shape: Optional[Tuple[int, ...]] = None):
        """A real ``jax.sharding.Mesh`` over this slice's devices.

        ``shape`` defaults to a balanced factorization of ``size`` over
        ``axis_names`` (one axis -> ``(size,)``).  In virtual mode the host's
        devices are tiled to ``size`` so the mesh is still constructible on a
        single-CPU test machine.
        """
        import jax
        import numpy as np

        axis_names = tuple(axis_names)
        if shape is None:
            shape = balanced_shape(self.size, len(axis_names))
        if math.prod(shape) != self.size:
            raise ValueError(f"mesh shape {shape} does not cover slice of "
                             f"size {self.size}")
        if self.devices is not None:
            devs = list(self.devices)
        else:
            host = jax.devices()
            devs = (host * ((self.size + len(host) - 1) // len(host)))[: self.size]
        return jax.sharding.Mesh(np.asarray(devs, dtype=object).reshape(shape),
                                 axis_names)


class SlicePool:
    """First-fit contiguous allocator over a linear device order.

    Free ranges are kept sorted by start offset; ``release`` merges with
    adjacent free ranges so a fully-drained pool always coalesces back to one
    range (``can_fit(n_total)`` is the invariant the tests check).
    """

    def __init__(self, devices: Optional[Sequence[Any]] = None,
                 n_virtual: Optional[int] = None):
        if (devices is None) == (n_virtual is None):
            raise ValueError("pass exactly one of devices= or n_virtual=")
        self._devices = tuple(devices) if devices is not None else None
        self.n_total = len(self._devices) if self._devices is not None else int(n_virtual)
        if self.n_total <= 0:
            raise ValueError("pool must hold at least one device")
        self._free: List[Tuple[int, int]] = [(0, self.n_total)]  # (start, size)
        self._held: dict = {}  # start -> size, for double-release detection
        self.n_acquired_total = 0  # lifetime acquire count (occupancy metrics)

    # -- queries -----------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return sum(size for _, size in self._free)

    def can_fit(self, size: int) -> bool:
        if size <= 0:
            raise ValueError(f"slice size must be positive, got {size}")
        return any(sz >= size for _, sz in self._free)

    @property
    def fragments(self) -> int:
        """Number of disjoint free ranges (1 = fully coalesced)."""
        return len(self._free)

    # -- allocate / release -------------------------------------------------------
    def acquire(self, size: int) -> MeshSlice:
        if size <= 0:
            raise ValueError(f"slice size must be positive, got {size}")
        for i, (start, sz) in enumerate(self._free):
            if sz >= size:
                if sz == size:
                    del self._free[i]
                else:
                    self._free[i] = (start + size, sz - size)
                self._held[start] = size
                self.n_acquired_total += 1
                devs = (self._devices[start:start + size]
                        if self._devices is not None else None)
                return MeshSlice(start=start, size=size, devices=devs)
        raise RuntimeError(
            f"SlicePool cannot fit a slice of {size} devices "
            f"(free={self.n_free}/{self.n_total} in {len(self._free)} fragments)")

    def release(self, sl: MeshSlice) -> None:
        if self._held.get(sl.start) != sl.size:
            raise ValueError(f"slice [{sl.start}, {sl.start + sl.size}) is not "
                             "currently held (double release?)")
        del self._held[sl.start]
        # insert sorted, then coalesce with neighbours
        import bisect
        idx = bisect.bisect_left(self._free, (sl.start, sl.size))
        self._free.insert(idx, (sl.start, sl.size))
        merged: List[Tuple[int, int]] = []
        for start, size in self._free:
            if merged and merged[-1][0] + merged[-1][1] == start:
                merged[-1] = (merged[-1][0], merged[-1][1] + size)
            else:
                merged.append((start, size))
        self._free = merged

    def __repr__(self) -> str:
        return (f"SlicePool(total={self.n_total}, free={self.n_free}, "
                f"fragments={len(self._free)})")
