"""Gemma-2B [arXiv:2403.08295] — dense, GeGLU, MQA, head_dim=256.

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.
"""
from ..models import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="geglu",
    embedding_scale=True,
    tie_embeddings=True,
    source="arXiv:2403.08295 (Gemma)",
)
