"""H2O-Danube-1.8B [arXiv:2401.16818] — llama+mistral mix with sliding-window.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096.
Window cache is O(window) -> long_500k RUNS.
"""
from ..models import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    activation="swiglu",
    sliding_window=4096,
    source="arXiv:2401.16818 (H2O-Danube)",
)
