"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M] — small llama-arch dense.

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
The Tune-representative case: many parallel trials fit one pod.
"""
from ..models import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    activation="swiglu",
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
