"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``.

One module per assigned architecture; each cites its source in ``source=``.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models import ModelConfig

_ARCH_MODULES = [
    "hubert_xlarge",
    "deepseek_moe_16b",
    "qwen1_5_110b",
    "paligemma_3b",
    "smollm_135m",
    "recurrentgemma_9b",
    "h2o_danube_1_8b",
    "granite_moe_3b_a800m",
    "rwkv6_1_6b",
    "gemma_2b",
]

_REGISTRY: Dict[str, ModelConfig] = {}


def _load() -> None:
    if _REGISTRY:
        return
    for mod_name in _ARCH_MODULES:
        mod = importlib.import_module(f".{mod_name}", __package__)
        cfg: ModelConfig = mod.CONFIG.validate()
        _REGISTRY[cfg.arch_id] = cfg


def list_archs() -> List[str]:
    _load()
    return sorted(_REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    _load()
    key = arch_id.replace("_", "-")
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; available: {list_archs()}")
    return _REGISTRY[key]
