"""HuBERT-XLarge [arXiv:2106.07447] — audio encoder, same arch as wav2vec2.

48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504 (masked-unit codebook).
Encoder-only: bidirectional attention, no decode shapes (DESIGN.md §4).
The conv feature extractor is a STUB: inputs are precomputed frame features
(B, T, 512) through a linear projection.
"""
from ..models import ModelConfig

CONFIG = ModelConfig(
    arch_id="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    activation="gelu",
    norm="layernorm",
    qkv_bias=True,
    encoder_only=True,
    frontend="audio_stub",
    frontend_dim=512,
    tie_embeddings=True,  # unit codebook head shares the (504, d) embedding
    source="arXiv:2106.07447 (HuBERT); backbone per wav2vec2 arXiv:2006.11477",
)
