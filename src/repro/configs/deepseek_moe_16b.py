"""DeepSeek-MoE 16B [arXiv:2401.06066] — fine-grained MoE.

28L d_model=2048 16H (GQA kv=16) d_ff=1408 (per expert) vocab=102400,
64 routed experts top-6 + 2 shared experts.
"""
from ..models import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    activation="swiglu",
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared=2,
        capacity_factor=1.25,
        group_size=256,
        aux_loss_coef=0.001,
    ),
    remat=True,
    train_microbatch=2,
    source="arXiv:2401.06066 (DeepSeekMoE)",
)
