"""RecurrentGemma-9B [arXiv:2402.19427 Griffin; model arXiv:2404.07839].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; RG-LRU + local
attention in a 2-recurrent:1-attention pattern, window 2048.
Sub-quadratic decode state -> long_500k RUNS.
"""
from ..models import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    activation="geglu",
    embedding_scale=True,
    tie_embeddings=True,
    block_pattern=("rglru", "rglru", "local_attn"),
    sliding_window=2048,
    rglru_d_rnn=4096,
    conv1d_width=4,
    remat=True,
    train_microbatch=4,
    source="arXiv:2402.19427 (Griffin) / arXiv:2404.07839 (RecurrentGemma)",
)
