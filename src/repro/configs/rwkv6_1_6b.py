"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892] — attention-free, data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536, head size 64 (32 heads).
O(1) decode state -> long_500k RUNS.
"""
from ..models import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,           # derived: d_model / rwkv_head_dim
    d_ff=7168,
    vocab_size=65536,
    norm="layernorm",
    rwkv_head_dim=64,
    source="arXiv:2404.05892 (Eagle and Finch / RWKV-6)",
)
