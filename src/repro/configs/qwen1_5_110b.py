"""Qwen1.5-110B [hf:Qwen/Qwen1.5-0.5B family card] — dense, QKV bias.

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
The memory-pressure stress case: FSDP+TP with remat.
"""
from ..models import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
    remat=True,
    train_microbatch=8,  # 256-seq global batch -> 32-seq microbatches
    source="hf:Qwen/Qwen1.5-110B (family per hf:Qwen/Qwen1.5-0.5B)",
)
