"""PaliGemma-3B [arXiv:2407.07726] — VLM: SigLIP vision + gemma decoder.

Language backbone: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.
The SigLIP encoder + projector is a STUB: inputs are precomputed patch
embeddings (B, 256, 1152) through the linear projector (prefix-LM layout).
"""
from ..models import ModelConfig

CONFIG = ModelConfig(
    arch_id="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    activation="geglu",
    embedding_scale=True,
    tie_embeddings=True,
    frontend="vision_stub",
    frontend_dim=1152,        # SigLIP-So400m width
    n_prefix_embeds=256,      # 224px / 14px patches = 16x16
    source="arXiv:2407.07726 (PaliGemma); decoder per arXiv:2403.08295 (Gemma)",
)
