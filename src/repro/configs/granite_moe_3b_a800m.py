"""Granite-MoE 3B-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base family].

32L d_model=1536 24H (GQA kv=8) d_ff=512 (per expert) vocab=49155,
MoE 40 experts top-8 (the assignment also says "32 experts"; we follow the
primary "MoE 40e top-8" spec — discrepancy noted in DESIGN.md §4).
"""
from ..models import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    activation="swiglu",
    tie_embeddings=True,
    moe=MoEConfig(
        n_experts=40,
        top_k=8,
        d_expert=512,
        n_shared=0,
        capacity_factor=1.25,
        group_size=256,
        aux_loss_coef=0.01,
    ),
    source="hf:ibm-granite/granite-3.0-3b-a800m-base (family per 1b-a400m card)",
)
