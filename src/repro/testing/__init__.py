"""repro.testing — deterministic virtual-time simulation harness (DESIGN.md §7).

The platform the test suite itself runs on: a ``SimTrainable`` whose device
work and faults are scripted virtual-time sleeps, scenario generators for the
failure classes the execution tiers exist to survive (crash storms, straggler
cascades, elastic resize churn), and invariant checkers that audit a finished
run for slice leaks, event-log gaps and scheduler-decision fidelity.  Paired
with ``repro.core.clock.VirtualClock``, minute-scale failure timelines run in
milliseconds — which is what makes thousand-trial fault matrices affordable
in CI (tests/test_scenarios.py).
"""
from ..core.clock import Clock, VirtualClock, WallClock, use_clock
from .invariants import (check_all, check_decision_provenance,
                         check_event_log, check_fault_accounting,
                         check_no_slice_leaks, check_serial_equivalence)
from .scenarios import (RecordingLogger, Scenario, ScenarioResult,
                        crash_storm, resize_churn, run_scenario,
                        straggler_cascade)
from .sim import SimKilled, SimTrainable, reset_faults

__all__ = [
    "Clock", "WallClock", "VirtualClock", "use_clock",
    "SimTrainable", "SimKilled", "reset_faults",
    "Scenario", "ScenarioResult", "RecordingLogger",
    "crash_storm", "straggler_cascade", "resize_churn", "run_scenario",
    "check_all", "check_no_slice_leaks", "check_event_log",
    "check_fault_accounting", "check_decision_provenance",
    "check_serial_equivalence",
]
