"""SimWorkerTrainable — scripted faults inside *real* worker processes.

``testing.sim.SimTrainable`` scripts faults for the in-host tiers, where a
module-level registry survives rebuilds because everything shares one
interpreter.  Across a spawn boundary that registry is reborn empty, so this
variant persists fault firings as marker files under ``config["fault_dir"]``
— the same trick as tests/_worker_trainables.py, generalized to the scenario
DSL's fault vocabulary so the 3000-trial matrix generators drive the process
and cluster tiers too:

- ``crash_at=k`` / ``crash_count=c`` — raise at iteration ``k`` for the
  first ``c`` incarnations (max_failures absorbs or surfaces them),
- ``kill_at=k`` — ``os._exit(13)`` at iteration ``k``: the process dies for
  real, which only this tier can express (the in-host analogue raises),
- ``straggle_at=k`` / ``straggle_wall_s`` — iteration ``k`` sleeps *real*
  seconds.  Children keep wall time; the controller's heartbeat/straggler
  deadline arithmetic reads the injected clock (the PR 5 virtual-deadline
  contract), so a test can fast-forward a five-minute deadline in real
  milliseconds while the child is genuinely stuck.

Loss is the same lr-separable ``(lr-0.01)^2 + 1/n`` every scheduler in the
matrix can rank, and ``save``/``restore`` carry ``n`` so restarts resume
instead of resetting.
"""
from __future__ import annotations

import errno
import os
import time

from ..core.api import Trainable

__all__ = ["SimWorkerTrainable"]


def _fire(fault_dir: str, sim_id: str, site: str, limit: int) -> bool:
    """True (and durably consume one firing) while ``site`` has fired fewer
    than ``limit`` times.  O_CREAT|O_EXCL marker files make each firing
    atomic even when a killed worker's successor races a stale sibling."""
    if limit <= 0 or not fault_dir:
        return False
    for k in range(limit):
        path = os.path.join(fault_dir, f"{sim_id}.{site}.{k}")
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except OSError as e:
            if e.errno == errno.EEXIST:
                continue  # this firing already happened (prior incarnation)
            raise
        os.close(fd)
        return True
    return False


class SimWorkerTrainable(Trainable):
    """Config keys: ``sim_id`` (fault key; required for any fault),
    ``fault_dir`` (marker directory; required for any fault), ``lr``,
    ``step_wall_s`` (real seconds of "device work" per step, default 0),
    ``crash_at``/``crash_count``, ``kill_at``,
    ``straggle_at``/``straggle_wall_s`` (default 3 real seconds)."""

    def setup(self, config):
        self.n = 0
        self.lr = float(config.get("lr", 0.01))
        self.sim_id = str(config.get("sim_id", "sim"))
        self.fault_dir = str(config.get("fault_dir", ""))

    def step(self):
        self.n += 1
        straggle_at = int(self.config.get("straggle_at", 0))
        if straggle_at and self.n == straggle_at and _fire(
                self.fault_dir, self.sim_id, "straggle", 1):
            time.sleep(float(self.config.get("straggle_wall_s", 3.0)))
        else:
            wall = float(self.config.get("step_wall_s", 0.0))
            if wall > 0:
                time.sleep(wall)
        crash_at = int(self.config.get("crash_at", 0))
        if crash_at and self.n == crash_at and _fire(
                self.fault_dir, self.sim_id, "crash",
                int(self.config.get("crash_count", 1))):
            self.n -= 1  # the step never completed
            raise RuntimeError(
                f"injected crash: {self.sim_id} at iteration {crash_at}")
        kill_at = int(self.config.get("kill_at", 0))
        if kill_at and self.n == kill_at and _fire(
                self.fault_dir, self.sim_id, "kill", 1):
            os._exit(13)  # a real process death, not an exception
        return {"loss": (self.lr - 0.01) ** 2 + 1.0 / self.n, "n": self.n}

    def save(self):
        return {"n": self.n}

    def restore(self, state):
        self.n = state["n"]

    def reset_config(self, new_config):
        self.lr = float(new_config.get("lr", self.lr))
        self.config = dict(new_config)
        return True
