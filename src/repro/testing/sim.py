"""SimTrainable — a scripted surrogate trainable for virtual-time testing.

The paper's claim is that the narrow Trainable waist makes schedulers and
fault handling testable; this is the trainable that cashes the claim in.  Its
"device work" is a ``clock.sleep`` of a scripted duration, so under a
``VirtualClock`` a thousand-trial sweep with minute-scale heartbeat timeouts
runs in real milliseconds — and its faults are scripted too:

- ``crash_at=k`` — ``step`` raises at iteration ``k`` (``crash_count``
  incarnations in a row; the runner's max_failures machinery absorbs or
  surfaces them),
- ``straggle_at=k`` / ``straggle_s`` — iteration ``k`` takes ``straggle_s``
  instead of its scripted duration (drives HEARTBEAT_MISSED),
- ``kill_at=k`` — raises ``SimKilled`` at iteration ``k``, the in-host
  analogue of an externally SIGKILLed worker (same ERROR → retry path).

Fault state must survive rebuilds (a crashed trial is reconstructed from its
checkpoint), so firings are counted in a module-level registry keyed by
``(sim_token, sim_id, site)`` — ``sim_token`` isolates runs from each other,
exactly like the marker files of tests/_worker_trainables.py but in-process.
"""
from __future__ import annotations

import threading
import zlib
from typing import Dict, Tuple

from ..core.api import Trainable
from ..core.clock import get_default_clock

__all__ = ["SimKilled", "SimTrainable", "reset_faults"]


class SimKilled(RuntimeError):
    """Injected external-kill fault (OOM-killer / preemption analogue)."""


_FAULTS: Dict[Tuple[str, str, str], int] = {}
_FAULTS_LOCK = threading.Lock()


def reset_faults(token: str = None) -> None:
    """Forget fault firings (all, or one run's ``sim_token``)."""
    with _FAULTS_LOCK:
        if token is None:
            _FAULTS.clear()
        else:
            for key in [k for k in _FAULTS if k[0] == token]:
                del _FAULTS[key]


def _fire(token: str, sim_id: str, site: str, limit: int) -> bool:
    """True (and consume one firing) while ``site`` has fired < limit times."""
    if limit <= 0:
        return False
    with _FAULTS_LOCK:
        key = (token, sim_id, site)
        n = _FAULTS.get(key, 0)
        if n >= limit:
            return False
        _FAULTS[key] = n + 1
        return True


def _scripted_jitter(sim_id: str, n: int, scale: float) -> float:
    """Deterministic per-(trial, step) duration wobble.  crc32, not hash():
    builtin hash is salted per interpreter, which would change wake ordering
    between a run and its serial-equivalence reference."""
    if scale <= 0:
        return 0.0
    return scale * (zlib.crc32(f"{sim_id}:{n}".encode()) % 997) / 997.0


class SimTrainable(Trainable):
    """Config keys (all optional unless noted):

    - ``sim_id`` — stable unique tag (REQUIRED for any fault key)
    - ``sim_token`` — run nonce isolating the fault registry between runs
    - ``lr`` — drives the lr-separable loss ``(lr-0.01)^2 + 1/n`` every
      scheduler in the matrix can rank
    - ``step_s`` — base virtual seconds per step (default 1.0)
    - ``durations`` — explicit per-step duration list (overrides step_s while
      it lasts)
    - ``jitter_s`` — deterministic duration wobble amplitude (keeps wake
      times distinct so virtual wake order is well-defined)
    - ``crash_at`` / ``crash_count`` — raise at that iteration, that many
      incarnations in a row (default count 1)
    - ``kill_at`` — raise SimKilled at that iteration (once)
    - ``straggle_at`` / ``straggle_s`` — that iteration sleeps straggle_s
      (default 120 virtual seconds) instead of its scripted duration
    """

    def setup(self, config):
        self.n = 0
        self.lr = float(config.get("lr", 0.01))
        self.sim_id = str(config.get("sim_id", "sim"))
        self.token = str(config.get("sim_token", ""))

    # -- scripted timing ---------------------------------------------------------------
    def _duration(self, n: int) -> float:
        straggle_at = int(self.config.get("straggle_at", 0))
        if straggle_at and n == straggle_at and _fire(
                self.token, self.sim_id, "straggle", 1):
            return float(self.config.get("straggle_s", 120.0))
        durations = self.config.get("durations")
        if durations and n <= len(durations):
            base = float(durations[n - 1])
        else:
            base = float(self.config.get("step_s", 1.0))
        return base + _scripted_jitter(
            self.sim_id, n, float(self.config.get("jitter_s", 0.0)))

    def step(self):
        self.n += 1
        get_default_clock().sleep(self._duration(self.n))
        crash_at = int(self.config.get("crash_at", 0))
        if crash_at and self.n == crash_at and _fire(
                self.token, self.sim_id, "crash",
                int(self.config.get("crash_count", 1))):
            self.n -= 1  # the step never completed
            raise RuntimeError(
                f"injected crash: {self.sim_id} at iteration {crash_at}")
        kill_at = int(self.config.get("kill_at", 0))
        if kill_at and self.n == kill_at and _fire(
                self.token, self.sim_id, "kill", 1):
            self.n -= 1
            raise SimKilled(
                f"injected external kill: {self.sim_id} at iteration {kill_at}")
        sl = self.config.get("_slice")
        return {"loss": (self.lr - 0.01) ** 2 + 1.0 / self.n, "n": self.n,
                "devices": sl.size if sl is not None else 0}

    def save(self):
        return {"n": self.n}

    def restore(self, state):
        self.n = state["n"]

    def reset_config(self, new_config):
        # PBT exploit support: mutate lr in place.
        self.lr = float(new_config.get("lr", self.lr))
        self.config = dict(new_config)
        return True
