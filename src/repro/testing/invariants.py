"""Invariant checkers over a finished ScenarioResult.

Each checker raises AssertionError with a scenario-sized diagnostic; the
fault-matrix tests call ``check_all``.  Three families:

- resource safety   — the SlicePool and the ResourceAccountant drained back
                      to empty (no leaked slice, no leaked accounting),
- event-log health  — per-trial result streams are strictly increasing and
                      gapless, restart/error/straggler counts reconcile with
                      the faults the scenario scripted,
- decision fidelity — a concurrent run on a capacity-1 pool reproduces the
                      serial executor's statuses/results/decisions exactly
                      (``check_serial_equivalence`` runs both and compares).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List

from ..core.events import EventType
from ..core.trial import TrialStatus
from .scenarios import Scenario, ScenarioResult, run_scenario

__all__ = ["check_no_slice_leaks", "check_event_log", "check_fault_accounting",
           "check_all", "check_serial_equivalence"]


def check_no_slice_leaks(result: ScenarioResult) -> None:
    """Every slice and every accounted resource returned to the pool."""
    pool = result.pool
    assert pool.n_free == pool.n_total, (
        f"{result.scenario.name}: slice leak — {pool.n_total - pool.n_free} "
        f"devices still held after the run ({pool!r})")
    assert pool.fragments() == 0, (
        f"{result.scenario.name}: free list failed to coalesce ({pool!r})")
    acct = result.executor.accountant
    assert acct.available.devices == acct.total.devices, (
        f"{result.scenario.name}: accountant leak — "
        f"{acct.total.devices - acct.available.devices} devices still booked")
    assert not result.executor.has_running(), (
        f"{result.scenario.name}: executor still has live workers")


def check_event_log(result: ScenarioResult, gapless: bool = True) -> None:
    """Per-trial streams are strictly increasing (gapless too, unless the
    scheduler clones — a PBT exploit legitimately jumps a trial forward to
    its donor's iteration) and every trial reached a terminal state; all
    timestamps sit on the virtual axis."""
    for t in result.trials:
        iters = [r.training_iteration for r in t.results]
        assert iters == sorted(set(iters)), (
            f"{t.trial_id}: result stream not strictly increasing: {iters}")
        if t.status == TrialStatus.TERMINATED:
            assert iters, f"{t.trial_id}: terminated with no results"
            if gapless:
                assert iters == list(range(1, len(iters) + 1)), (
                    f"{t.trial_id}: terminated with a gapped stream: {iters}")
        else:
            assert t.status == TrialStatus.ERROR, (
                f"{t.trial_id}: non-terminal status {t.status} after run")
            assert t.error, f"{t.trial_id}: ERROR status with no error"
    virtual_end = result.clock.time()
    for r in result.recorder.results:
        assert r.timestamp <= virtual_end, (
            f"result stamped past the virtual clock: {r.timestamp} > {virtual_end}")
    restarted = result.recorder.of(EventType.RESTARTED)
    assert len(restarted) == result.runner.n_restarts, (
        f"{result.scenario.name}: {result.runner.n_restarts} restarts but "
        f"{len(restarted)} RESTARTED events (lost or duplicated)")


def check_fault_accounting(result: ScenarioResult, strict: bool = True) -> None:
    """Reconcile observed restarts/errors/heartbeats with the scripted
    faults.  ``strict`` (run-to-completion scheduling) demands equality; an
    early-stopping scheduler may cancel a trial before its fault fires, so
    non-strict demands the observation never *exceeds* the script."""
    sc = result.scenario
    expected_restarts = sc.expected_crashes - sc.expected_fatal
    if strict:
        assert result.runner.n_restarts == expected_restarts, (
            f"{sc.name}: scripted {expected_restarts} absorbable crashes, "
            f"observed {result.runner.n_restarts} restarts")
        assert result.runner.n_errors == sc.expected_fatal, (
            f"{sc.name}: scripted {sc.expected_fatal} fatal trials, "
            f"observed {result.runner.n_errors} errors")
    else:
        assert result.runner.n_restarts <= expected_restarts, (
            f"{sc.name}: more restarts ({result.runner.n_restarts}) than "
            f"scripted crashes ({expected_restarts})")
        assert result.runner.n_errors <= sc.expected_fatal, (
            f"{sc.name}: more errors ({result.runner.n_errors}) than "
            f"scripted fatal trials ({sc.expected_fatal})")
    if sc.expected_stragglers:
        straggling = {e.trial_id
                      for e in result.recorder.of(EventType.HEARTBEAT_MISSED)}
        scripted = {t.trial_id
                    for t, cfg in zip(result.trials, sc.configs)
                    if cfg.get("straggle_at")}
        assert straggling <= scripted, (
            f"{sc.name}: heartbeat warnings for trials that never straggled: "
            f"{sorted(straggling - scripted)[:5]}")
        if strict:
            missing = scripted - straggling
            assert not missing, (
                f"{sc.name}: {len(missing)} scripted stragglers never "
                f"produced HEARTBEAT_MISSED: {sorted(missing)[:5]}")


def check_all(result: ScenarioResult, strict: bool = True,
              gapless: bool = True) -> None:
    check_no_slice_leaks(result)
    check_event_log(result, gapless=gapless)
    check_fault_accounting(result, strict=strict)


def check_serial_equivalence(
    scenario: Scenario,
    scheduler_factory: Callable[[], Any],
    lookahead: int = 1,
) -> Dict[str, ScenarioResult]:
    """Run the scenario twice on a capacity-1 pool — concurrent (virtual
    worker threads, heartbeat monitor on) vs the serial reference tier — and
    demand identical statuses, result streams and losses per trial.  With one
    device both tiers execute trials one at a time, so any divergence is a
    real decision-fidelity bug, not an interleaving artifact."""
    results = {}
    for tier in ("serial", "concurrent"):
        results[tier] = run_scenario(
            scenario, scheduler_factory, executor=tier, pool_devices=1,
            lookahead=lookahead if tier == "concurrent" else 1)
    ref, got = results["serial"], results["concurrent"]
    assert len(ref.trials) == len(got.trials)
    for mine, theirs in zip(got.trials, ref.trials):
        assert mine.status == theirs.status, (
            f"{mine.trial_id}: {mine.status} (concurrent) != "
            f"{theirs.status} (serial); error={mine.error}")
        mine_iters = [r.training_iteration for r in mine.results]
        theirs_iters = [r.training_iteration for r in theirs.results]
        assert mine_iters == theirs_iters, (
            f"{mine.trial_id}: result streams diverge: "
            f"{mine_iters} != {theirs_iters}")
        for a, b in zip(mine.results, theirs.results):
            assert abs(a.metrics["loss"] - b.metrics["loss"]) < 1e-12, (
                f"{mine.trial_id}@{a.training_iteration}: loss diverges")
    return results
