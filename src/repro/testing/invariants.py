"""Invariant checkers over a finished ScenarioResult.

Each checker raises AssertionError with a scenario-sized diagnostic; the
fault-matrix tests call ``check_all``.  Three families:

- resource safety   — the SlicePool and the ResourceAccountant drained back
                      to empty (no leaked slice, no leaked accounting),
- event-log health  — per-trial result streams are strictly increasing and
                      gapless, restart/error/straggler counts reconcile with
                      the faults the scenario scripted,
- decision fidelity — a concurrent run on a capacity-1 pool reproduces the
                      serial executor's statuses/results/decisions exactly
                      (``check_serial_equivalence`` runs both and compares).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List

from ..core.events import EventType
from ..core.trial import TrialStatus
from .scenarios import Scenario, ScenarioResult, run_scenario

__all__ = ["check_no_slice_leaks", "check_event_log", "check_fault_accounting",
           "check_decision_provenance", "check_all", "check_serial_equivalence"]


def check_no_slice_leaks(result: ScenarioResult) -> None:
    """Every slice and every accounted resource returned to the pool(s) —
    the shared pool on in-host tiers, every host's own pool on the cluster
    tier (an evicted host's pool must drain too: its trials were killed and
    released, not abandoned)."""
    name = result.scenario.name
    pools = ([("", result.pool)] if result.pool is not None else
             [(f"host {h}: ", host.pool)
              for h, host in sorted(
                  getattr(result.executor, "hosts", {}).items())])
    for tag, pool in pools:
        assert pool.n_free == pool.n_total, (
            f"{name}: {tag}slice leak — {pool.n_total - pool.n_free} "
            f"devices still held after the run ({pool!r})")
        assert pool.fragments() == 0, (
            f"{name}: {tag}free list failed to coalesce ({pool!r})")
    acct = result.executor.accountant
    assert acct.available.devices == acct.total.devices, (
        f"{result.scenario.name}: accountant leak — "
        f"{acct.total.devices - acct.available.devices} devices still booked")
    assert not result.executor.has_running(), (
        f"{result.scenario.name}: executor still has live workers")


def check_event_log(result: ScenarioResult, gapless: bool = True) -> None:
    """Per-trial streams are strictly increasing (gapless too, unless the
    scheduler clones — a PBT exploit legitimately jumps a trial forward to
    its donor's iteration) and every trial reached a terminal state; all
    timestamps sit on the virtual axis."""
    for t in result.trials:
        iters = [r.training_iteration for r in t.results]
        assert iters == sorted(set(iters)), (
            f"{t.trial_id}: result stream not strictly increasing: {iters}")
        if t.status == TrialStatus.TERMINATED:
            assert iters, f"{t.trial_id}: terminated with no results"
            if gapless:
                assert iters == list(range(1, len(iters) + 1)), (
                    f"{t.trial_id}: terminated with a gapped stream: {iters}")
        else:
            assert t.status == TrialStatus.ERROR, (
                f"{t.trial_id}: non-terminal status {t.status} after run")
            assert t.error, f"{t.trial_id}: ERROR status with no error"
    virtual_end = result.clock.time()
    for r in result.recorder.results:
        assert r.timestamp <= virtual_end, (
            f"result stamped past the virtual clock: {r.timestamp} > {virtual_end}")
    restarted = result.recorder.of(EventType.RESTARTED)
    assert len(restarted) == result.runner.n_restarts, (
        f"{result.scenario.name}: {result.runner.n_restarts} restarts but "
        f"{len(restarted)} RESTARTED events (lost or duplicated)")


def check_fault_accounting(result: ScenarioResult, strict: bool = True) -> None:
    """Reconcile observed restarts/errors/heartbeats with the scripted
    faults.  ``strict`` (run-to-completion scheduling) demands equality; an
    early-stopping scheduler may cancel a trial before its fault fires, so
    non-strict demands the observation never *exceeds* the script."""
    sc = result.scenario
    expected_restarts = sc.expected_crashes - sc.expected_fatal
    if strict:
        assert result.runner.n_restarts == expected_restarts, (
            f"{sc.name}: scripted {expected_restarts} absorbable crashes, "
            f"observed {result.runner.n_restarts} restarts")
        assert result.runner.n_errors == sc.expected_fatal, (
            f"{sc.name}: scripted {sc.expected_fatal} fatal trials, "
            f"observed {result.runner.n_errors} errors")
    else:
        assert result.runner.n_restarts <= expected_restarts, (
            f"{sc.name}: more restarts ({result.runner.n_restarts}) than "
            f"scripted crashes ({expected_restarts})")
        assert result.runner.n_errors <= sc.expected_fatal, (
            f"{sc.name}: more errors ({result.runner.n_errors}) than "
            f"scripted fatal trials ({sc.expected_fatal})")
    if sc.expected_stragglers:
        straggling = {e.trial_id
                      for e in result.recorder.of(EventType.HEARTBEAT_MISSED)}
        scripted = {t.trial_id
                    for t, cfg in zip(result.trials, sc.configs)
                    if cfg.get("straggle_at")}
        assert straggling <= scripted, (
            f"{sc.name}: heartbeat warnings for trials that never straggled: "
            f"{sorted(straggling - scripted)[:5]}")
        if strict:
            missing = scripted - straggling
            assert not missing, (
                f"{sc.name}: {len(missing)} scripted stragglers never "
                f"produced HEARTBEAT_MISSED: {sorted(missing)[:5]}")


def check_decision_provenance(result: ScenarioResult) -> None:
    """Every stopped/perturbed trial left a DECISION record whose inputs
    reconcile with the journaled metric stream (DESIGN.md §10).

    Runner stopping-criterion verdicts must reconcile *exactly* (the journaled
    value IS the stream's value — FIFO-exact); ASHA rung and HyperBand cut
    verdicts reconcile as *bounds* (score below cutoff / rank past the keep
    line), because the cutoff is a function of scheduler-internal rung state
    the journal only witnesses through the record itself.  PBT exploits must
    name a real donor whose journaled score beats the victim's."""
    sched = result.runner.scheduler
    metric = getattr(sched, "metric", "loss")
    mode = getattr(sched, "mode", "min")
    name = result.scenario.name
    trial_ids = {t.trial_id for t in result.trials}
    by_trial: Dict[str, List[Dict[str, Any]]] = {}
    for e in result.recorder.of(EventType.DECISION):
        by_trial.setdefault(e.trial_id, []).append(e.info)
        assert e.trial_id in trial_ids, (
            f"{name}: DECISION record for unknown trial {e.trial_id}")

    for t in result.trials:
        decs = by_trial.get(t.trial_id, [])
        stream = {r.training_iteration: r.metrics for r in t.results}
        if t.status == TrialStatus.TERMINATED:
            stops = [d for d in decs if d.get("verdict") == "STOP"]
            assert stops, (
                f"{name}: {t.trial_id} TERMINATED with no STOP decision "
                f"(verdicts seen: {[d.get('verdict') for d in decs]})")
            inputs = stops[-1].get("inputs") or {}
            it = stops[-1].get("iteration")
            reason = inputs.get("reason")
            if reason == "stopping_criterion":
                crit, bound, value = (inputs["criterion"], inputs["bound"],
                                      inputs["value"])
                assert value >= bound, (
                    f"{name}: {t.trial_id} stopped on {crit} with "
                    f"value {value} below bound {bound}")
                if crit == "training_iteration" and stream:
                    assert value == max(stream), (
                        f"{name}: {t.trial_id} stop record says "
                        f"{crit}={value} but stream ends at {max(stream)}")
                elif stream and it in stream and crit in stream[it]:
                    assert abs(value - stream[it][crit]) < 1e-12, (
                        f"{name}: {t.trial_id} stop record {crit}={value} "
                        f"!= journaled {stream[it][crit]} at iter {it}")
            elif reason == "rung":           # ASHA — bound + stream reconcile
                assert inputs["score"] < inputs["cutoff"], (
                    f"{name}: {t.trial_id} ASHA-stopped with score "
                    f"{inputs['score']} >= cutoff {inputs['cutoff']}")
                if it in stream and metric in stream[it]:
                    expected = (stream[it][metric] if mode == "max"
                                else -stream[it][metric])
                    assert abs(inputs["score"] - expected) < 1e-9, (
                        f"{name}: {t.trial_id} rung score {inputs['score']} "
                        f"!= journaled {expected} at iter {it}")
            elif reason in ("cut", "cut_after_error"):   # HyperBand — bounds
                assert inputs["rank"] >= inputs["n_keep"], (
                    f"{name}: {t.trial_id} cut at rank {inputs['rank']} "
                    f"inside the keep line {inputs['n_keep']}")
                assert inputs["score"] <= inputs["cut_score"] + 1e-12, (
                    f"{name}: {t.trial_id} cut with score {inputs['score']} "
                    f"above cut_score {inputs['cut_score']}")
            elif reason == "median":
                assert inputs["best_so_far"] < inputs["median"], (
                    f"{name}: {t.trial_id} median-stopped with best "
                    f"{inputs['best_so_far']} >= median {inputs['median']}")
            elif reason == "max_t":
                assert it is None or it >= inputs["max_t"], (
                    f"{name}: {t.trial_id} max_t-stopped at iter {it} "
                    f"< max_t {inputs['max_t']}")
        for d in decs:                        # PBT perturbations, any status
            if d.get("verdict") != "RESTART_WITH_CONFIG":
                continue
            inputs = d.get("inputs") or {}
            donor = inputs.get("donor")
            assert donor in trial_ids and donor != t.trial_id, (
                f"{name}: {t.trial_id} exploit names donor {donor!r} that "
                f"is not another trial in this run")
            if (inputs.get("donor_score") is not None
                    and inputs.get("my_score") is not None):
                assert inputs["donor_score"] >= inputs["my_score"], (
                    f"{name}: {t.trial_id} exploited a donor scoring "
                    f"{inputs['donor_score']} below its own "
                    f"{inputs['my_score']}")


def check_all(result: ScenarioResult, strict: bool = True,
              gapless: bool = True) -> None:
    check_no_slice_leaks(result)
    check_event_log(result, gapless=gapless)
    check_fault_accounting(result, strict=strict)
    check_decision_provenance(result)


def check_serial_equivalence(
    scenario: Scenario,
    scheduler_factory: Callable[[], Any],
    lookahead: int = 1,
) -> Dict[str, ScenarioResult]:
    """Run the scenario twice on a capacity-1 pool — concurrent (virtual
    worker threads, heartbeat monitor on) vs the serial reference tier — and
    demand identical statuses, result streams and losses per trial.  With one
    device both tiers execute trials one at a time, so any divergence is a
    real decision-fidelity bug, not an interleaving artifact."""
    results = {}
    for tier in ("serial", "concurrent"):
        results[tier] = run_scenario(
            scenario, scheduler_factory, executor=tier, pool_devices=1,
            lookahead=lookahead if tier == "concurrent" else 1)
    ref, got = results["serial"], results["concurrent"]
    assert len(ref.trials) == len(got.trials)
    for mine, theirs in zip(got.trials, ref.trials):
        assert mine.status == theirs.status, (
            f"{mine.trial_id}: {mine.status} (concurrent) != "
            f"{theirs.status} (serial); error={mine.error}")
        mine_iters = [r.training_iteration for r in mine.results]
        theirs_iters = [r.training_iteration for r in theirs.results]
        assert mine_iters == theirs_iters, (
            f"{mine.trial_id}: result streams diverge: "
            f"{mine_iters} != {theirs_iters}")
        for a, b in zip(mine.results, theirs.results):
            assert abs(a.metrics["loss"] - b.metrics["loss"]) < 1e-12, (
                f"{mine.trial_id}@{a.training_iteration}: loss diverges")
    return results
