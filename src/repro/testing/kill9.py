"""kill9 — subprocess harness for true-SIGKILL durable-resume testing.

``tests/test_resume_kill9.py`` needs a controller that actually dies the way
the tentpole claims to survive: no atexit hooks, no finally blocks, no flushed
buffers — ``os.kill(os.getpid(), SIGKILL)``.  That cannot be done in-process
(it would take pytest down with it), so this module is a ``python -m``
entrypoint the test drives as a child process:

    python -m repro.testing.kill9 --log-dir D --scheduler asha --kill-after 40
    python -m repro.testing.kill9 --log-dir D --scheduler asha --resume

The first invocation runs a small sweep under a ``VirtualClock`` and SIGKILLs
itself after the Nth completed trainable step (counted in a module global —
under the virtual clock, step completions are totally ordered by their
scripted durations, so the kill lands at a reproducible point in the sweep).
The second invocation resumes from the journal / search-state snapshot /
checkpoint mirrors that survived on disk.  Without ``--kill-after`` the sweep
runs to completion and writes ``final.json`` (trial table + summary) into the
log dir; the test compares that file — and the decision records in
``events.jsonl`` — between a clean child and a killed-then-resumed child.

The sweep itself is ``SimTrainable`` with per-trial step durations derived
from the grid index, exactly the recipe the in-process equivalence tests use;
what this tier adds is that the interruption is a real SIGKILL arriving
mid-write rather than a cooperative ``runner.step()`` cutoff.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import threading

from ..core.clock import VirtualClock, set_default_clock
from ..core.experiment import run_experiments
from ..core.resources import Resources
from ..core.schedulers.asha import AsyncHyperBandScheduler
from ..core.schedulers.hyperband import HyperBandScheduler
from ..core.schedulers.pbt import PopulationBasedTraining
from ..core.search.space import grid_search
from .sim import SimTrainable

__all__ = ["Kill9Trainable", "main"]

N_TRIALS = 6
STOP_ITERATION = 8
_LRS = [0.001 * (i + 1) for i in range(N_TRIALS)]
_STEP_S = [0.5, 0.7, 0.9, 1.1, 1.3, 1.7]

# Module globals, not config: the kill budget belongs to the *process* (one
# controller incarnation), not to any trial — a resumed run must not inherit
# the original run's trigger.
_KILL_AFTER = 0
_STEPS_DONE = 0
_COUNT_LOCK = threading.Lock()


class Kill9Trainable(SimTrainable):
    """SimTrainable whose grid index fixes its identity and step duration,
    and which SIGKILLs the whole process after the Nth global step."""

    def setup(self, config):
        super().setup(config)
        i = _LRS.index(self.lr)
        self.sim_id = f"k9-{i}"
        self.config.setdefault("step_s", _STEP_S[i])
        self.config.setdefault("jitter_s", 0.25)

    def step(self):
        global _STEPS_DONE
        out = super().step()
        if _KILL_AFTER > 0:
            with _COUNT_LOCK:
                _STEPS_DONE += 1
                fire = _STEPS_DONE >= _KILL_AFTER
            if fire:
                os.kill(os.getpid(), signal.SIGKILL)
        return out


def build_scheduler(kind: str):
    if kind == "asha":
        return AsyncHyperBandScheduler(metric="loss", mode="min",
                                       max_t=STOP_ITERATION, grace_period=1,
                                       reduction_factor=3)
    if kind == "hyperband":
        return HyperBandScheduler(metric="loss", mode="min",
                                  max_t=STOP_ITERATION + 1, eta=3)
    if kind == "pbt":
        return PopulationBasedTraining(
            metric="loss", mode="min", perturbation_interval=3,
            hyperparam_mutations={"lr": [0.001, 0.004, 0.008, 0.02]}, seed=7)
    raise SystemExit(f"unknown scheduler {kind!r}")


def main(argv=None) -> int:
    global _KILL_AFTER
    ap = argparse.ArgumentParser(prog="python -m repro.testing.kill9")
    ap.add_argument("--log-dir", required=True)
    ap.add_argument("--scheduler", choices=("asha", "hyperband", "pbt"),
                    default="asha")
    ap.add_argument("--kill-after", type=int, default=0,
                    help="SIGKILL the process after this many completed "
                         "trainable steps (0 = run to completion)")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    _KILL_AFTER = args.kill_after
    clock = VirtualClock()
    set_default_clock(clock)

    space = {"lr": grid_search(_LRS), "sim_token": "kill9"}
    analysis = run_experiments(
        Kill9Trainable,
        space,
        scheduler=build_scheduler(args.scheduler),
        stop={"training_iteration": STOP_ITERATION},
        resources_per_trial=Resources(cpu=1, devices=1),
        total_devices=N_TRIALS,
        executor="concurrent",
        clock=clock,
        log_dir=args.log_dir,
        search_state_interval=3.0,
        resume=args.resume,
    )

    from ..obs.analysis import ExperimentAnalysis as JournalAnalysis
    table = sorted(
        [t.trial_id, t.status.value, t.training_iteration,
         round(t.best_value("loss", "min") or -1.0, 9)]
        for t in analysis.trials)
    journal = JournalAnalysis.from_journal(
        os.path.join(args.log_dir, "events.jsonl"))
    final = {"table": table,
             "summary": journal.summary_json(metric="loss", mode="min")}
    with open(os.path.join(args.log_dir, "final.json"), "w") as f:
        json.dump(final, f, indent=1, sort_keys=True)
    print(json.dumps(final["table"]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
