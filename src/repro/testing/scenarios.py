"""Scenario DSL + runner — fault sweeps on deterministic virtual time.

A scenario is a list of ``SimTrainable`` configs plus expectations about the
faults scripted into them.  ``run_scenario`` places the whole execution stack
(executor, event bus, loggers, broker, trials) on one ``VirtualClock`` and
runs it to completion, returning a ``ScenarioResult`` the invariant checkers
(invariants.py) interrogate.  Three generators cover the failure classes the
execution tiers were built for:

- ``crash_storm``       — a fraction of trials crash mid-run (some more times
                          than max_failures absorbs, ending ERROR on purpose),
- ``straggler_cascade`` — a fraction of trials stall far past the heartbeat
                          timeout, driving HEARTBEAT_MISSED monitoring,
- ``resize_churn``      — elastic policy on, so early stops + completions
                          keep resizing the survivors' slices.

Everything is seeded and the virtual clock serializes thread wake order, so
a thousand-trial sweep is reproducible enough to assert exact bookkeeping
(crash counts, restart counts, leak-freedom) rather than just "it finished".
"""
from __future__ import annotations

import itertools
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from ..core.clock import VirtualClock, WallClock, use_clock
from ..core.checkpoint import CheckpointManager
from ..core.concurrent_executor import ConcurrentMeshExecutor
from ..core.elastic import ResourceBroker, resolve_policy
from ..core.executor import SerialMeshExecutor
from ..core.loggers import CompositeLogger, JSONLLogger, Logger
from ..core.object_store import ObjectStore
from ..core.resources import Resources
from ..core.runner import TrialRunner
from ..core.trial import Trial
from ..dist.submesh import SlicePool
from ..obs.flightrec import FlightRecorder
from .sim import SimTrainable, reset_faults

__all__ = ["Scenario", "ScenarioResult", "RecordingLogger", "run_scenario",
           "crash_storm", "straggler_cascade", "resize_churn"]

_token_counter = itertools.count()


class RecordingLogger(Logger):
    """Captures every event and result the runner routes to loggers (the
    runner thread is the only caller, so plain lists suffice)."""

    def __init__(self):
        self.events: List[Any] = []
        self.results: List[Any] = []

    def on_event(self, trial, event):
        self.events.append(event)

    def on_result(self, trial, result):
        self.results.append(result)

    def of(self, kind):
        return [e for e in self.events if e.type == kind]


@dataclass
class Scenario:
    name: str
    configs: List[Dict[str, Any]]     # one SimTrainable config per trial
    stop_iteration: int = 5
    max_failures: int = 1
    elastic: Optional[str] = None     # "greedy" / "fair" / None
    heartbeat_timeout: float = 60.0
    # scripted-fault accounting the invariants cross-check
    expected_crashes: int = 0         # total injected step failures (incl. kills)
    expected_fatal: int = 0           # trials whose budget those exhaust
    expected_stragglers: int = 0
    # cluster tier (executor="cluster"): roster + host-level fault script
    hosts: Any = None                 # parse_hosts input, e.g. "4x4"
    host_faults: List[Any] = field(default_factory=list)
    #   entries: (kind, host, at_s) or (kind, host, at_s, duration_s)
    #   kinds: "crash" (abrupt death), "partition" (heals after duration)
    host_timeout: float = 0.0         # silent-host eviction age (0 = default)


@dataclass
class ScenarioResult:
    scenario: Scenario
    trials: List[Trial]
    runner: TrialRunner
    executor: Any
    pool: Optional[SlicePool]         # None on the cluster tier (per-host pools)
    clock: Any                        # VirtualClock (WallClock on "process")
    recorder: RecordingLogger
    flightrec: Optional[FlightRecorder] = None
    wall_elapsed_s: float = 0.0
    fleet: Optional[Any] = None       # cluster tier's SimFleet (fault script)

    @property
    def virtual_elapsed_s(self) -> float:
        return self.clock.monotonic()

    def by_status(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for t in self.trials:
            out[t.status.value] = out.get(t.status.value, 0) + 1
        return out


def run_scenario(
    scenario: Scenario,
    scheduler_factory: Callable[[], Any],
    executor: str = "concurrent",
    pool_devices: int = 16,
    lookahead: int = 1,
    max_steps: int = 10_000_000,
    obs: Optional[Any] = None,
    token: Optional[str] = None,
    journal_path: Optional[str] = None,
    decisions: Any = True,
    log_dir: Optional[str] = None,
    resume: bool = False,
    interrupt_after_steps: Optional[int] = None,
    search_state_interval: float = 10.0,
    keep_last: int = 2,
) -> ScenarioResult:
    """Run one scenario on a fresh ``VirtualClock`` to completion.

    ``executor="serial"`` is the reference tier for equivalence checks; with
    ``pool_devices=1`` both tiers execute trials one at a time, so their
    event streams — and every scheduler decision — must coincide exactly.

    ``obs`` attaches a ``repro.obs.Observability`` bundle (tracing/metrics)
    to the stack.  ``token`` overrides the run nonce baked into trial ids —
    pass a fixed token to make trial ids (hence trace ids) identical across
    runs, which is what the byte-identical-trace determinism tests and
    ``bench_faults`` rely on.

    ``journal_path`` additionally tees the event stream through a
    ``JSONLLogger`` (v3 journal with run_header), so a scenario run leaves
    an ``ExperimentAnalysis``-readable artifact on disk.  The header's
    ``run_id`` is pinned to ``token`` to keep same-token runs byte-identical
    — the flight recorder is pinned to the same id, so forensic bundles from
    identical-token runs are byte-identical too (ISSUE 8 comparability fix).

    ``log_dir`` arms the full durable-resume stack (DESIGN.md §12): the
    journal at ``log_dir/events.jsonl``, durable checkpoint mirrors under
    ``log_dir/ckpt`` (rotated to ``keep_last``), and watermarked
    search-state snapshots at ``log_dir/search_state.json`` every
    ``search_state_interval`` virtual seconds.  ``interrupt_after_steps=N``
    simulates a controller kill -9: the runner is abandoned after N events —
    no final snapshot, no ``on_experiment_end`` — and a *partial*
    ScenarioResult comes back.  ``resume=True`` (same ``token`` required, so
    trial identities line up) rebuilds the runner from those artifacts via
    ``prepare_resume`` and continues the sweep in a fresh stack.
    """
    import os as _os
    import tempfile as _tempfile
    import time as _wall

    token = token if token is not None else f"{scenario.name}-{next(_token_counter)}"
    reset_faults()
    if log_dir is not None:
        _os.makedirs(log_dir, exist_ok=True)
        if journal_path is None:
            journal_path = _os.path.join(log_dir, "events.jsonl")
    if resume and (log_dir is None or journal_path is None
                   or not _os.path.exists(journal_path)):
        raise ValueError("resume=True needs a log_dir holding the journal of "
                         "the interrupted run (pass the same token too, so "
                         "trial identities line up)")
    # The process tier runs REAL worker processes: the clock cannot see them,
    # so fast-forwarding virtual time between their (real) deliveries would
    # trip the runner's stall detector long before any child speaks.  That
    # tier runs on wall time with wall-scaled faults; every in-process tier
    # (serial/concurrent/cluster-virtual) runs on deterministic virtual time.
    # (The virtual-deadline escalation over real children IS still testable —
    # by driving the executor directly, as test_virtual_deadline_math does.)
    clock = WallClock() if executor == "process" else VirtualClock()
    if obs is not None:
        obs.bind_clock(clock)  # span timestamps must ride the virtual axis
    pool = SlicePool(n_virtual=pool_devices)
    recorder = RecordingLogger()
    # The journal is opened AFTER the resume plan is prepared (below): a
    # resumed run re-opens it in append mode with the surviving record count.
    journal = None
    flightrec = FlightRecorder(
        clock=clock, run_id=f"run-{token}",
        out_dir=_os.environ.get("REPRO_FLIGHTREC_DIR", "flightrec"))
    t0 = _wall.monotonic()
    with use_clock(clock):
        store = ObjectStore()
        ckpt = (CheckpointManager(store, dir=_os.path.join(log_dir, "ckpt"),
                                  durable=True, keep_last=keep_last)
                if log_dir is not None else CheckpointManager(store))
        common = dict(
            trainable_cls_resolver=lambda name: SimTrainable,
            checkpoint_manager=ckpt,
            total_devices=pool_devices,
            total_cpu=4 * pool_devices,
            slice_pool=pool,
            checkpoint_freq=1,
            clock=clock,
            obs=obs,
        )
        fleet = None
        fault_dir = None
        trainable_name = "SimTrainable"
        if executor == "serial":
            ex = SerialMeshExecutor(**common)
        elif executor == "concurrent":
            ex = ConcurrentMeshExecutor(
                heartbeat_timeout=scenario.heartbeat_timeout, **common)
        elif executor == "process":
            # Satellite tier: the same fault matrix on REAL worker processes.
            # SimWorkerTrainable persists fault firings as marker files (a
            # module registry dies at the spawn boundary); the controller
            # keeps the VirtualClock for its deadline arithmetic while the
            # children live on wall time — the PR 5 virtual-deadline contract.
            from ..core.workers import TrainableFactory
            trainable_name = "SimWorkerTrainable"
            fault_dir = _tempfile.mkdtemp(prefix=f"repro-simworker-{token}-")
            factory = TrainableFactory(
                target="repro.testing.simworker:SimWorkerTrainable")
            common.pop("trainable_cls_resolver")
            from ..core.process_executor import ProcessMeshExecutor
            ex = ProcessMeshExecutor(
                factory_resolver=lambda _n: factory,
                heartbeat_timeout=scenario.heartbeat_timeout,
                spawn_timeout=0,  # spawn ages would fast-forward too
                **common)
        elif executor == "cluster":
            # Simulated host fleet: virtual transports + scripted host faults
            # on the same deterministic timeline (DESIGN.md §11).
            from ..cluster import ClusterMeshExecutor
            from ..cluster.sim import SimFleet
            from ..core.workers import TrainableFactory
            common.pop("slice_pool")
            common.pop("total_devices")  # the roster defines capacity
            # Virtual workers run in-process, so the import-path factory
            # resolves to the SAME sim module — scripted faults keep their
            # shared registry across "process" rebuilds.
            sim_factory = TrainableFactory(
                target="repro.testing.sim:SimTrainable")
            ex = ClusterMeshExecutor(
                hosts=scenario.hosts if scenario.hosts is not None else "4x4",
                transport="virtual", placement="fixed",
                heartbeat_timeout=scenario.heartbeat_timeout,
                host_timeout=scenario.host_timeout or None,
                spawn_timeout=0,
                factory_resolver=lambda _n: sim_factory,
                **common)
            fleet = SimFleet(ex, clock)
            for fault in scenario.host_faults:
                fleet.script(*fault[:2], at=fault[2],
                             duration=fault[3] if len(fault) > 3 else None)
        else:
            raise ValueError(f"run_scenario drives serial/concurrent/process/"
                             f"cluster tiers, not {executor!r}")
        broker = None
        if scenario.elastic is not None or lookahead != 1:
            broker = ResourceBroker(policy=resolve_policy(scenario.elastic),
                                    lookahead=lookahead, clock=clock)

        def _build_trials() -> List[Trial]:
            out = []
            for i, config in enumerate(scenario.configs):
                cfg = dict(config)
                cfg.setdefault("sim_id", f"{scenario.name}-{i:05d}")
                cfg["sim_token"] = token
                if fault_dir is not None:
                    # Process tier: wall-time fault vocabulary.  Virtual
                    # durations make no sense for real children (they'd sleep
                    # real hours), so scripted timing is dropped and
                    # stragglers sleep a short real interval the virtual
                    # deadline math escalates around.
                    cfg.pop("step_s", None)
                    cfg.pop("jitter_s", None)
                    cfg.pop("durations", None)
                    cfg["fault_dir"] = fault_dir
                    if cfg.pop("straggle_s", None) is not None:
                        cfg.setdefault("straggle_wall_s", 3.0)
                out.append(Trial(
                    cfg, trainable_name=trainable_name,
                    resources=Resources(cpu=1.0,
                                        devices=int(cfg.get("devices_req", 1))),
                    stopping_criteria={
                        "training_iteration": scenario.stop_iteration},
                    trial_id=f"{token}-{i:05d}",
                ))
            return out

        scheduler = scheduler_factory()
        plan = None
        if resume:
            from ..core.resume import prepare_resume
            plan = prepare_resume(
                journal_path,
                _os.path.join(log_dir, "search_state.json"),
                scheduler, base_trials=_build_trials(),
                checkpoint_dir=_os.path.join(log_dir, "ckpt"),
                trainable_name=trainable_name,
                stopping_criteria={
                    "training_iteration": scenario.stop_iteration})
        logger: Logger = recorder
        if journal_path is not None:
            journal = JSONLLogger(
                journal_path, clock=clock, run_id=f"run-{token}",
                executor=executor, decisions=decisions is not False,
                resumed=plan is not None,
                initial_records=plan.n_journal_records if plan is not None else 0)
            logger = CompositeLogger([recorder, journal])
        snapshotter = None
        if log_dir is not None:
            from ..obs.flightrec import SearchStateSnapshotter
            snapshotter = SearchStateSnapshotter(
                _os.path.join(log_dir, "search_state.json"), clock=clock,
                interval_s=search_state_interval,
                watermark_fn=((lambda: journal.n_records)
                              if journal is not None else None))

        runner = TrialRunner(
            scheduler,
            ex,
            logger=logger,
            trainable_name=trainable_name,
            stopping_criteria={"training_iteration": scenario.stop_iteration},
            max_failures=scenario.max_failures,
            broker=broker,
            obs=obs,
            decisions=decisions,
            flight_recorder=flightrec,
            state_snapshotter=snapshotter,
        )
        if plan is not None:
            runner.apply_resume_plan(plan)
        else:
            for trial in _build_trials():
                runner.add_trial(trial)
        if fleet is not None:
            fleet.start()
        try:
            if interrupt_after_steps is not None:
                # Simulated controller kill -9: abandon the runner mid-sweep.
                # No final search-state snapshot, no on_experiment_end — only
                # what the original process had already flushed survives.
                for _ in range(interrupt_after_steps):
                    if not runner.step():
                        break
                ex.shutdown()  # reap worker threads; journals nothing
                trials = runner.trials
            else:
                trials = runner.run(max_steps=max_steps)
        except BaseException:
            # A controller exception IS the crash-forensics use case: leave a
            # bundle behind (CI uploads the dump dir with if: failure()).
            try:
                flightrec.dump(runner, ex, reason="abort")
            except Exception:
                pass
            raise
        finally:
            if fleet is not None:
                fleet.stop()
    if journal is not None:
        journal.close()
    reset_faults(token)
    return ScenarioResult(
        scenario=scenario, trials=trials, runner=runner, executor=ex,
        pool=None if executor == "cluster" else pool, clock=clock,
        recorder=recorder, flightrec=flightrec,
        wall_elapsed_s=_wall.monotonic() - t0, fleet=fleet)


# -- scenario generators ---------------------------------------------------------------

def _base_config(rng: random.Random, i: int) -> Dict[str, Any]:
    return {
        "lr": 10 ** rng.uniform(-3, -1),
        "step_s": rng.choice([0.5, 1.0, 2.0]),
        "jitter_s": 0.25,
        "sim_id": f"trial-{i:05d}",
    }


def crash_storm(n_trials: int = 250, seed: int = 0, stop_iteration: int = 5,
                crash_frac: float = 0.3, fatal_frac: float = 0.05) -> Scenario:
    """A fraction of trials crash once mid-run (absorbed by max_failures=1);
    ``fatal_frac`` of them crash twice and must exhaust the budget."""
    rng = random.Random(seed)
    configs, crashes, fatal = [], 0, 0
    for i in range(n_trials):
        cfg = _base_config(rng, i)
        r = rng.random()
        if r < fatal_frac:
            cfg["crash_at"] = rng.randint(1, stop_iteration)
            cfg["crash_count"] = 2  # retry crashes again -> ERROR
            crashes += 2
            fatal += 1
        elif r < crash_frac:
            site = rng.random()
            if site < 0.3:
                cfg["kill_at"] = rng.randint(1, stop_iteration)
            else:
                cfg["crash_at"] = rng.randint(1, stop_iteration)
            crashes += 1
        configs.append(cfg)
    return Scenario(name="crash-storm", configs=configs,
                    stop_iteration=stop_iteration, max_failures=1,
                    expected_crashes=crashes, expected_fatal=fatal)


def straggler_cascade(n_trials: int = 250, seed: int = 0,
                      stop_iteration: int = 4,
                      straggle_frac: float = 0.2,
                      heartbeat_timeout: float = 30.0) -> Scenario:
    """A fraction of trials stall one step far past the heartbeat timeout;
    the monitor must surface every one of them without perturbing any
    scheduler decision."""
    rng = random.Random(seed)
    configs, stragglers = [], 0
    for i in range(n_trials):
        cfg = _base_config(rng, i)
        if rng.random() < straggle_frac:
            cfg["straggle_at"] = rng.randint(1, stop_iteration)
            cfg["straggle_s"] = heartbeat_timeout * rng.uniform(2.5, 6.0)
            stragglers += 1
        configs.append(cfg)
    return Scenario(name="straggler-cascade", configs=configs,
                    stop_iteration=stop_iteration, max_failures=0,
                    heartbeat_timeout=heartbeat_timeout,
                    expected_stragglers=stragglers)


def resize_churn(n_trials: int = 250, seed: int = 0, stop_iteration: int = 5,
                 crash_frac: float = 0.1) -> Scenario:
    """Elastic fair-share on: every completion/stop frees capacity the broker
    immediately redistributes, so slices churn constantly while a sprinkle of
    crashes exercises resize-vs-restart interleavings."""
    rng = random.Random(seed)
    configs, crashes = [], 0
    for i in range(n_trials):
        cfg = _base_config(rng, i)
        if rng.random() < crash_frac:
            cfg["crash_at"] = rng.randint(1, stop_iteration)
            crashes += 1
        configs.append(cfg)
    return Scenario(name="resize-churn", configs=configs,
                    stop_iteration=stop_iteration, max_failures=1,
                    elastic="fair", expected_crashes=crashes)
