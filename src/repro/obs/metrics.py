"""Metrics registry — counters, gauges, histograms; zero external deps.

The control plane wants aggregates ("how deep does the bus queue get", "what
is the p99 of a first-fit scan"), not a sample stream, so every instrument
keeps O(1) state.  Histograms bucket by power-of-two exponent (``math.frexp``)
— enough resolution to tell a 5µs first-fit from a 5ms one without storing
samples, and quantile estimates come from the bucket boundaries.

Instruments are updated from worker threads and the runner thread alike, so
each carries its own (uncontended, ~100ns) lock; the registry itself is
create-on-first-use under a registry lock.  Hot-path discipline: call sites
resolve the instrument ONCE (``registry.histogram("x")`` at init) and guard
each observation with ``if m is not None`` — with observability off there is
no registry and the per-event cost is a single attribute test.

Values observed here may come from ``time.perf_counter()`` (real host
latency): metrics are a *profiling* surface and are NOT required to be
deterministic under a VirtualClock — that guarantee belongs to the tracer
(tracing.py), which only ever stamps from the injected clock.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Any, Dict, List, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotone event count."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    def snapshot(self) -> Any:
        return self.value


class Gauge:
    """Last-write-wins instantaneous value (queue depth, pool utilization)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> Any:
        return self.value


class Histogram:
    """count/sum/min/max plus power-of-two buckets for quantile estimates.

    ``observe`` takes any non-negative value (µs latencies, byte sizes,
    seconds of heartbeat lag).  Bucket ``e`` holds values in ``[2^(e-1), 2^e)``
    — ``percentile`` answers from the upper boundary, so estimates are
    conservative (never under-report a tail).
    """

    __slots__ = ("name", "_lock", "_count", "_sum", "_min", "_max", "_buckets")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._buckets: Dict[int, int] = {}

    def observe(self, v: float) -> None:
        e = math.frexp(v)[1] if v > 0 else 0  # v in [2^(e-1), 2^e)
        with self._lock:
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            self._buckets[e] = self._buckets.get(e, 0) + 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def percentile(self, q: float) -> float:
        """Upper-boundary estimate of the q-th percentile (q in [0, 100])."""
        with self._lock:
            if not self._count:
                return 0.0
            target = max(1, math.ceil(self._count * q / 100.0))
            seen = 0
            for e in sorted(self._buckets):
                seen += self._buckets[e]
                if seen >= target:
                    return min(float(2 ** e), self._max)
            return self._max

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            if not self._count:
                return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                        "mean": 0.0}
            return {"count": self._count,
                    "sum": round(self._sum, 6),
                    "min": round(self._min, 6),
                    "max": round(self._max, 6),
                    "mean": round(self._sum / self._count, 6)}


class MetricsRegistry:
    """Named instruments, created on first use, snapshotted as one dict.

    Names are dotted (``bus.fanin_us``, ``pool.acquire_us``, ``trials.
    restarts``) — see DESIGN.md §8 for the full catalogue.  Asking for an
    existing name with a different instrument kind raises: a silent kind
    change would corrupt every dashboard reading the snapshot stream.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[str, Any] = {}

    def _get(self, name: str, cls: type) -> Any:
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls(name)
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, not {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def get(self, name: str) -> Optional[Any]:
        with self._lock:
            return self._instruments.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._instruments)

    def snapshot(self) -> Dict[str, Any]:
        """``{name: value-or-aggregate-dict}`` for every instrument."""
        with self._lock:
            instruments = list(self._instruments.values())
        return {inst.name: inst.snapshot() for inst in instruments}

    def snapshot_line(self, t: float, schema_version: int = 1) -> str:
        """One JSONL metrics-stream record (loggers/DESIGN.md §8)."""
        return json.dumps({"t": t, "schema_version": schema_version,
                           "metrics": self.snapshot()},
                          sort_keys=True, separators=(",", ":"))
