"""Structured tracing — per-trial spans, exported as Chrome trace-event JSON.

A span is one timed control-plane phase of one trial (DESIGN.md §8 taxonomy:
``trial``, ``schedule.decision``, ``slice.acquire``, ``build``, ``step``,
``ckpt.save``, ``ckpt.restore``, ``resize``, ``restart``).  The ``trace`` of a
span is the trial id — every span of a trial's life, across retries, resizes
and even process boundaries (worker children ship their spans back over the
pipe protocol), lands on that trial's timeline row.

Determinism contract: span timestamps and durations are read ONLY from the
injected ``Clock`` (clock.time(), the timestamp axis).  Under a
``VirtualClock`` two identical scenario runs therefore produce *byte-identical*
Chrome exports — ``export_chrome`` canonically sorts events and serializes
with fixed separators to keep that promise.  Real-time profiling numbers
(``time.perf_counter`` deltas) belong in the metrics registry, never here.

The disabled path is one attribute check: ``tracer.enabled`` is False on the
shared null tracer, ``span()`` returns a reused no-op context manager, and
``record``/``begin``/``end`` return immediately.
"""
from __future__ import annotations

import json
import threading
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Span", "Tracer", "NULL_TRACER"]

# JSON-safe span-arg types; anything else is dropped at record time so a
# span can never poison the export (or a SPAN bus event's JSONL record).
_JSON_SCALARS = (int, float, str, bool, type(None))

# Wire format for spans crossing a thread/process boundary (SPAN bus events,
# MSG_SPANS pipe messages): (name, ts, dur, cat, proc, args_dict).
SpanTuple = Tuple[str, float, float, str, str, Dict[str, Any]]


class Span:
    """One completed timed phase.  ``ts``/``dur`` are clock-time seconds."""

    __slots__ = ("name", "trace", "ts", "dur", "cat", "proc", "args")

    def __init__(self, name: str, trace: str, ts: float, dur: float,
                 cat: str = "", proc: str = "host",
                 args: Optional[Dict[str, Any]] = None):
        self.name = name
        self.trace = trace      # trial id ("" = control plane)
        self.ts = ts
        self.dur = dur
        self.cat = cat
        self.proc = proc        # "host" (runner/worker thread) | "worker" (child process)
        self.args = args or {}

    def __repr__(self):
        return (f"Span({self.name!r}, trace={self.trace!r}, ts={self.ts:.6f}, "
                f"dur={self.dur:.6f}, cat={self.cat!r}, proc={self.proc!r})")


class _NullSpanCtx:
    """Shared no-op context manager for the disabled tracer."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def arg(self, key: str, value: Any) -> None:
        pass


_NULL_CTX = _NullSpanCtx()


class _SpanCtx:
    """Live ``with tracer.span(...)`` body; ``arg()`` annotates before exit."""

    __slots__ = ("_tracer", "_name", "_trace", "_cat", "_proc", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, trace: str, cat: str,
                 proc: str, args: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._trace = trace
        self._cat = cat
        self._proc = proc
        self._args = args

    def __enter__(self):
        self._t0 = self._tracer.clock.time()
        return self

    def arg(self, key: str, value: Any) -> None:
        self._args[key] = value

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self._args.setdefault("error", exc_type.__name__)
        self._tracer.record(self._name, self._trace, self._t0,
                            self._tracer.clock.time() - self._t0,
                            cat=self._cat, proc=self._proc, **self._args)
        return False


class Tracer:
    """Thread-safe span collector bound to one injected clock.

    ``record`` appends a finished span; ``span()`` is the context-manager
    form; ``begin``/``end`` bracket phases whose start and finish happen in
    different calls (a trial's lifecycle span opens at launch and closes at
    stop/pause/requeue).  ``adopt`` ingests wire-format tuples that arrived
    over a bus event or a worker pipe.
    """

    def __init__(self, clock: Optional[Any] = None, enabled: bool = True):
        if clock is None:
            from ..core.clock import get_default_clock  # lazy: no import cycle
            clock = get_default_clock()
        self.clock = clock
        self.enabled = enabled
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._open: Dict[Any, Tuple[str, str, str, str, Dict[str, Any], float]] = {}

    # -- recording ----------------------------------------------------------------
    def record(self, name: str, trace: str, ts: float, dur: float,
               cat: str = "", proc: str = "host", **args: Any) -> None:
        if not self.enabled:
            return
        clean = {k: v for k, v in args.items() if isinstance(v, _JSON_SCALARS)}
        with self._lock:
            self._spans.append(Span(name, trace, ts, dur, cat, proc, clean))

    def span(self, name: str, trace: str = "", cat: str = "",
             proc: str = "host", **args: Any):
        if not self.enabled:
            return _NULL_CTX
        return _SpanCtx(self, name, trace, cat, proc, dict(args))

    def begin(self, key: Any, name: str, trace: str, cat: str = "",
              proc: str = "host", **args: Any) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._open[key] = (name, trace, cat, proc, dict(args),
                               self.clock.time())

    def end(self, key: Any, **extra: Any) -> None:
        if not self.enabled:
            return
        with self._lock:
            rec = self._open.pop(key, None)
        if rec is None:
            return
        name, trace, cat, proc, args, t0 = rec
        args.update(extra)
        self.record(name, trace, t0, self.clock.time() - t0,
                    cat=cat, proc=proc, **args)

    def end_all(self, **extra: Any) -> None:
        with self._lock:
            keys = list(self._open)
        for key in keys:
            self.end(key, **extra)

    def adopt(self, trace: str, spans: List[SpanTuple]) -> None:
        """Ingest wire-format spans shipped from a worker thread/process."""
        if not self.enabled:
            return
        for name, ts, dur, cat, proc, args in spans:
            self.record(name, trace, float(ts), float(dur),
                        cat=str(cat), proc=str(proc), **dict(args))

    # -- introspection ---------------------------------------------------------------
    @property
    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def spans_named(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._open.clear()

    # -- Chrome trace-event export (DESIGN.md §8) --------------------------------------
    def chrome_events(self) -> List[Dict[str, Any]]:
        """Canonical trace-event list: metadata rows first, then "X" complete
        events with integer-µs timestamps rebased to the earliest span.

        Canonicalization is what makes identical VirtualClock runs export
        byte-identical files: rows (tids) are assigned from the *sorted* set
        of trace ids, events are sorted by (ts, pid, tid, name, dur), and the
        caller serializes with sorted keys and fixed separators.
        """
        spans = self.spans
        traces = sorted({s.trace for s in spans if s.trace})
        tid_of = {t: i + 1 for i, t in enumerate(traces)}  # tid 0 = control plane
        pid_of = {"host": 1, "worker": 2}
        t0 = min((s.ts for s in spans), default=0.0)
        events: List[Dict[str, Any]] = []
        for pid, label in ((1, "control-plane (host)"), (2, "trial workers (child)")):
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": 0, "args": {"name": label}})
        for trace, tid in tid_of.items():
            for pid in (1, 2):
                events.append({"ph": "M", "name": "thread_name", "pid": pid,
                               "tid": tid, "args": {"name": trace}})
        xs = []
        for s in spans:
            xs.append({
                "ph": "X",
                "name": s.name,
                "cat": s.cat or "span",
                "pid": pid_of.get(s.proc, 1),
                "tid": tid_of.get(s.trace, 0),
                "ts": int(round((s.ts - t0) * 1e6)),
                "dur": max(1, int(round(s.dur * 1e6))),
                "args": dict(sorted(s.args.items())),
            })
        xs.sort(key=lambda e: (e["ts"], e["pid"], e["tid"], e["name"], e["dur"]))
        return events + xs

    def chrome_json(self) -> str:
        return json.dumps({"displayTimeUnit": "ms",
                           "traceEvents": self.chrome_events()},
                          sort_keys=True, separators=(",", ":")) + "\n"

    def export_chrome(self, path: str) -> str:
        """Write the Perfetto/chrome://tracing-viewable trace; returns path."""
        import os
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.chrome_json())
        return path


class _NullClock:
    """Never consulted: the null tracer early-returns before reading time."""

    __slots__ = ()

    def time(self) -> float:  # pragma: no cover — defensive only
        return 0.0


NULL_TRACER = Tracer(clock=_NullClock(), enabled=False)
