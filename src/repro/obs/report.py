"""Static self-contained HTML run report (DESIGN.md §9).

``build_report`` renders one experiment's artifacts — the JSONL journal
(required), the Chrome trace (optional) and the metrics JSONL stream
(optional) — into a single HTML string with inline CSS and inline SVG: no
scripts, no external fetches, nothing but the file.  ``launch/report.py``
is the CLI wrapper that writes it next to the trace.

Sections: run summary + status tiles, best-config table, per-trial metric
curves (best trial highlighted, the rest recessive), trial-lifecycle gantt
reconstructed from the trace's ``thread_name`` metadata + ``trial`` spans
(restart markers from the ``restart`` fault instants), and the control-plane
metrics snapshot (counter table + latency-histogram mean bars).

Determinism contract: the output is a pure function of the input files —
no generation timestamps, all iteration orders sorted, all floats formatted
through one ``%.6g`` path — so two identical VirtualClock runs produce
byte-identical report bodies (asserted in tests/test_analysis_report.py).

Palette: the dataviz reference instance (validated for both modes) — series
slots 1-2, status colors paired with text labels, text in ink tokens only.
"""
from __future__ import annotations

import html
import json
from typing import Any, Dict, List, Optional, Tuple

from .analysis import ExperimentAnalysis

__all__ = ["build_report"]

_MAX_CURVES = 64       # polylines in the metric chart
_MAX_GANTT_ROWS = 64   # trial rows in the lifecycle gantt
_MAX_CONFIG_ROWS = 10  # best-config table

_CSS = """
:root { color-scheme: light; }
body {
  margin: 2rem auto; max-width: 62rem; padding: 0 1rem;
  font-family: system-ui, -apple-system, "Segoe UI", sans-serif;
  background: #f9f9f7; color: #0b0b0b;
  --surface-1: #fcfcfb; --text-primary: #0b0b0b; --text-secondary: #52514e;
  --text-muted: #898781; --grid: #e1e0d9; --baseline: #c3c2b7;
  --series-1: #2a78d6; --series-2: #eb6834; --series-rest: #9ec5f4;
  --status-critical: #d03b3b; --status-good: #0ca30c;
  --border: rgba(11,11,11,0.10);
}
@media (prefers-color-scheme: dark) {
  :root { color-scheme: dark; }
  body {
    background: #0d0d0d; color: #ffffff;
    --surface-1: #1a1a19; --text-primary: #ffffff; --text-secondary: #c3c2b7;
    --text-muted: #898781; --grid: #2c2c2a; --baseline: #383835;
    --series-1: #3987e5; --series-2: #d95926; --series-rest: #184f95;
    --status-critical: #d03b3b; --status-good: #0ca30c;
    --border: rgba(255,255,255,0.10);
  }
}
h1 { font-size: 1.4rem; } h2 { font-size: 1.05rem; margin-top: 2rem; }
.card { background: var(--surface-1); border: 1px solid var(--border);
        border-radius: 8px; padding: 1rem; margin: 0.75rem 0; }
.tiles { display: flex; flex-wrap: wrap; gap: 0.75rem; }
.tile { background: var(--surface-1); border: 1px solid var(--border);
        border-radius: 8px; padding: 0.6rem 1rem; min-width: 7rem; }
.tile .label { font-size: 0.75rem; color: var(--text-secondary); }
.tile .value { font-size: 1.5rem; font-weight: 600; }
table { border-collapse: collapse; width: 100%; font-size: 0.85rem; }
th { text-align: left; color: var(--text-secondary); font-weight: 600; }
th, td { padding: 0.3rem 0.6rem; border-bottom: 1px solid var(--grid); }
td.num, th.num { text-align: right; font-variant-numeric: tabular-nums; }
.note { color: var(--text-muted); font-size: 0.8rem; }
svg text { font-family: system-ui, -apple-system, "Segoe UI", sans-serif; }
.legend { display: flex; gap: 1.25rem; font-size: 0.8rem;
          color: var(--text-secondary); margin: 0.25rem 0 0.5rem; }
.legend .key { display: inline-block; width: 14px; height: 3px;
               border-radius: 2px; vertical-align: middle;
               margin-right: 0.4rem; }
"""


def _esc(v: Any) -> str:
    return html.escape(str(v), quote=True)


def _fmt(v: Any) -> str:
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return _esc(v)
    if isinstance(v, int):
        return f"{v:,}"
    return f"{v:.6g}"


def _nice_ticks(lo: float, hi: float, n: int = 4) -> List[float]:
    """Clean tick values covering [lo, hi] — deterministic, no float drift
    surprises (everything renders through %.6g anyway)."""
    if hi <= lo:
        return [lo]
    span = hi - lo
    import math
    step = 10 ** math.floor(math.log10(span / max(n, 1)))
    for mult in (1, 2, 5, 10):
        if span / (step * mult) <= n:
            step *= mult
            break
    first = math.ceil(lo / step) * step
    ticks, t = [], first
    while t <= hi + 1e-12 * span:
        ticks.append(round(t, 10))
        t += step
    return ticks or [lo]


# -- metric curves ---------------------------------------------------------------
def _metric_chart(analysis: ExperimentAnalysis, metric: str, mode: str) -> str:
    series: List[Tuple[str, List[Tuple[int, float]]]] = []
    for tid in sorted(analysis.records):
        pts = analysis.records[tid].series.get(metric)
        if pts:
            series.append((tid, [(it, v) for _, it, v in pts]))
    if not series:
        return "<p class='note'>no numeric series for this metric in the journal</p>"
    best = analysis.best_trial(metric, mode)
    best_id = best.trial_id if best is not None else None
    shown = series[:_MAX_CURVES]
    if best_id is not None and best_id not in {t for t, _ in shown}:
        shown = shown[:-1] + [(best_id, [
            (it, v) for _, it, v in analysis.records[best_id].series[metric]])]

    w, h, ml, mr, mt, mb = 640, 240, 52, 110, 12, 28
    xs = [p[0] for _, pts in shown for p in pts]
    ys = [p[1] for _, pts in shown for p in pts]
    x0, x1 = min(xs), max(xs)
    y0, y1 = min(ys), max(ys)
    if x1 == x0:
        x1 = x0 + 1
    if y1 == y0:
        y1 = y0 + 1 or 1

    def X(x: float) -> float:
        return ml + (x - x0) / (x1 - x0) * (w - ml - mr)

    def Y(y: float) -> float:
        return h - mb - (y - y0) / (y1 - y0) * (h - mt - mb)

    out = [f"<svg viewBox='0 0 {w} {h}' width='{w}' height='{h}' "
           f"role='img' aria-label='{_esc(metric)} per trial'>"]
    for ty in _nice_ticks(y0, y1):
        out.append(f"<line x1='{ml}' y1='{Y(ty):.1f}' x2='{w - mr}' "
                   f"y2='{Y(ty):.1f}' stroke='var(--grid)' stroke-width='1'/>")
        out.append(f"<text x='{ml - 6}' y='{Y(ty) + 3:.1f}' text-anchor='end' "
                   f"font-size='10' fill='var(--text-muted)'>{_fmt(ty)}</text>")
    for tx in _nice_ticks(x0, x1):
        out.append(f"<text x='{X(tx):.1f}' y='{h - mb + 14}' text-anchor='middle' "
                   f"font-size='10' fill='var(--text-muted)'>{_fmt(tx)}</text>")
    out.append(f"<line x1='{ml}' y1='{h - mb}' x2='{w - mr}' y2='{h - mb}' "
               f"stroke='var(--baseline)' stroke-width='1'/>")
    best_svg = ""
    for tid, pts in shown:
        d = " ".join(f"{X(x):.1f},{Y(y):.1f}" for x, y in pts)
        label = _esc(tid)
        if tid == best_id:
            # Best trial on top of the recessive rest, end-dot + direct label.
            ex, ey = X(pts[-1][0]), Y(pts[-1][1])
            best_svg = (
                f"<polyline points='{d}' fill='none' stroke='var(--series-1)' "
                f"stroke-width='2' stroke-linejoin='round' "
                f"stroke-linecap='round'><title>{label}</title></polyline>"
                f"<circle cx='{ex:.1f}' cy='{ey:.1f}' r='4' "
                f"fill='var(--series-1)' stroke='var(--surface-1)' "
                f"stroke-width='2'/>"
                f"<text x='{ex + 8:.1f}' y='{ey + 3:.1f}' font-size='10' "
                f"fill='var(--text-secondary)'>{label}</text>")
        else:
            out.append(f"<polyline points='{d}' fill='none' "
                       f"stroke='var(--series-rest)' stroke-width='1.5' "
                       f"stroke-linejoin='round'><title>{label}</title>"
                       f"</polyline>")
    out.append(best_svg)
    out.append("</svg>")
    note = ""
    if len(series) > len(shown):
        note = (f"<p class='note'>showing {len(shown)} of {len(series)} "
                f"trial curves (cap {_MAX_CURVES}); the rest are in the "
                f"table below</p>")
    legend = (
        "<div class='legend'>"
        "<span><span class='key' style='background:var(--series-1)'></span>"
        f"best trial ({_esc(best_id) if best_id else 'n/a'})</span>"
        "<span><span class='key' style='background:var(--series-rest)'></span>"
        "other trials</span></div>")
    return legend + "".join(out) + note


# -- lifecycle gantt (from the Chrome trace) --------------------------------------
def _load_trace(path: str) -> List[Dict[str, Any]]:
    with open(path) as f:
        obj = json.load(f)
    evs = obj.get("traceEvents", obj) if isinstance(obj, dict) else obj
    return evs if isinstance(evs, list) else []


def _gantt_chart(trace_events: List[Dict[str, Any]]) -> str:
    # tid -> row label from thread_name metadata (trial ids; tid 0 = control).
    names: Dict[int, str] = {}
    for e in trace_events:
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            names[e.get("tid", -1)] = e.get("args", {}).get("name", "")
    spans = [e for e in trace_events
             if e.get("ph") == "X" and e.get("name") == "trial"]
    restarts = [e for e in trace_events
                if e.get("ph") == "X" and e.get("name") == "restart"]
    if not spans:
        return ("<p class='note'>no trial lifecycle spans in the trace "
                "(was the run traced?)</p>")
    rows = sorted({names.get(e.get("tid"), str(e.get("tid"))) for e in spans})
    shown_rows = rows[:_MAX_GANTT_ROWS]
    row_of = {r: i for i, r in enumerate(shown_rows)}
    t1 = max(e["ts"] + e.get("dur", 0) for e in spans) or 1

    rh, gap, ml, mr, mt, mb = 12, 4, 150, 16, 8, 22
    w = 640
    h = mt + mb + len(shown_rows) * (rh + gap)
    plot_w = w - ml - mr

    def X(ts: float) -> float:
        return ml + ts / t1 * plot_w

    out = [f"<svg viewBox='0 0 {w} {h}' width='{w}' height='{h}' role='img' "
           f"aria-label='trial lifecycle gantt'>"]
    for tx in _nice_ticks(0, t1 / 1e6):
        out.append(f"<line x1='{X(tx * 1e6):.1f}' y1='{mt}' "
                   f"x2='{X(tx * 1e6):.1f}' y2='{h - mb}' "
                   f"stroke='var(--grid)' stroke-width='1'/>")
        out.append(f"<text x='{X(tx * 1e6):.1f}' y='{h - 6}' "
                   f"text-anchor='middle' font-size='10' "
                   f"fill='var(--text-muted)'>{_fmt(tx)}s</text>")
    for e in sorted(spans, key=lambda e: (e.get("tid", 0), e["ts"])):
        label = names.get(e.get("tid"), str(e.get("tid")))
        if label not in row_of:
            continue
        y = mt + row_of[label] * (rh + gap)
        x, bw = X(e["ts"]), max(2.0, e.get("dur", 0) / t1 * plot_w)
        dur_s = e.get("dur", 0) / 1e6
        status = e.get("args", {}).get("status", "")
        out.append(
            f"<rect x='{x:.1f}' y='{y}' width='{bw:.1f}' height='{rh}' "
            f"rx='2' fill='var(--series-1)'>"
            f"<title>{_esc(label)}: {_fmt(dur_s)}s"
            f"{' → ' + _esc(status) if status else ''}</title></rect>")
    for e in restarts:
        label = names.get(e.get("tid"), str(e.get("tid")))
        if label not in row_of:
            continue
        y = mt + row_of[label] * (rh + gap)
        out.append(
            f"<rect x='{X(e['ts']) - 1:.1f}' y='{y - 2}' width='2' "
            f"height='{rh + 4}' fill='var(--status-critical)'>"
            f"<title>restart: {_esc(label)}</title></rect>")
    for label, i in row_of.items():
        y = mt + i * (rh + gap) + rh - 2
        out.append(f"<text x='{ml - 6}' y='{y}' text-anchor='end' "
                   f"font-size='9' fill='var(--text-secondary)'>"
                   f"{_esc(label)}</text>")
    out.append("</svg>")
    note = ""
    if len(rows) > len(shown_rows):
        note = (f"<p class='note'>showing {len(shown_rows)} of {len(rows)} "
                f"trial rows (cap {_MAX_GANTT_ROWS})</p>")
    legend = (
        "<div class='legend'>"
        "<span><span class='key' style='background:var(--series-1)'></span>"
        "lifecycle span (launch → stop/pause)</span>"
        "<span><span class='key' "
        "style='background:var(--status-critical);width:3px;height:12px'>"
        "</span>restart (fault boundary)</span></div>")
    return legend + "".join(out) + note


# -- metrics snapshot -------------------------------------------------------------
def _last_metrics_snapshot(path: str) -> Optional[Dict[str, Any]]:
    last = None
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except ValueError:
                continue  # truncated tail
            if isinstance(obj, dict) and "metrics" in obj:
                last = obj
    return last


def _metrics_section(snap: Dict[str, Any]) -> str:
    metrics: Dict[str, Any] = snap.get("metrics", {})
    scalars = {k: v for k, v in sorted(metrics.items())
               if not isinstance(v, dict)}
    hists = {k: v for k, v in sorted(metrics.items())
             if isinstance(v, dict) and v.get("count")}
    out = []
    if hists:
        # Horizontal mean-latency bars: one hue, magnitude only.
        w, rh, gap, ml = 640, 14, 6, 170
        h = (rh + gap) * len(hists) + 24
        vmax = max(v["mean"] for v in hists.values()) or 1
        out.append(f"<svg viewBox='0 0 {w} {h}' width='{w}' height='{h}' "
                   f"role='img' aria-label='histogram means'>")
        for i, (name, v) in enumerate(hists.items()):
            y = i * (rh + gap)
            bw = max(2.0, v["mean"] / vmax * (w - ml - 120))
            out.append(f"<text x='{ml - 6}' y='{y + rh - 3}' text-anchor='end' "
                       f"font-size='10' fill='var(--text-secondary)'>"
                       f"{_esc(name)}</text>")
            out.append(f"<rect x='{ml}' y='{y}' width='{bw:.1f}' "
                       f"height='{rh}' rx='2' fill='var(--series-1)'>"
                       f"<title>{_esc(name)}: mean {_fmt(v['mean'])} "
                       f"(n={v['count']})</title></rect>")
            out.append(f"<text x='{ml + bw + 6:.1f}' y='{y + rh - 3}' "
                       f"font-size='10' fill='var(--text-secondary)'>"
                       f"{_fmt(v['mean'])} (n={_fmt(v['count'])})</text>")
        out.append("</svg>")
        out.append("<p class='note'>mean per histogram instrument "
                   "(µs for *_us, bytes/seconds otherwise), from the final "
                   "metrics snapshot</p>")
    if scalars:
        out.append("<table><tr><th>counter / gauge</th>"
                   "<th class='num'>value</th></tr>")
        for k, v in scalars.items():
            out.append(f"<tr><td>{_esc(k)}</td>"
                       f"<td class='num'>{_fmt(v)}</td></tr>")
        out.append("</table>")
    return "".join(out) or "<p class='note'>metrics stream is empty</p>"


# -- trial/fault tables -----------------------------------------------------------
def _best_table(analysis: ExperimentAnalysis, metric: str, mode: str) -> str:
    ranked = []
    for tid in sorted(analysis.records):
        v = analysis.records[tid].best_value(metric, mode)
        if v is not None:
            ranked.append((v, tid))
    ranked.sort(key=lambda p: (-p[0], p[1]) if mode == "max" else p)
    if not ranked:
        return "<p class='note'>no trials reported this metric</p>"
    keys = sorted({k for _, tid in ranked[:_MAX_CONFIG_ROWS]
                   for k in analysis.records[tid].config})
    out = ["<table><tr><th>#</th><th>trial</th>",
           f"<th class='num'>best {_esc(metric)}</th><th class='num'>iters</th>",
           f"<th class='num'>restarts</th>"]
    out += [f"<th class='num'>{_esc(k)}</th>" for k in keys]
    out.append("</tr>")
    for rank, (v, tid) in enumerate(ranked[:_MAX_CONFIG_ROWS], 1):
        r = analysis.records[tid]
        out.append(f"<tr><td>{rank}</td><td>{_esc(tid)}</td>"
                   f"<td class='num'>{_fmt(v)}</td>"
                   f"<td class='num'>{_fmt(r.iterations)}</td>"
                   f"<td class='num'>{_fmt(r.count('restarted'))}</td>")
        out += [f"<td class='num'>{_fmt(r.config.get(k, ''))}</td>"
                for k in keys]
        out.append("</tr>")
    out.append("</table>")
    if len(ranked) > _MAX_CONFIG_ROWS:
        out.append(f"<p class='note'>top {_MAX_CONFIG_ROWS} of "
                   f"{len(ranked)} ranked trials</p>")
    return "".join(out)


def _fault_table(analysis: ExperimentAnalysis) -> str:
    rows = []
    for tid in sorted(analysis.records):
        r = analysis.records[tid]
        n_restart, n_resize, n_kill = (r.count("restarted"),
                                       r.count("resized"), r.count("killed"))
        if n_restart or n_resize or n_kill or r.status == "ERROR":
            rows.append((tid, r, n_restart, n_resize, n_kill))
    if not rows:
        return "<p class='note'>clean run: no restarts, resizes, or kills</p>"
    out = ["<table><tr><th>trial</th><th>status</th>"
           "<th class='num'>restarts</th><th class='num'>resizes</th>"
           "<th class='num'>kills</th><th>decision timeline</th></tr>"]
    for tid, r, n_restart, n_resize, n_kill in rows[:_MAX_GANTT_ROWS]:
        timeline = "; ".join(
            f"{d['kind']}@{_fmt(d['t'])}" for d in r.decision_timeline()[:8])
        out.append(
            f"<tr><td>{_esc(tid)}</td><td>{_esc(r.status or 'in flight')}</td>"
            f"<td class='num'>{n_restart}</td><td class='num'>{n_resize}</td>"
            f"<td class='num'>{n_kill}</td><td>{_esc(timeline)}</td></tr>")
    out.append("</table>")
    if len(rows) > _MAX_GANTT_ROWS:
        out.append(f"<p class='note'>first {_MAX_GANTT_ROWS} of {len(rows)} "
                   f"trials with fault/decision activity</p>")
    return "".join(out)


def _provenance_table(analysis: ExperimentAnalysis) -> str:
    """Decision provenance (DESIGN.md §10): per-trial terminal verdicts with
    the inputs that produced them, rendered via ``format_decision`` so the
    report answers "why?" with the same words as the explain CLI."""
    from .analysis import format_decision
    rows = []
    n_total = 0
    for tid in sorted(analysis.records):
        decs = analysis.records[tid].decisions()
        if not decs:
            continue
        n_total += len(decs)
        # The last non-SUGGEST decision is the trial's fate; fall back to
        # the suggestion record for trials that ran to completion untouched.
        fate = next((d for d in reversed(decs)
                     if d["info"].get("verdict") != "SUGGEST"), decs[-1])
        rows.append((tid, len(decs), fate))
    if not rows:
        return ""
    out = ["<h2>Decision provenance</h2><div class='card'>",
           "<table><tr><th>trial</th><th class='num'>decisions</th>"
           "<th class='num'>t</th><th>last verdict (why)</th></tr>"]
    for tid, n, fate in rows[:_MAX_GANTT_ROWS]:
        out.append(f"<tr><td>{_esc(tid)}</td><td class='num'>{n}</td>"
                   f"<td class='num'>{_fmt(fate['t'])}</td>"
                   f"<td>{_esc(format_decision(fate['info']))}</td></tr>")
    out.append("</table>")
    if len(rows) > _MAX_GANTT_ROWS:
        out.append(f"<p class='note'>first {_MAX_GANTT_ROWS} of {len(rows)} "
                   f"trials with decision records</p>")
    out.append(f"<p class='note'>{n_total} DECISION records across "
               f"{len(rows)} trials (schema v3 journal)</p></div>")
    return "".join(out)


def _profile_table(analysis: ExperimentAnalysis) -> str:
    rows = [(tid, analysis.records[tid].profile)
            for tid in sorted(analysis.records)
            if analysis.records[tid].profile]
    if not rows:
        return ""
    cols = ["compile_s", "steady_step_s", "predicted_step_s", "dominant",
            "arg_bytes", "temp_bytes"]
    out = ["<h2>Hardware profiles</h2><div class='card'>",
           "<table><tr><th>trial</th>"]
    out += [f"<th class='num'>{_esc(c)}</th>" for c in cols]
    out.append("</tr>")
    for tid, prof in rows[:_MAX_GANTT_ROWS]:
        out.append(f"<tr><td>{_esc(tid)}</td>")
        out += [f"<td class='num'>{_fmt(prof.get(c, '-'))}</td>" for c in cols]
        out.append("</tr>")
    out.append("</table>")
    out.append("<p class='note'>step-time split is wall-clock (first step = "
               "compile + execute); roofline prediction from "
               "launch/roofline.py when profiling was enabled</p></div>")
    return "".join(out)


# -- entry point ------------------------------------------------------------------
def build_report(journal_path: Optional[str] = None,
                 analysis: Optional[ExperimentAnalysis] = None,
                 trace_path: Optional[str] = None,
                 metrics_path: Optional[str] = None,
                 metric: Optional[str] = None,
                 mode: str = "max",
                 title: str = "repro run report") -> str:
    """Render the report; pass a journal path or a pre-built analysis."""
    if analysis is None:
        if journal_path is None:
            raise ValueError("build_report needs journal_path or analysis")
        analysis = ExperimentAnalysis.from_journal(journal_path)
    if metric is None:
        # Deterministic default: the lexicographically-first metric any
        # trial reported.
        metric = next(iter(sorted(
            {m for r in analysis.records.values() for m in r.series})), None)

    header = analysis.header or {}
    tiles = [("trials", len(analysis.records)),
             ("results", sum(r.n_results for r in analysis.records.values())),
             ("iterations",
              sum(r.iterations for r in analysis.records.values()))]
    tiles += sorted(analysis.status_counts().items())
    tile_html = "".join(
        f"<div class='tile'><div class='label'>{_esc(k)}</div>"
        f"<div class='value'>{_fmt(v)}</div></div>" for k, v in tiles)

    head_rows = "".join(
        f"<tr><td>{_esc(k)}</td><td>{_esc(header.get(k, '-'))}</td></tr>"
        for k in ("schema_version", "clock", "executor"))

    parts = [
        "<!DOCTYPE html><html><head><meta charset='utf-8'>",
        f"<title>{_esc(title)}</title><style>{_CSS}</style></head><body>",
        f"<h1>{_esc(title)}</h1>",
        f"<div class='tiles'>{tile_html}</div>",
        "<h2>Run</h2><div class='card'><table>",
        head_rows,
        f"<tr><td>skipped journal lines</td>"
        f"<td>{analysis.n_skipped_lines}</td></tr>",
        "</table></div>",
    ]
    if metric is not None:
        parts.append(f"<h2>Best configurations — {_esc(metric)} "
                     f"({_esc(mode)})</h2><div class='card'>")
        parts.append(_best_table(analysis, metric, mode))
        parts.append("</div>")
        parts.append(f"<h2>{_esc(metric)} per trial</h2><div class='card'>")
        parts.append(_metric_chart(analysis, metric, mode))
        parts.append("</div>")
    if trace_path:
        parts.append("<h2>Trial lifecycle (from trace)</h2><div class='card'>")
        try:
            parts.append(_gantt_chart(_load_trace(trace_path)))
        except (OSError, ValueError) as e:
            parts.append(f"<p class='note'>trace unreadable: {_esc(e)}</p>")
        parts.append("</div>")
    parts.append("<h2>Faults &amp; scheduler decisions</h2><div class='card'>")
    parts.append(_fault_table(analysis))
    parts.append("</div>")
    parts.append(_provenance_table(analysis))
    parts.append(_profile_table(analysis))
    if metrics_path:
        parts.append("<h2>Control-plane metrics</h2><div class='card'>")
        try:
            snap = _last_metrics_snapshot(metrics_path)
            parts.append(_metrics_section(snap) if snap else
                         "<p class='note'>metrics stream is empty</p>")
        except OSError as e:
            parts.append(f"<p class='note'>metrics unreadable: {_esc(e)}</p>")
        parts.append("</div>")
    parts.append("</body></html>\n")
    return "".join(parts)
