"""repro.obs — control-plane observability (DESIGN.md §8).

One clock-injected bundle threaded through the whole stack:

- ``Tracer`` (tracing.py) — per-trial spans for every lifecycle phase,
  deterministic under a ``VirtualClock``, exported as Chrome trace-event JSON.
- ``MetricsRegistry`` (metrics.py) — counters/gauges/histograms over the hot
  paths (EventBus fan-in, SlicePool first-fit, scheduler decisions,
  checkpoint bytes+latency, heartbeat lag, restarts/kills/resizes),
  snapshotted periodically to a JSONL metrics stream.

``Observability`` owns both plus the snapshot throttle; ``NULL_OBS`` is the
shared disabled instance every component defaults to — its ``active`` flag is
False and every method early-returns, so with observability off the per-event
cost is one attribute test (the bench_overhead acceptance gate).

This package imports nothing from ``repro.core`` at module level (clock
defaults resolve lazily), so ``repro.core`` modules can import it without a
cycle.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional

from .analysis import ExperimentAnalysis, TrialRecord
from .flightrec import FlightRecorder, SearchStateSnapshotter, json_safe
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .tracing import NULL_TRACER, Span, Tracer

__all__ = ["Observability", "NULL_OBS",
           "Tracer", "Span", "NULL_TRACER",
           "MetricsRegistry", "Counter", "Gauge", "Histogram",
           "ExperimentAnalysis", "TrialRecord",
           "FlightRecorder", "SearchStateSnapshotter", "json_safe"]

METRICS_SCHEMA_VERSION = 1


class Observability:
    """Tracer + metrics registry + periodic JSONL metrics snapshots.

    - ``trace``: falsy = tracing off; True = collect spans in memory; a path
      string = collect AND export Chrome trace-event JSON there on ``close()``.
    - ``metrics``: falsy = metrics off; True = registry only (queried in
      process); a path string = registry + JSONL snapshot stream at that path,
      flushed every ``metrics_interval`` clock-seconds (plus a final snapshot
      on close).

    All throttling runs on the injected clock's timestamp axis, so a
    VirtualClock run snapshots on virtual seconds.  The heavyweight samplers
    (pool utilization, bus depth) run only inside ``snapshot`` — never per
    event.
    """

    def __init__(self, trace: Any = None, metrics: Any = None,
                 metrics_interval: float = 10.0,
                 clock: Optional[Any] = None):
        if clock is None:
            from ..core.clock import get_default_clock  # lazy: no import cycle
            clock = get_default_clock()
        self.clock = clock
        self.trace_path: Optional[str] = trace if isinstance(trace, str) else None
        self.tracer = Tracer(clock=clock, enabled=bool(trace))
        self.metrics: Optional[MetricsRegistry] = \
            MetricsRegistry() if metrics else None
        self.metrics_path: Optional[str] = \
            metrics if isinstance(metrics, str) else None
        self.metrics_interval = float(metrics_interval)
        self.active = bool(trace) or bool(metrics)
        self._snap_lock = threading.Lock()
        self._next_snap: Optional[float] = None
        self._mfile = None
        self._closed = False
        # Pre-resolved instruments for the event-routing hot path.
        if self.metrics is not None:
            self._m_hb_lag = self.metrics.histogram("hb.lag_s")
            self._m_ckpt_bytes = self.metrics.histogram("ckpt.bytes")
            self._event_counters: Dict[Any, Counter] = {}
        else:
            self._m_hb_lag = self._m_ckpt_bytes = None
            self._event_counters = {}

    def bind_clock(self, clock: Any) -> None:
        """Rebind the bundle (and its tracer) onto ``clock``.  Harnesses that
        construct the Observability before installing a VirtualClock (e.g.
        ``run_scenario``) call this so every span timestamp rides the virtual
        time axis — the precondition for byte-identical trace exports."""
        self.clock = clock
        self.tracer.clock = clock

    # -- event routing (runner thread) -------------------------------------------------
    def on_event(self, event: Any) -> None:
        """Every TrialEvent the runner drains flows through here: count it,
        fold special payloads into metrics, adopt shipped SPAN batches."""
        if not self.active:
            return
        kind = getattr(getattr(event, "type", None), "value", None)
        if self.metrics is not None and kind is not None:
            ctr = self._event_counters.get(kind)
            if ctr is None:
                ctr = self._event_counters[kind] = \
                    self.metrics.counter(f"events.{kind.lower()}")
            ctr.inc()
            if kind == "HEARTBEAT_MISSED":
                stalled = event.info.get("stalled_s")
                if stalled is not None:
                    self._m_hb_lag.observe(float(stalled))
        if kind == "SPAN":
            spans = event.info.get("spans", ())
            if self.tracer.enabled:
                self.tracer.adopt(event.trial_id, spans)
            if self.metrics is not None:
                for sp in spans:
                    nbytes = sp[5].get("bytes") if len(sp) > 5 else None
                    if nbytes is not None:
                        self._m_ckpt_bytes.observe(float(nbytes))

    # -- metrics snapshot stream --------------------------------------------------------
    def maybe_snapshot(self, executor: Any = None) -> bool:
        """Throttled snapshot; call freely from the runner loop."""
        if self.metrics is None or self.metrics_path is None:
            return False
        now = self.clock.time()
        with self._snap_lock:
            if self._next_snap is not None and now < self._next_snap:
                return False
            self._next_snap = now + self.metrics_interval
        self.snapshot(executor)
        return True

    def sample(self, executor: Any = None) -> None:
        """Point-in-time gauges that are too costly to maintain per event."""
        if self.metrics is None:
            return
        if executor is not None:
            bus = getattr(executor, "bus", None)
            if bus is not None:
                self.metrics.gauge("bus.depth").set(len(bus))
            pool = getattr(executor, "slice_pool", None)
            if pool is not None:
                self.metrics.gauge("pool.utilization").set(
                    round(pool.utilization(), 4))
                self.metrics.gauge("pool.fragments").set(pool.fragments())

    def snapshot(self, executor: Any = None) -> None:
        if self.metrics is None or self.metrics_path is None or self._closed:
            return
        self.sample(executor)
        if self._mfile is None:
            import os
            os.makedirs(os.path.dirname(self.metrics_path) or ".",
                        exist_ok=True)
            self._mfile = open(self.metrics_path, "w")
        self._mfile.write(self.metrics.snapshot_line(
            self.clock.time(), METRICS_SCHEMA_VERSION) + "\n")
        self._mfile.flush()

    # -- teardown ------------------------------------------------------------------
    def close(self, executor: Any = None) -> None:
        """Final metrics snapshot + Chrome trace export (when paths are set)."""
        if self._closed:
            return
        self.tracer.end_all()
        self.snapshot(executor)
        self._closed = True
        if self._mfile is not None:
            self._mfile.close()
            self._mfile = None
        if self.trace_path and self.tracer.enabled:
            self.tracer.export_chrome(self.trace_path)


class _NullObservability(Observability):
    """The shared disabled bundle: ``active`` False, tracer disabled, no
    registry — every guard in the hot paths reduces to one attribute test."""

    def __init__(self):
        self.clock = None
        self.trace_path = None
        self.tracer = NULL_TRACER
        self.metrics = None
        self.metrics_path = None
        self.metrics_interval = 0.0
        self.active = False
        self._snap_lock = threading.Lock()
        self._next_snap = None
        self._mfile = None
        self._closed = False
        self._m_hb_lag = self._m_ckpt_bytes = None
        self._event_counters = {}

    def on_event(self, event: Any) -> None:
        pass

    def maybe_snapshot(self, executor: Any = None) -> bool:
        return False

    def snapshot(self, executor: Any = None) -> None:
        pass

    def close(self, executor: Any = None) -> None:
        pass


NULL_OBS = _NullObservability()
