"""ExperimentAnalysis over the JSONL journal (DESIGN.md §9).

``repro.core.experiment.ExperimentAnalysis`` answers queries from live Trial
objects; this module answers the same questions from the *journal* — the
``events.jsonl`` stream a run leaves behind — so a detached process (report
generator, dashboard, a later resume) can reconstruct per-trial time series
and the scheduler's decision history without the producing process.

Parsing contract (mirrors JSONLLogger):

- A v2 stream opens with a ``run_header`` record; v1 streams have none.
  Readers filter on the ``event`` key and ignore unknown keys/records, so
  both parse through one code path.
- A crashed producer may leave a truncated final line — unparseable lines
  are skipped, never raised on.  Every record the producer flushed before
  dying is recovered (JSONLLogger flushes per line).

Determinism contract: ``summary()``/``summary_json()`` fold only journal
fields that are deterministic under a VirtualClock run (virtual timestamps
included; ``run_id`` and hardware-profile wall timings excluded), serialized
with sorted keys and fixed separators — two identical-token scenario runs
produce byte-identical summaries (asserted in tests/test_analysis_report.py).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = ["TrialRecord", "ExperimentAnalysis", "DECISION_EVENTS",
           "format_decision", "parse_journal_lines"]


def parse_journal_lines(lines: Iterable[str]
                        ) -> Tuple[Optional[Dict[str, Any]],
                                   List[Dict[str, Any]], int]:
    """Tolerant ordered parse of a JSONL journal: ``(header, records, skipped)``.

    The one journal-reading code path (parsing contract in the module
    docstring), shared by ``ExperimentAnalysis.from_lines`` and durable
    resume (``repro.core.resume``), which needs the records *in stream
    order* rather than folded per trial.  ``header`` is the first
    ``run_header`` (None on a v1 stream); later headers — a resumed run
    appends one per resume (DESIGN.md §12) — are dropped without counting
    as skipped.  ``records`` holds every other parseable dict in order;
    ``skipped`` counts unparseable/non-dict lines (the torn tail of a
    crashed producer)."""
    header: Optional[Dict[str, Any]] = None
    records: List[Dict[str, Any]] = []
    skipped = 0
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            obj = json.loads(line)
        except (ValueError, TypeError):
            skipped += 1  # truncated tail of a crashed run, or junk
            continue
        if not isinstance(obj, dict):
            skipped += 1
            continue
        if obj.get("event") == "run_header":
            if header is None:
                header = obj
            continue
        records.append(obj)
    return header, records, skipped

# The scheduler/fault decision kinds reconstructed into per-trial timelines
# (lowercased on the wire by JSONLLogger.on_event).  "decision" is the typed
# provenance record (schema v3, DESIGN.md §10): a scheduler/searcher/runner
# verdict carrying the inputs that produced it.
DECISION_EVENTS = ("restarted", "resized", "resize_failed", "credits",
                  "killed", "heartbeat_missed", "decision")


def format_decision(info: Dict[str, Any]) -> str:
    """One-line human rendering of a DECISION record's ``info`` payload.

    Shared by the explain CLI and the HTML report's provenance table, so
    both surfaces answer "why?" with the same words.  Deterministic: pure
    function of the record, %.6g for floats.
    """
    def _f(v: Any) -> str:
        if isinstance(v, float):
            return f"{v:.6g}"
        return str(v)

    verdict = info.get("verdict", "?")
    by = info.get("by", info.get("source", "?"))
    inputs = info.get("inputs") or {}
    reason = inputs.get("reason")
    if reason == "stopping_criterion":
        detail = (f"{inputs.get('criterion')} reached its bound "
                  f"({_f(inputs.get('value'))} >= {_f(inputs.get('bound'))})")
    elif reason == "result_done":
        detail = "trainable reported done"
    elif reason == "max_t":
        detail = f"reached max_t={_f(inputs.get('max_t'))}"
    elif reason == "rung":
        detail = (f"rung@{_f(inputs.get('milestone'))} score "
                  f"{_f(inputs.get('score'))} vs cutoff "
                  f"{_f(inputs.get('cutoff'))} "
                  f"(n={_f(inputs.get('n_rung'))}, rf={_f(inputs.get('rf'))})")
    elif reason == "milestone_wait":
        detail = (f"waiting at milestone {_f(inputs.get('milestone'))} "
                  f"round {_f(inputs.get('round'))} "
                  f"({_f(inputs.get('n_arrived'))}/{_f(inputs.get('n_live'))} "
                  f"arrived)")
    elif reason in ("cut", "cut_after_error"):
        detail = (f"halving cut@{_f(inputs.get('milestone'))} rank "
                  f"{_f(inputs.get('rank'))}/{_f(inputs.get('n_live'))} "
                  f"(keep {_f(inputs.get('n_keep'))}, score "
                  f"{_f(inputs.get('score'))} vs cut "
                  f"{_f(inputs.get('cut_score'))})")
    elif reason == "median":
        detail = (f"best-so-far {_f(inputs.get('best_so_far'))} vs median "
                  f"{_f(inputs.get('median'))} of {_f(inputs.get('n_others'))} "
                  f"trials at step {_f(inputs.get('step'))}")
    elif reason == "exploit":
        detail = (f"exploit donor {inputs.get('donor')} "
                  f"(donor score {_f(inputs.get('donor_score'))} vs mine "
                  f"{_f(inputs.get('my_score'))}, bottom "
                  f"{_f(inputs.get('n_bottom'))}/{_f(inputs.get('population'))})")
    elif "strategy" in inputs:
        extras = {k: v for k, v in sorted(inputs.items()) if k != "strategy"}
        kv = " ".join(f"{k}={_f(v)}" for k, v in extras.items())
        detail = f"suggested via {inputs['strategy']}" + (f" ({kv})" if kv else "")
    else:
        kv = " ".join(f"{k}={_f(v)}" for k, v in sorted(inputs.items()))
        detail = kv or "(no inputs recorded)"
    return f"{verdict} by {by}: {detail}"

_NUMERIC = (int, float)


@dataclass
class TrialRecord:
    """Everything the journal says about one trial."""

    trial_id: str
    config: Dict[str, Any] = field(default_factory=dict)
    status: Optional[str] = None          # terminal status, None = never completed
    iterations: int = 0
    # metric name -> [(t, training_iteration, value)] in journal order
    series: Dict[str, List[Tuple[float, int, float]]] = field(default_factory=dict)
    # full non-result event timeline: [(t, seq, kind, info)] in journal order
    events: List[Tuple[float, int, str, Dict[str, Any]]] = field(default_factory=list)
    profile: Optional[Dict[str, Any]] = None
    n_results: int = 0

    @property
    def completed(self) -> bool:
        return self.status is not None

    def count(self, kind: str) -> int:
        return sum(1 for _, _, k, _ in self.events if k == kind)

    def last_value(self, metric: str) -> Optional[float]:
        pts = self.series.get(metric)
        return pts[-1][2] if pts else None

    def best_value(self, metric: str, mode: str = "max") -> Optional[float]:
        pts = self.series.get(metric)
        if not pts:
            return None
        vals = [v for _, _, v in pts]
        return max(vals) if mode == "max" else min(vals)

    def decision_timeline(self) -> List[Dict[str, Any]]:
        """RESTARTED/RESIZED/CREDITS/KILLED/... fault events merged with the
        typed DECISION provenance records (schema v3), in journal order."""
        return [
            {"t": t, "seq": seq, "kind": kind, "info": info}
            for t, seq, kind, info in self.events if kind in DECISION_EVENTS
        ]

    def decisions(self) -> List[Dict[str, Any]]:
        """Just the typed DECISION records (verdict + inputs), in order."""
        return [
            {"t": t, "seq": seq, "info": info}
            for t, seq, kind, info in self.events if kind == "decision"
        ]


class ExperimentAnalysis:
    """Queryable view over one journal (see module docstring)."""

    def __init__(self, records: Dict[str, TrialRecord],
                 header: Optional[Dict[str, Any]] = None,
                 n_skipped_lines: int = 0):
        self.records = records
        self.header = header            # None on a v1 (header-less) stream
        self.n_skipped_lines = n_skipped_lines

    # -- construction -----------------------------------------------------------
    @classmethod
    def from_journal(cls, path: str) -> "ExperimentAnalysis":
        with open(path, "r") as f:
            return cls.from_lines(f)

    @classmethod
    def from_lines(cls, lines: Iterable[str]) -> "ExperimentAnalysis":
        records: Dict[str, TrialRecord] = {}
        header, stream, skipped = parse_journal_lines(lines)

        def rec(trial_id: str) -> TrialRecord:
            r = records.get(trial_id)
            if r is None:
                r = records[trial_id] = TrialRecord(trial_id)
            return r

        for obj in stream:
            kind = obj.get("event")
            trial_id = obj.get("trial_id")
            if not isinstance(trial_id, str):
                continue  # unknown record shape: tolerated, not indexed
            r = rec(trial_id)
            if kind == "result":
                r.n_results += 1
                it = obj.get("iteration", 0)
                if isinstance(it, _NUMERIC):
                    r.iterations = max(r.iterations, int(it))
                cfg = obj.get("config")
                if isinstance(cfg, dict) and not r.config:
                    r.config = cfg
                t = obj.get("t", 0.0)
                metrics = obj.get("metrics")
                if isinstance(metrics, dict):
                    for m, v in metrics.items():
                        if isinstance(v, _NUMERIC) and not isinstance(v, bool):
                            r.series.setdefault(m, []).append(
                                (float(t), int(it), float(v)))
            elif kind == "complete":
                r.status = obj.get("status")
                it = obj.get("iterations", 0)
                if isinstance(it, _NUMERIC):
                    r.iterations = max(r.iterations, int(it))
            elif kind == "profile":
                r.profile = obj.get("info") or {}
            elif isinstance(kind, str):
                r.events.append((
                    float(obj.get("t", 0.0)), int(obj.get("seq", -1)),
                    kind, obj.get("info") or {}))
        return cls(records, header=header, n_skipped_lines=skipped)

    # -- queries ---------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.records)

    def trial_ids(self) -> List[str]:
        return sorted(self.records)

    def get(self, trial_id: str) -> Optional[TrialRecord]:
        return self.records.get(trial_id)

    def best_trial(self, metric: str, mode: str = "max") -> Optional[TrialRecord]:
        if mode not in ("min", "max"):
            raise ValueError("mode must be 'min' or 'max'")
        best, best_v = None, None
        for tid in sorted(self.records):  # deterministic tie-break
            v = self.records[tid].best_value(metric, mode)
            if v is None:
                continue
            if best_v is None or (v > best_v if mode == "max" else v < best_v):
                best, best_v = self.records[tid], v
        return best

    def dataframe(self, metric: Optional[str] = None) -> Dict[str, List[Any]]:
        """Column-oriented trial table (a dict of equal-length lists — the
        zero-dependency stand-in for a pandas DataFrame)."""
        cols: Dict[str, List[Any]] = {
            "trial_id": [], "status": [], "iterations": [], "n_results": [],
            "restarts": [], "resizes": [], "kills": [],
        }
        if metric is not None:
            cols[f"last_{metric}"] = []
            cols[f"best_{metric}"] = []
        for tid in sorted(self.records):
            r = self.records[tid]
            cols["trial_id"].append(tid)
            cols["status"].append(r.status)
            cols["iterations"].append(r.iterations)
            cols["n_results"].append(r.n_results)
            cols["restarts"].append(r.count("restarted"))
            cols["resizes"].append(r.count("resized"))
            cols["kills"].append(r.count("killed"))
            if metric is not None:
                cols[f"last_{metric}"].append(r.last_value(metric))
                cols[f"best_{metric}"].append(r.best_value(metric, "max"))
        return cols

    def decision_timeline(self, trial_id: str) -> List[Dict[str, Any]]:
        r = self.records.get(trial_id)
        return r.decision_timeline() if r is not None else []

    def decisions(self, trial_id: str) -> List[Dict[str, Any]]:
        r = self.records.get(trial_id)
        return r.decisions() if r is not None else []

    def status_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for r in self.records.values():
            key = r.status or "(in flight)"
            counts[key] = counts.get(key, 0) + 1
        return dict(sorted(counts.items()))

    # -- cross-run diff ---------------------------------------------------------
    def diff(self, other: "ExperimentAnalysis",
             metric: Optional[str] = None) -> Dict[str, Any]:
        """Compare two journals trial-by-trial.  Runs produced with the same
        scenario ``token`` (repro.testing) share trial ids, so the alignment
        is exact; for ad-hoc runs only the id intersection is compared."""
        mine, theirs = set(self.records), set(other.records)
        changed: Dict[str, Dict[str, Any]] = {}
        for tid in sorted(mine & theirs):
            a, b = self.records[tid], other.records[tid]
            delta: Dict[str, Any] = {}
            if a.status != b.status:
                delta["status"] = [a.status, b.status]
            if a.iterations != b.iterations:
                delta["iterations"] = [a.iterations, b.iterations]
            for kind in ("restarted", "resized", "killed"):
                ca, cb = a.count(kind), b.count(kind)
                if ca != cb:
                    delta[kind] = [ca, cb]
            if metric is not None:
                va, vb = a.best_value(metric), b.best_value(metric)
                if va != vb:
                    delta[f"best_{metric}"] = [va, vb]
            if delta:
                changed[tid] = delta
        return {
            "only_in_self": sorted(mine - theirs),
            "only_in_other": sorted(theirs - mine),
            "changed": changed,
            "n_common": len(mine & theirs),
        }

    # -- canonical summary -------------------------------------------------------
    def summary(self, metric: Optional[str] = None,
                mode: str = "max") -> Dict[str, Any]:
        """Deterministic run digest: everything here is a pure function of
        the journal's deterministic fields (see module docstring), so two
        identical VirtualClock runs summarize byte-identically."""
        out: Dict[str, Any] = {
            "schema_version": (self.header or {}).get("schema_version"),
            "clock": (self.header or {}).get("clock"),
            "executor": (self.header or {}).get("executor"),
            "n_trials": len(self.records),
            "status_counts": self.status_counts(),
            "total_iterations": sum(r.iterations for r in self.records.values()),
            "total_results": sum(r.n_results for r in self.records.values()),
            "events": self._event_totals(),
            "skipped_lines": self.n_skipped_lines,
        }
        if metric is not None:
            best = self.best_trial(metric, mode)
            out["best"] = None if best is None else {
                "trial_id": best.trial_id,
                "config": best.config,
                "value": best.best_value(metric, mode),
                "iterations": best.iterations,
            }
        return out

    def summary_json(self, metric: Optional[str] = None,
                     mode: str = "max") -> str:
        return json.dumps(self.summary(metric, mode), sort_keys=True,
                          separators=(",", ":"))

    def _event_totals(self) -> Dict[str, int]:
        totals: Dict[str, int] = {}
        for r in self.records.values():
            for _, _, kind, _ in r.events:
                totals[kind] = totals.get(kind, 0) + 1
        return dict(sorted(totals.items()))
