"""Crash-forensics flight recorder + searcher-state snapshots (DESIGN.md §10).

``FlightRecorder`` keeps bounded ring buffers of the last N bus events and the
last N DECISION records.  Recording is append-only and cheap (one deque append
per event — sanitization is deferred to dump time); on a controller exception,
SIGTERM, or a ``max_experiment_failures`` abort it dumps a self-contained
forensic bundle: ring contents, scheduler/searcher ``state_dict()``, the
active trial table, pool/queue stats, and failure counters.  Everything in
the bundle rides the injected clock's axis and is serialized with sorted keys,
so two identical-token VirtualClock runs dump byte-identical bundles (the same
comparability contract as traces and analysis summaries).

``SearchStateSnapshotter`` checkpoints scheduler+searcher state to a JSON file
on the same clock-throttle pattern as the metrics snapshot stream — the raw
material for durable resume (ROADMAP: crash-tolerant controller).

This module imports nothing from ``repro.core`` (the runner imports us), so
there is no import cycle.
"""
from __future__ import annotations

import json
import os
import threading
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = ["FlightRecorder", "SearchStateSnapshotter", "json_safe",
           "load_search_state"]

FLIGHTREC_SCHEMA_VERSION = 1
SEARCH_STATE_SCHEMA_VERSION = 2


def _strict_default(obj: Any) -> Any:
    """``json.dumps`` default for search-state snapshots: numpy scalars
    collapse to their Python value, everything else is an error.

    Unlike forensic dumps (``json_safe`` + ``default=repr``), resume state
    must round-trip exactly — a repr'd tuple or RNG word is silent data
    corruption that only surfaces as wrong verdicts after resume, so any
    state_dict() that is not JSON-clean fails loudly at write time.
    """
    fn = getattr(obj, "item", None)
    if callable(fn):
        return fn()  # numpy scalar (arrays of size>1 raise, which we want)
    raise TypeError(
        f"search-state snapshot is not JSON-clean: {type(obj).__name__}: "
        f"{obj!r}")


def json_safe(obj: Any, depth: int = 0) -> Any:
    """Best-effort coercion to JSON-serializable values.

    Decision inputs and event payloads may hold numpy scalars or arbitrary
    objects (a PBT-mutated config value, a Checkpoint); forensic dumps and
    journaling must never crash on them, so anything unknown goes to repr.
    """
    if obj is None or isinstance(obj, (bool, int, str)):
        return obj
    if isinstance(obj, float):
        return obj
    if depth > 8:
        # deep enough for every scheduler state_dict (ASHA rung pairs nest 5
        # levels); the cap only guards true pathologies (cyclic/huge graphs)
        return repr(obj)
    if isinstance(obj, dict):
        return {str(k): json_safe(v, depth + 1) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_safe(v, depth + 1) for v in obj]
    fn = getattr(obj, "item", None)  # numpy scalars
    if callable(fn):
        try:
            return json_safe(fn(), depth + 1)
        except Exception:
            pass
    return repr(obj)


class FlightRecorder:
    """Bounded ring buffer over bus events + decisions with forensic dumps.

    - ``record_event`` / ``record_decision``: O(1) deque appends on the runner
      thread; no serialization happens until a dump.
    - ``dump``: write the bundle to ``out_dir/<run_id>-<seq>-<reason>.json``.
      The filename carries a per-recorder dump counter so repeated dumps
      (e.g. SIGTERM during an abort path) never collide.
    - ``install_signal_handler``: dump on SIGTERM then exit 143 via
      ``SystemExit`` so ``finally`` blocks still run.  Main thread only
      (returns False elsewhere — worker threads can't own signal handlers).
    """

    def __init__(self, capacity: int = 512, decision_capacity: int = 256,
                 clock: Optional[Any] = None, run_id: Optional[str] = None,
                 out_dir: Optional[str] = None):
        self.capacity = int(capacity)
        self.decision_capacity = int(decision_capacity)
        self.clock = clock
        self.run_id = run_id or "run-unknown"
        self.out_dir = out_dir or "flightrec"
        self._events: "deque[Any]" = deque(maxlen=self.capacity)
        self._decisions: "deque[Any]" = deque(maxlen=self.decision_capacity)
        self._dump_seq = 0
        self._prev_handlers: Dict[int, Any] = {}
        self.n_events_seen = 0

    def bind_clock(self, clock: Any) -> None:
        self.clock = clock

    # -- recording (runner thread, hot path) ------------------------------------
    def record_event(self, event: Any) -> None:
        self._events.append(event)
        self.n_events_seen += 1

    def record_decision(self, event: Any) -> None:
        self._decisions.append(event)

    # -- bundle assembly ---------------------------------------------------------
    @staticmethod
    def _event_row(ev: Any) -> Dict[str, Any]:
        kind = getattr(getattr(ev, "type", None), "value", None) or "?"
        row: Dict[str, Any] = {
            "type": kind,
            "trial_id": getattr(ev, "trial_id", None),
            "seq": getattr(ev, "seq", -1),
            "t": getattr(ev, "timestamp", None),
        }
        info = getattr(ev, "info", None)
        if info:
            row["info"] = json_safe(info)
        result = getattr(ev, "result", None)
        if result is not None:
            row["iteration"] = getattr(result, "training_iteration", None)
        error = getattr(ev, "error", None)
        if error:
            row["error"] = str(error)[-500:]
        return row

    def bundle(self, runner: Any = None, executor: Any = None,
               reason: str = "abort") -> Dict[str, Any]:
        """Assemble the forensic bundle as a plain dict (JSON-safe)."""
        out: Dict[str, Any] = {
            "schema_version": FLIGHTREC_SCHEMA_VERSION,
            "run_id": self.run_id,
            "reason": reason,
            "t_virtual": self.clock.time() if self.clock is not None else None,
            "n_events_seen": self.n_events_seen,
            "events": [self._event_row(e) for e in self._events],
            "decisions": [self._event_row(e) for e in self._decisions],
        }
        sched = getattr(runner, "scheduler", None)
        if sched is not None and hasattr(sched, "state_dict"):
            try:
                out["scheduler"] = {"type": type(sched).__name__,
                                    "state": json_safe(sched.state_dict())}
            except Exception as e:  # a dump must never fail on state capture
                out["scheduler"] = {"type": type(sched).__name__,
                                    "error": repr(e)}
        else:
            out["scheduler"] = None
        searcher = getattr(runner, "searcher", None)
        if searcher is not None and hasattr(searcher, "state_dict"):
            try:
                out["searcher"] = {"type": type(searcher).__name__,
                                   "state": json_safe(searcher.state_dict())}
            except Exception as e:
                out["searcher"] = {"type": type(searcher).__name__,
                                   "error": repr(e)}
        else:
            out["searcher"] = None
        trials = getattr(runner, "trials", None)
        if trials is not None:
            table = []
            counts: Dict[str, int] = {}
            for t in trials:
                status = getattr(getattr(t, "status", None), "value", "?")
                counts[status] = counts.get(status, 0) + 1
                table.append({
                    "trial_id": t.trial_id,
                    "status": status,
                    "iteration": getattr(t, "training_iteration", None),
                    "failures": getattr(t, "num_failures", 0),
                })
            table.sort(key=lambda r: r["trial_id"])
            out["trials"] = table
            out["status_counts"] = counts
            out["n_errors"] = getattr(runner, "n_errors", None)
            out["n_restarts"] = getattr(runner, "n_restarts", None)
        if executor is not None:
            bus = getattr(executor, "bus", None)
            pool = getattr(executor, "slice_pool", None)
            out["bus_depth"] = len(bus) if bus is not None else None
            out["pool"] = ({
                "utilization": round(pool.utilization(), 4),
                "fragments": pool.fragments(),
            } if pool is not None else None)
            host_state = getattr(executor, "host_state", None)
            if callable(host_state):
                try:
                    out["hosts"] = host_state()
                except Exception:  # noqa: BLE001 — forensics must not raise
                    out["hosts"] = None
        return out

    # -- dumping -----------------------------------------------------------------
    def dump(self, runner: Any = None, executor: Any = None,
             reason: str = "abort") -> str:
        """Write the bundle; returns the written path.

        Sorted keys + compact separators: same run -> byte-identical file.
        """
        bundle = self.bundle(runner=runner, executor=executor, reason=reason)
        os.makedirs(self.out_dir, exist_ok=True)
        path = os.path.join(
            self.out_dir, f"{self.run_id}-{self._dump_seq:02d}-{reason}.json")
        self._dump_seq += 1
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(bundle, f, sort_keys=True, separators=(",", ":"),
                      default=repr)
            f.write("\n")
        os.replace(tmp, path)
        return path

    # -- SIGTERM wiring ----------------------------------------------------------
    def install_signal_handler(self, runner: Any = None,
                               executor: Any = None) -> bool:
        """Dump a ``sigterm`` bundle on SIGTERM, then SystemExit(143) so the
        caller's ``finally`` path still runs.  Returns False off-main-thread
        (signal handlers are a main-thread-only facility)."""
        import signal

        def _handler(signum, frame):
            try:
                self.dump(runner=runner, executor=executor, reason="sigterm")
            finally:
                raise SystemExit(143)

        try:
            self._prev_handlers[signal.SIGTERM] = signal.signal(
                signal.SIGTERM, _handler)
            return True
        except ValueError:
            return False

    def remove_signal_handler(self) -> None:
        import signal
        for sig, prev in self._prev_handlers.items():
            try:
                signal.signal(sig, prev)
            except ValueError:
                pass
        self._prev_handlers.clear()


class SearchStateSnapshotter:
    """Clock-throttled scheduler+searcher state checkpoints (DESIGN.md §10).

    Same throttle pattern as ``Observability.maybe_snapshot``: call freely
    from the runner loop; at most one snapshot per ``interval_s`` clock
    seconds.  Writes are atomic (tmp + replace) so a crash mid-write never
    leaves a torn snapshot — the file always holds the last complete state.
    """

    def __init__(self, path: str, clock: Optional[Any] = None,
                 interval_s: float = 10.0,
                 watermark_fn: Optional[Any] = None):
        if clock is None:
            from ..core.clock import get_default_clock  # lazy: no import cycle
            clock = get_default_clock()
        self.path = path
        self.clock = clock
        self.interval_s = float(interval_s)
        # Called at snapshot time; returns the number of journal records the
        # captured state has already been fed (the resume replay watermark).
        self.watermark_fn = watermark_fn
        self._lock = threading.Lock()
        self._next: Optional[float] = None
        self.n_snapshots = 0

    def bind_clock(self, clock: Any) -> None:
        self.clock = clock

    def maybe_snapshot(self, scheduler: Any, searcher: Any = None) -> bool:
        now = self.clock.time()
        with self._lock:
            if self._next is not None and now < self._next:
                return False
            self._next = now + self.interval_s
        self.snapshot(scheduler, searcher)
        return True

    def snapshot(self, scheduler: Any, searcher: Any = None) -> None:
        watermark = None
        if self.watermark_fn is not None:
            watermark = int(self.watermark_fn())
        state: Dict[str, Any] = {
            "schema_version": SEARCH_STATE_SCHEMA_VERSION,
            "t": self.clock.time(),
            "journal_records": watermark,
            "scheduler": ({"type": type(scheduler).__name__,
                           "state": scheduler.state_dict()}
                          if scheduler is not None
                          and hasattr(scheduler, "state_dict") else None),
            "searcher": ({"type": type(searcher).__name__,
                          "state": searcher.state_dict()}
                         if searcher is not None
                         and hasattr(searcher, "state_dict") else None),
        }
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f, sort_keys=True, separators=(",", ":"),
                      default=_strict_default)
            f.write("\n")
        os.replace(tmp, self.path)
        self.n_snapshots += 1


def load_search_state(path: str) -> Optional[Dict[str, Any]]:
    """Read a ``search_state.json`` snapshot; None when missing or corrupt.

    Writes are atomic (tmp + replace) so corruption should never happen, but
    resume must degrade to journal-only replay rather than crash on a bad
    file.
    """
    try:
        with open(path) as f:
            state = json.load(f)
    except (OSError, ValueError):
        return None
    return state if isinstance(state, dict) else None
