"""Pallas TPU kernels for the model zoo's compute hot spots.

Each kernel ships three pieces: ``<name>.py`` (pl.pallas_call + BlockSpec
VMEM tiling), a wrapper in ``ops.py`` (jit-friendly padding + CPU-interpret
fallback), and an oracle in ``ref.py`` (pure-jnp ground truth used by the
allclose sweeps in tests/test_kernels.py).
"""
from . import ops, ref
from .ops import flash_attention, moe_router, rglru_scan, rwkv6_scan
