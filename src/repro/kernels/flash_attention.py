"""Flash attention Pallas kernel — blocked online softmax, TPU tiling.

Grid (B, H, nQ, nK); the last axis is sequential on TPU, so fp32 running
(max, sum, acc) live in VMEM scratch across the kv sweep and the output block
is written on the final kv step.  Block shapes are MXU-aligned (q-block x hd
and k-block x hd tiles, 128-multiples where shapes allow).  Supports causal /
sliding-window / bidirectional masks from explicit position vectors (ring
caches pass k_pos with -1 for unfilled slots), GQA head grouping and logit
soft-capping.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas", "DEFAULT_BLOCK_Q", "DEFAULT_BLOCK_K"]

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30


def _kernel(q_pos_ref, k_pos_ref, q_ref, k_ref, v_ref,  # inputs
            o_ref,                                      # output
            m_scr, l_scr, acc_scr,                      # scratch
            *, causal: bool, window: Optional[int], softcap: Optional[float],
            scale: float, n_k: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, hd)
    qp = q_pos_ref[0]                            # (bq,)
    kp = k_pos_ref[0]                             # (bk,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    d = qp[:, None] - kp[None, :]
    ok = kp[None, :] >= 0
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    s = jnp.where(ok, s, _NEG_INF)

    m_prev = m_scr[...]                           # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = alpha * l_scr[...] + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array,
    q_pos: jax.Array, k_pos: jax.Array,
    causal: bool = True, window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """q (B,Sq,H,hd); k/v (B,Sk,K,hd); q_pos (B,Sq); k_pos (B,Sk).

    Sq and Sk must be multiples of the block sizes (ops.py pads)."""
    B, Sq, H, hd = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    if Sq % block_q or Sk % block_k:
        raise ValueError(f"Sq={Sq}/Sk={Sk} must divide blocks ({block_q},{block_k})")
    n_q, n_k = Sq // block_q, Sk // block_k

    # layout: (B, heads, S, hd) for blocked access
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, softcap=softcap,
        scale=1.0 / math.sqrt(hd), n_k=n_k)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, h, qi, ki: (b, qi)),
            pl.BlockSpec((1, block_k), lambda b, h, qi, ki: (b, ki)),
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q_pos, k_pos, qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
