"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Each mirrors its kernel's exact contract (shapes, dtypes, masking rules) with
straightforward jnp code — no blocking, no VMEM tiling, no online softmax.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "rwkv6_scan_ref", "rglru_scan_ref",
           "moe_router_ref"]


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array,
    q_pos: jax.Array, k_pos: jax.Array,
    causal: bool = True, window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    """q (B,Sq,H,hd); k/v (B,Sk,K,hd); q_pos (B,Sq); k_pos (B,Sk) -> (B,Sq,H,hd).

    GQA via head grouping; invalid cache slots are k_pos < 0."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    d = q_pos[:, :, None] - k_pos[:, None, :]
    ok = k_pos[:, None, :] >= 0
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    logits = jnp.where(ok[:, None, None, :, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - jnp.maximum(m, -1e30))
    l = jnp.sum(p, axis=-1, keepdims=True)
    w = p / jnp.maximum(l, 1e-30)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def rwkv6_scan_ref(
    r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
    u: jax.Array, state: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Sequential RWKV-6 WKV recurrence.

    r/k/v (B,S,H,N); logw (B,S,H,N) fp32 log-decay; u (H,N); state (B,H,N,N)
    fp32.  y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1}
    + k_t v_t^T.  Returns (y (B,S,H,N), final state)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    uf = u.astype(jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs  # (B,H,N) each
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhn,bhnm->bhm", rt, S + uf[None, :, :, None] * kv)
        S = jnp.exp(wt)[..., None] * S + kv
        return S, y

    xs = tuple(a.swapaxes(0, 1) for a in (rf, kf, vf, logw))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.swapaxes(0, 1).astype(r.dtype), state


def rglru_scan_ref(
    a: jax.Array, b: jax.Array, h0: Optional[jax.Array] = None,
) -> jax.Array:
    """Linear recurrence h_t = a_t * h_{t-1} + b_t.  a/b (B,S,R) fp32;
    h0 (B,R) or None.  Returns h (B,S,R)."""
    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h
    h_init = h0 if h0 is not None else jnp.zeros_like(b[:, 0])
    _, hs = jax.lax.scan(step, h_init,
                         (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)


def moe_router_ref(
    logits: jax.Array, top_k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Softmax over experts -> top-k -> renormalize (DeepSeek convention).

    logits (T, E) -> (weights (T, k) fp32, idx (T, k) int32)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx.astype(jnp.int32)
