"""RG-LRU linear-recurrence Pallas kernel: h_t = a_t * h_{t-1} + b_t.

Grid (B, nR, nT): feature blocks are independent lanes (8x128-aligned); the
time axis is last (sequential) so the (block_r,) carry persists in VMEM
scratch across time chunks.  Inside a chunk the recurrence is a fori_loop of
fused multiply-adds over rows — VPU work, no MXU — which is the right shape
for TPU: the recurrence is memory-bound, so the win is keeping the carry and
the (chunk, block_r) tile resident in VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rglru_scan_pallas", "DEFAULT_CHUNK_T", "DEFAULT_BLOCK_R"]

DEFAULT_CHUNK_T = 128
DEFAULT_BLOCK_R = 512


def _kernel(a_ref, b_ref, h0_ref, h_ref, carry_scr, *, chunk_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        carry_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)   # (chunk_t, block_r)
    b = b_ref[0].astype(jnp.float32)

    def row(t, carry):
        h = a[t] * carry + b[t]
        h_ref[0, t, :] = h.astype(h_ref.dtype)
        return h

    carry_scr[...] = jax.lax.fori_loop(0, chunk_t, row, carry_scr[...])


def rglru_scan_pallas(
    a: jax.Array, b: jax.Array, h0: Optional[jax.Array] = None,
    chunk_t: int = DEFAULT_CHUNK_T, block_r: int = DEFAULT_BLOCK_R,
    interpret: bool = False,
) -> jax.Array:
    """a/b (B,S,R); h0 (B,R) or None -> h (B,S,R).  S % chunk_t == 0 and
    R % block_r == 0 (ops.py pads: a=1,b=0 rows are identity steps)."""
    B, S, R = a.shape
    chunk_t = min(chunk_t, S)
    block_r = min(block_r, R)
    if S % chunk_t or R % block_r:
        raise ValueError(f"S={S},R={R} must divide blocks ({chunk_t},{block_r})")
    if h0 is None:
        h0 = jnp.zeros((B, R), jnp.float32)

    kernel = functools.partial(_kernel, chunk_t=chunk_t)
    return pl.pallas_call(
        kernel,
        grid=(B, R // block_r, S // chunk_t),
        in_specs=[
            pl.BlockSpec((1, chunk_t, block_r), lambda bi, ri, ti: (bi, ti, ri)),
            pl.BlockSpec((1, chunk_t, block_r), lambda bi, ri, ti: (bi, ti, ri)),
            pl.BlockSpec((1, block_r), lambda bi, ri, ti: (bi, ri)),
        ],
        out_specs=pl.BlockSpec((1, chunk_t, block_r),
                               lambda bi, ri, ti: (bi, ti, ri)),
        out_shape=jax.ShapeDtypeStruct((B, S, R), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_r,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
