"""Public jit'd wrappers for the Pallas kernels.

Handles block-size padding (each kernel requires divisible shapes) and the
CPU-interpret fallback: on this container ``jax.default_backend() == 'cpu'``
so kernels execute via ``interpret=True`` (the kernel body runs exactly as it
would on TPU, minus the tiling performance).  On TPU the same call sites lower
to real Mosaic kernels.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import moe_router as _router
from . import rglru_scan as _rglru
from . import rwkv6_scan as _rwkv

__all__ = ["flash_attention", "rwkv6_scan", "rglru_scan", "moe_router",
           "use_interpret"]


def use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> Tuple[jax.Array, int]:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), pad


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    q_pos: jax.Array, k_pos: jax.Array,
    causal: bool = True, window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = _fa.DEFAULT_BLOCK_Q, block_k: int = _fa.DEFAULT_BLOCK_K,
) -> jax.Array:
    """Padded/dispatching wrapper; see flash_attention_pallas for the contract.

    Padding: extra q rows compute garbage that is sliced off; extra k slots get
    k_pos = -1 which the mask rejects."""
    Sq, Sk = q.shape[1], k.shape[1]
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    q, pq = _pad_to(q, 1, bq)
    q_pos, _ = _pad_to(q_pos, 1, bq, value=0)
    k, pk = _pad_to(k, 1, bk)
    v, _ = _pad_to(v, 1, bk)
    k_pos, _ = _pad_to(k_pos, 1, bk, value=-1)
    out = _fa.flash_attention_pallas(
        q, k, v, q_pos, k_pos, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, interpret=use_interpret())
    return out[:, :Sq] if pq else out


def rwkv6_scan(
    r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
    u: jax.Array, state: jax.Array, chunk: int = _rwkv.DEFAULT_CHUNK,
) -> Tuple[jax.Array, jax.Array]:
    """Padding: logw=0 (w=1) and k=0 make padded steps state-identities."""
    S = r.shape[1]
    c = min(chunk, S)
    r, pad = _pad_to(r, 1, c)
    k, _ = _pad_to(k, 1, c)
    v, _ = _pad_to(v, 1, c)
    logw, _ = _pad_to(logw, 1, c)
    y, s_out = _rwkv.rwkv6_scan_pallas(r, k, v, logw, u, state, chunk=c,
                                       interpret=use_interpret())
    return (y[:, :S] if pad else y), s_out


def rglru_scan(
    a: jax.Array, b: jax.Array, h0: Optional[jax.Array] = None,
    chunk_t: int = _rglru.DEFAULT_CHUNK_T, block_r: int = _rglru.DEFAULT_BLOCK_R,
) -> jax.Array:
    """Padding: a=1, b=0 rows are identity steps; extra R lanes sliced off."""
    B, S, R = a.shape
    ct, br = min(chunk_t, S), min(block_r, R)
    a, pad_t = _pad_to(a, 1, ct, value=1.0)
    b, _ = _pad_to(b, 1, ct, value=0.0)
    a, pad_r = _pad_to(a, 2, br, value=1.0)
    b, _ = _pad_to(b, 2, br, value=0.0)
    if h0 is None:
        h0 = jnp.zeros((B, a.shape[2]), jnp.float32)
    else:
        h0, _ = _pad_to(h0, 1, br, value=0.0)
    h = _rglru.rglru_scan_pallas(a, b, h0, chunk_t=ct, block_r=br,
                                 interpret=use_interpret())
    return h[:, :S, :R]


def moe_router(logits: jax.Array, top_k: int,
               block_t: int = _router.DEFAULT_BLOCK_T) -> Tuple[jax.Array, jax.Array]:
    """Padding: extra token rows routed to garbage and sliced off."""
    T = logits.shape[0]
    bt = min(block_t, T)
    logits_p, pad = _pad_to(logits, 0, bt)
    w, idx = _router.moe_router_pallas(logits_p, top_k, block_t=bt,
                                       interpret=use_interpret())
    return (w[:T], idx[:T]) if pad else (w, idx)
