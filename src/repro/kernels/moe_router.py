"""Fused MoE router Pallas kernel: softmax -> top-k -> renormalize.

Grid (nT,): each program routes a block of tokens.  The expert axis (<= a few
hundred) fits a lane tile, so softmax is one VPU pass; top-k (k <= 8) is k
iterations of argmax+mask — cheaper than a full sort and fused with the
softmax, saving two HBM round-trips of the (T, E) probability tensor that the
unfused jnp path (softmax -> lax.top_k) makes.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["moe_router_pallas", "DEFAULT_BLOCK_T"]

DEFAULT_BLOCK_T = 256


def _kernel(logits_ref, w_ref, idx_ref, *, top_k: int):
    logits = logits_ref[...].astype(jnp.float32)       # (bt, E)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    probs = p / p.sum(axis=-1, keepdims=True)

    bt, E = probs.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (bt, E), 1)
    ws, idxs = [], []
    for _ in range(top_k):
        w = probs.max(axis=-1)
        i = jnp.argmax(probs, axis=-1).astype(jnp.int32)
        ws.append(w)
        idxs.append(i)
        probs = jnp.where(iota == i[:, None], -1.0, probs)
    w = jnp.stack(ws, axis=-1)                         # (bt, k)
    idx = jnp.stack(idxs, axis=-1)
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-9)
    w_ref[...] = w
    idx_ref[...] = idx


def moe_router_pallas(
    logits: jax.Array, top_k: int,
    block_t: int = DEFAULT_BLOCK_T, interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """logits (T, E) -> (weights (T, k) fp32, idx (T, k) int32).  T % block_t
    == 0 (ops.py pads)."""
    T, E = logits.shape
    block_t = min(block_t, T)
    if T % block_t:
        raise ValueError(f"T={T} must divide block_t={block_t}")

    kernel = functools.partial(_kernel, top_k=top_k)
    return pl.pallas_call(
        kernel,
        grid=(T // block_t,),
        in_specs=[pl.BlockSpec((block_t, E), lambda ti: (ti, 0))],
        out_specs=[
            pl.BlockSpec((block_t, top_k), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t, top_k), lambda ti: (ti, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, top_k), jnp.float32),
            jax.ShapeDtypeStruct((T, top_k), jnp.int32),
        ],
        interpret=interpret,
    )(logits)
