"""RWKV-6 WKV chunked-scan Pallas kernel.

Grid (B*H, n_chunks): the chunk axis is sequential on TPU, so the (N, N) fp32
recurrent state lives in VMEM scratch across chunks (loaded from the initial
state at chunk 0, flushed to the output at the last chunk).  Within a chunk,
decay-ratio weights are computed in log space (ratios <= 1, no overflow) and
the heavy lifting — intra-chunk A @ V, inter-chunk (r*decay) @ S, and the
state update K^T @ V — are MXU matmuls.  The (L, L, N) ratio tensor is the
VPU-side cost; L (chunk) is kept small (32-64) so it fits VMEM comfortably:
VMEM ~= L*N*4 inputs * 4 + L*L*N*4 ratio ~= 0.6 MiB at L=32, N=64.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rwkv6_scan_pallas", "DEFAULT_CHUNK"]

DEFAULT_CHUNK = 32


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,   # inputs
            y_ref, sout_ref,                              # outputs
            s_scr,                                        # scratch (N,N) f32
            *, n_chunks: int, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)   # (L, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    logw = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)   # (N,)

    L = r.shape[0]
    cum = jnp.cumsum(logw, axis=0)          # (L, N) inclusive
    cum_excl = cum - logw

    # intra-chunk: A[t,s] = sum_n r[t,n] k[s,n] exp(cum_excl[t,n] - cum[s,n]), s<t
    ratio = cum_excl[:, None, :] - cum[None, :, :]          # (L, L, N)
    mask = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    ratio = jnp.where(mask[:, :, None], ratio, -jnp.inf)
    A = jnp.sum(r[:, None, :] * k[None, :, :] * jnp.exp(ratio), axis=-1)  # (L, L)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)              # (L,)
    A = A + jnp.diag(diag)

    y_intra = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    r_dec = r * jnp.exp(cum_excl)
    y_inter = jax.lax.dot_general(r_dec, s_scr[...], (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    decay_all = jnp.exp(cum[-1])                              # (N,)
    k_scaled = k * jnp.exp(cum[-1][None, :] - cum)            # (L, N)
    s_scr[...] = decay_all[:, None] * s_scr[...] + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        sout_ref[0] = s_scr[...]


def rwkv6_scan_pallas(
    r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
    u: jax.Array, state: jax.Array,
    chunk: int = DEFAULT_CHUNK, interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """r/k/v (B,S,H,N); logw (B,S,H,N) fp32; u (H,N); state (B,H,N,N) fp32.

    Returns (y (B,S,H,N), final state).  S must divide ``chunk`` (ops.py pads
    with logw=0, k=0 which leaves y/state unchanged)."""
    B, S, H, N = r.shape
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError(f"S={S} must divide chunk={chunk}")
    n_chunks = S // chunk

    def to_bh(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, S, N)

    rb, kb, vb = to_bh(r), to_bh(k), to_bh(v)
    wb = to_bh(logw.astype(jnp.float32))
    ub = jnp.tile(u, (B, 1))                         # (B*H, N)
    s0 = state.reshape(B * H, N, N).astype(jnp.float32)

    kernel = functools.partial(_kernel, n_chunks=n_chunks, chunk=chunk)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(B * H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, N), lambda bh, ci: (bh, 0)),
            pl.BlockSpec((1, N, N), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, N, N), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, N), r.dtype),
            jax.ShapeDtypeStruct((B * H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(rb, kb, vb, wb, ub, s0)
    return (y.reshape(B, H, S, N).transpose(0, 2, 1, 3),
            s_out.reshape(B, H, N, N))
