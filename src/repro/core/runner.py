"""TrialRunner — the event loop wiring trials, scheduler, searcher and executor.

One ``step()`` = (1) launch trials while the scheduler offers one and resources
allow (pulling fresh suggestions from the searcher when the explicit trial list
is exhausted); (2) drain the next ``TrialEvent`` from the executor (worker
threads push RESULT/ERROR/CHECKPOINTED/HEARTBEAT_MISSED onto an EventBus;
poll-style executors are adapted by ``TrialExecutor.get_next_event``'s compat
shim); (3) let the scheduler decide CONTINUE / PAUSE / STOP /
RESTART_WITH_CONFIG and apply it.  Trial metadata is kept in memory; fault
tolerance is via checkpoints (paper §4.2): a trial whose trainable raises is
restarted from its last checkpoint up to ``max_failures`` times before it is
marked ERROR, and the experiment aborts when errored trials exceed
``max_experiment_failures``.
"""
from __future__ import annotations

import itertools
from time import perf_counter as _perf
from typing import Any, Callable, Dict, List, Optional, Union

from ..obs import NULL_OBS
from ..obs.flightrec import json_safe as _json_safe
from .events import EventType, TrialEvent
from .executor import TrialExecutor
from .loggers import Logger
from .resources import Resources
from .schedulers.base import SchedulerDecision, TrialScheduler
from .search.basic import Searcher
from .trial import Result, Trial, TrialStatus

__all__ = ["TrialRunner"]


class TrialRunner:
    def __init__(
        self,
        scheduler: TrialScheduler,
        executor: TrialExecutor,
        searcher: Optional[Searcher] = None,
        logger: Optional[Logger] = None,
        trainable_name: str = "trainable",
        default_resources: Optional[Resources] = None,
        stopping_criteria: Optional[Dict[str, float]] = None,
        max_pending_from_searcher: int = 0,  # 0 = unlimited
        max_failures: int = 0,               # per-trial restarts-from-checkpoint
        max_experiment_failures: int = 0,    # 0 = unlimited errored trials
        broker: Optional[Any] = None,        # elastic.ResourceBroker (DESIGN.md §6)
        obs: Optional[Any] = None,           # repro.obs.Observability (§8)
        decisions: Union[bool, str] = True,  # DECISION journaling (§10): True |
                                             # "full" (incl. CONTINUE) | False
        flight_recorder: Optional[Any] = None,    # repro.obs.FlightRecorder (§10)
        state_snapshotter: Optional[Any] = None,  # SearchStateSnapshotter (§10)
    ):
        self.scheduler = scheduler
        self.executor = executor
        self.obs = obs or NULL_OBS
        self.decisions = decisions
        self.flightrec = flight_recorder
        self.state_snapshotter = state_snapshotter
        # Pre-resolved hot-path instruments (one None test per use when off).
        m = self.obs.metrics
        if m is not None:
            self._m_choose = m.histogram("sched.choose_us")
            self._m_decide = m.histogram("sched.decision_us")
            self._m_restarts = m.counter("trials.restarts")
        else:
            self._m_choose = self._m_decide = self._m_restarts = None
        self.searcher = searcher
        self.logger = logger or Logger()
        self.trainable_name = trainable_name
        self.default_resources = default_resources or Resources()
        self.stopping_criteria = dict(stopping_criteria or {})
        self.max_pending_from_searcher = max_pending_from_searcher
        self.max_failures = max_failures
        self.max_experiment_failures = max_experiment_failures
        self.trials: List[Trial] = []
        self._by_id: Dict[str, Trial] = {}
        # Indexed ready-queue (DESIGN.md §9): trials bucketed by
        # (status, resource shape) so choose_trial_to_run / is_finished cost
        # O(#shapes) instead of scanning all n trials.  Maintained by the
        # status listener installed on every trial in add_trial; all status
        # transitions happen on the runner thread (executors call
        # trial.set_status from start/stop/pause paths the runner drives), so
        # plain dicts need no lock.  Dicts are insertion-ordered: within a
        # bucket the head is the oldest (re)queued trial of that shape.
        self._status_index: Dict[TrialStatus, Dict[Resources, Dict[str, Trial]]] = {
            s: {} for s in TrialStatus}
        self._enq_counter = itertools.count()
        self._n_finished = 0  # TERMINATED + ERROR, kept by the listener
        self._searcher_exhausted = searcher is None
        self._suggest_counter = itertools.count()
        self.n_errors = 0
        self.n_restarts = 0
        # Durable resume (DESIGN.md §12), installed by apply_resume_plan:
        # - result fences: re-executed iterations <= fence were already
        #   journaled before the crash — drop them (re-opening the credit
        #   gate) so the merged journal carries each result exactly once;
        # - event fences: ditto for iteration-stamped non-result events
        #   (CHECKPOINTED), keyed per event kind;
        # - resume queue: restored trials launched (phase-ordered) ahead of
        #   the scheduler's own choose loop so fresh PENDING trials cannot
        #   steal their capacity.
        self._resume_result_fence: Dict[str, int] = {}
        self._resume_event_fence: Dict[str, Dict[str, int]] = {}
        self._resume_queue: List[str] = []
        self.broker = broker
        if broker is not None:
            # Installs the effective lookahead on the executor (clamped to 1
            # unless the scheduler declares decision_interval() == 0).
            broker.bind(self)

    # -- trial management ------------------------------------------------------
    def add_trial(self, trial: Trial) -> None:
        self.trials.append(trial)
        self._by_id[trial.trial_id] = trial
        trial._status_listener = self._on_status_change
        if trial.status.is_finished():
            self._n_finished += 1
        self._index_insert(trial)
        self.scheduler.on_trial_add(self, trial)

    def adopt_trial(self, trial: Trial) -> None:
        """Add a restored trial WITHOUT notifying the scheduler.

        Durable resume rebuilds scheduler state from its snapshot / the
        journal replay, which already reflects every ``on_trial_add`` of the
        original run — re-firing the hook here would double-register the
        trial (and burn scheduler RNG draws, e.g. ASHA's per-add bracket
        choice), diverging every later verdict.
        """
        self.trials.append(trial)
        self._by_id[trial.trial_id] = trial
        trial._status_listener = self._on_status_change
        if trial.status.is_finished():
            self._n_finished += 1
        self._index_insert(trial)

    def apply_resume_plan(self, plan: Any) -> None:
        """Install a ``repro.core.resume.ResumePlan``: adopt its trials and
        arm the fences + phase-ordered relaunch queue (DESIGN.md §12)."""
        for trial in plan.trials:
            if trial.trial_id not in self._by_id:
                self.adopt_trial(trial)
        self._resume_result_fence = dict(plan.result_fences)
        self._resume_event_fence = {
            tid: dict(kinds) for tid, kinds in plan.event_fences.items()}
        self._resume_queue = [
            tid for tid in plan.resume_order
            if not self.scheduler.holds_trial(tid)]
        if plan.next_suggest_index:
            self._suggest_counter = itertools.count(plan.next_suggest_index)

    # -- status index ------------------------------------------------------------
    def _index_insert(self, trial: Trial) -> None:
        key = (trial.status, trial.resources)
        self._status_index[key[0]].setdefault(key[1], {})[trial.trial_id] = trial
        # Remember the exact bucket: an elastic resize may swap
        # trial.resources while the trial sits in a bucket keyed by the old
        # shape, so removal must not re-derive the key from the trial.
        trial._index_key = key
        trial._enq_seq = next(self._enq_counter)

    def _index_remove(self, trial: Trial) -> None:
        key = getattr(trial, "_index_key", None)
        if key is None:
            return
        bucket = self._status_index[key[0]].get(key[1])
        if bucket is not None:
            bucket.pop(trial.trial_id, None)
        trial._index_key = None

    def _on_status_change(self, trial: Trial, old: TrialStatus,
                          new: TrialStatus) -> None:
        self._n_finished += new.is_finished() - old.is_finished()
        self._index_remove(trial)
        self._index_insert(trial)

    def next_ready(self, status: TrialStatus,
                   fit: Optional[Callable[[Trial], bool]] = None
                   ) -> Optional[Trial]:
        """Oldest trial in ``status`` that the executor can place right now.

        ``has_resources`` is a pure function of the resource shape given pool
        state (frozen across this call), so it runs once per bucket — the
        indexed replacement for the per-trial O(n) scan.  ``fit`` filters
        candidates within a bucket (e.g. HyperBand's crash-requeue test);
        oldest is by (re)queue order, so a requeued trial goes to the back of
        the line rather than retaking its original submission slot.
        """
        best: Optional[Trial] = None
        for bucket in self._status_index[status].values():
            if not bucket:
                continue
            probe = next(iter(bucket.values()))
            if not self.executor.has_resources(probe):
                continue
            for t in bucket.values():
                if fit is None or fit(t):
                    if best is None or t._enq_seq < best._enq_seq:
                        best = t
                    break  # bucket is ordered: first fit-passing is oldest
        return best

    def get_trial(self, trial_id: str) -> Optional[Trial]:
        return self._by_id.get(trial_id)

    def has_resources(self, trial: Trial) -> bool:
        return self.executor.has_resources(trial)

    def stop_trial(self, trial: Trial) -> None:
        self.executor.stop_trial(trial)
        self.obs.tracer.end(("trial", trial.trial_id), status=trial.status.name)
        self.scheduler.on_trial_complete(self, trial)
        self.logger.on_trial_complete(trial)
        self._observe(trial, final=True)

    # -- decision provenance (DESIGN.md §10) -------------------------------------
    def _emit_decision(self, trial_id: str, source: str, by: str,
                       record: Dict[str, Any]) -> None:
        """Journal one decision record as a DECISION TrialEvent."""
        info = {"source": source, "by": by,
                "verdict": record.get("verdict"),
                "iteration": record.get("iteration"),
                "inputs": _json_safe(record.get("inputs") or {})}
        clock = getattr(self.executor, "clock", None)
        event = TrialEvent(
            EventType.DECISION, trial_id, info=info,
            timestamp=clock.time() if clock is not None else None)
        trial = self.get_trial(trial_id)
        if trial is not None:
            self.logger.on_event(trial, event)
        if self.flightrec is not None:
            self.flightrec.record_decision(event)

    def _drain_scheduler_decisions(self) -> None:
        """Journal verdicts the scheduler recorded during its last call.

        Drained after every on_result/on_trial_error so peer verdicts (e.g.
        a HyperBand cut stopping PAUSED peers directly) land in the journal
        even though they never surface as a returned decision.
        """
        records = self.scheduler.pop_decisions()
        if not records or self.decisions is False:
            return
        by = type(self.scheduler).__name__
        for rec in records:
            if self.decisions != "full" and rec.get("verdict") == "CONTINUE":
                continue
            self._emit_decision(rec["trial_id"], "scheduler", by, rec)

    # -- searcher integration ----------------------------------------------------
    def _maybe_suggest(self) -> Optional[Trial]:
        if self._searcher_exhausted:
            return None
        live = len(self.trials) - self._n_finished
        if self.max_pending_from_searcher and live >= self.max_pending_from_searcher:
            return None

        # Only pull a suggestion when it can actually start now: suggesting
        # ahead of capacity would drain the searcher before any results come
        # back, degrading TPE/BayesOpt to random search.
        class _Probe:
            resources = self.default_resources
        if not self.executor.has_resources(_Probe()):
            return None
        trial_id = f"{self.trainable_name}_sugg_{next(self._suggest_counter):05d}"
        config = self.searcher.suggest(trial_id)
        if config is None:
            self._searcher_exhausted = True
            return None
        if self.decisions is not False:
            rec = self.searcher.explain_last()
            if rec is not None and rec.get("trial_id") == trial_id:
                # Emitted after add_trial below so the logger can resolve the
                # trial; buffer the record until then.
                pending_suggest = rec
            else:
                pending_suggest = None
        else:
            pending_suggest = None
        trial = Trial(
            config=config,
            trainable_name=self.trainable_name,
            resources=self.default_resources,
            stopping_criteria=self.stopping_criteria,
            trial_id=trial_id,
        )
        self.add_trial(trial)
        if pending_suggest is not None:
            self._emit_decision(trial_id, "searcher",
                                type(self.searcher).__name__, pending_suggest)
        return trial

    def _observe(self, trial: Trial, final: bool) -> None:
        if self.searcher is None or trial.last_result is None:
            return
        metric = self.searcher.metric
        if metric in trial.last_result.metrics:
            self.searcher.observe(
                trial.trial_id, trial.config, trial.last_result.value(metric), final
            )

    # -- main loop -----------------------------------------------------------------
    def is_finished(self) -> bool:
        if self.executor.has_running():
            return False
        # One has_resources probe per (status, shape) bucket via the index —
        # this runs after every event, so it must not scan all n trials.
        for status in (TrialStatus.PENDING, TrialStatus.PAUSED):
            for bucket in self._status_index[status].values():
                if not bucket:
                    continue
                if self.executor.has_resources(next(iter(bucket.values()))):
                    return False
        if not self._searcher_exhausted:
            return False
        return True

    def _choose(self) -> Optional[Trial]:
        """``choose_trial_to_run``, timed into ``sched.choose_us`` — one of
        the three profiled control-plane hot paths (DESIGN.md §8)."""
        if self._m_choose is None:
            return self.scheduler.choose_trial_to_run(self)
        p0 = _perf()
        trial = self.scheduler.choose_trial_to_run(self)
        self._m_choose.observe((_perf() - p0) * 1e6)
        return trial

    def _drain_resume_queue(self) -> None:
        """Launch restored trials (phase order) before the scheduler's own
        choose loop runs: the base ``choose_trial_to_run`` is PENDING-first,
        so fresh never-started trials would otherwise steal the capacity the
        restored trials held when the original controller died."""
        tracer = self.obs.tracer
        while self._resume_queue:
            trial = self.get_trial(self._resume_queue[0])
            if trial is None or trial.status not in (
                    TrialStatus.PAUSED, TrialStatus.PENDING):
                self._resume_queue.pop(0)
                continue
            if not self.executor.has_resources(trial):
                return
            checkpoint = (trial.checkpoint
                          if trial.status == TrialStatus.PAUSED else None)
            ok = self.executor.start_trial(trial, checkpoint=checkpoint)
            if not ok:
                if trial.status == TrialStatus.ERROR:
                    self._resume_queue.pop(0)
                    self._finalize_error(trial)
                    continue
                return  # no resources after all
            self._resume_queue.pop(0)
            if tracer.enabled:
                tracer.begin(("trial", trial.trial_id), "trial",
                             trial.trial_id, cat="lifecycle",
                             trainable=trial.trainable_name, restored=True)

    def _launch_loop(self) -> None:
        if self._resume_queue:
            self._drain_resume_queue()
            if self._resume_queue and self.executor.has_running():
                # Out of capacity with restored trials still waiting: don't
                # let the scheduler's choose loop hand their slots to fresh
                # PENDING trials.  (If nothing is running we fall through —
                # the head must be blocked on something else, and stalling
                # the whole loop would deadlock.)
                return
        tracer = self.obs.tracer
        while True:
            t_dec = tracer.clock.time() if tracer.enabled else 0.0
            trial = self._choose()
            if trial is None:
                suggested = self._maybe_suggest()
                if suggested is None:
                    return
                trial = self._choose()
                if trial is None:
                    return
            if tracer.enabled:
                tracer.record("schedule.decision", trial.trial_id, t_dec,
                              tracer.clock.time() - t_dec, cat="sched")
            checkpoint = trial.checkpoint if trial.status == TrialStatus.PAUSED else None
            restored = checkpoint is not None
            ok = self.executor.start_trial(trial, checkpoint=checkpoint)
            if not ok:
                if trial.status == TrialStatus.ERROR:
                    self._finalize_error(trial)
                    continue
                return  # no resources after all
            if tracer.enabled:
                # The trial's lifecycle span: opened per (re)launch, closed at
                # stop/pause/requeue — every other span of this trial nests
                # inside it on the trace row.
                tracer.begin(("trial", trial.trial_id), "trial",
                             trial.trial_id, cat="lifecycle",
                             trainable=trial.trainable_name, restored=restored)

    def step(self) -> bool:
        """Process one event. Returns False when the experiment is finished."""
        self._launch_loop()
        event = self.executor.get_next_event()
        if event is None:
            if not self.is_finished():
                self._stall_count = getattr(self, "_stall_count", 0) + 1
                if self._stall_count > 3:
                    stuck = [t.trial_id for t in self.trials
                             if t.status in (TrialStatus.PENDING, TrialStatus.PAUSED)]
                    raise RuntimeError(
                        f"trial runner stalled: no runnable events but experiment "
                        f"not finished (stuck trials: {stuck}); scheduler deadlock?"
                    )
                return True
            return False
        self._stall_count = 0
        self.obs.on_event(event)          # count + adopt shipped SPAN batches
        self.obs.maybe_snapshot(self.executor)
        if self.flightrec is not None:
            self.flightrec.record_event(event)
        if self.state_snapshotter is not None:
            self.state_snapshotter.maybe_snapshot(self.scheduler, self.searcher)
        if event.type == EventType.SPAN:
            # Spans live in the trace export, not the event log — fully
            # consumed by obs.on_event above.
            return not self.is_finished()
        trial = self.get_trial(event.trial_id)
        if trial is None:  # event for a trial this runner never adopted
            return not self.is_finished()
        if self.broker is not None:
            self.broker.observe(self, event)

        if event.type not in (EventType.RESULT, EventType.ERROR):
            # Observability events (CHECKPOINTED / HEARTBEAT_MISSED /
            # RESTARTED / KILLED / RESIZED / ...): no scheduler decision,
            # just the loggers.
            kinds = self._resume_event_fence.get(trial.trial_id)
            if kinds:
                # Re-executed pre-crash iteration (durable resume): already
                # journaled by the original run — keep the merged journal
                # duplicate-free.
                kind = getattr(event.type, "value", str(event.type)).lower()
                bound = kinds.get(kind)
                if bound is not None:
                    iteration = (event.info or {}).get("iteration")
                    if iteration is not None and iteration <= bound:
                        return not self.is_finished()
                    kinds.pop(kind, None)
            self.logger.on_event(trial, event)
            return not self.is_finished()

        if event.type == EventType.ERROR:
            return self._handle_trial_error(trial, event.error or "unknown trial error")

        if trial.status != TrialStatus.RUNNING:
            # Stale RESULT from a worker halted mid-step (e.g. abandoned after
            # a join timeout, trial since requeued): acting on it would gate a
            # relaunched instance twice.  Drop it.
            return not self.is_finished()

        fence = self._resume_result_fence.get(trial.trial_id)
        if fence is not None:
            if event.result.training_iteration <= fence:
                # Durable resume replaying through an already-journaled
                # stretch: the original run's records for these iterations
                # survive in the (appended-to) journal, so drop the re-run's
                # copy — but still re-open the credit gate, or the worker
                # would park forever waiting for a verdict on it.
                self.executor.resume_trial(trial)
                return not self.is_finished()
            # First live result past the fence: normal processing resumes
            # (and a later PBT rewind below the old fence must not be
            # dropped, so the fence is retired rather than kept around).
            del self._resume_result_fence[trial.trial_id]

        result: Result = event.result
        profile = result.metrics.pop("_profile", None)
        if profile is not None:
            # Hardware profile smuggled on the first result after a (re)build
            # (train/trainable.py): publish it as trial metadata + a PROFILE
            # event so loggers/analysis see it, and keep it out of the
            # metric stream proper.
            trial.profile = profile
            self.logger.on_event(trial, TrialEvent(
                EventType.PROFILE, trial.trial_id, info=profile,
                timestamp=result.timestamp))
        trial.record_result(result)
        self.logger.on_result(trial, result)

        if result.done or trial.should_stop(result):
            if self.decisions is not False:
                self._emit_decision(trial.trial_id, "runner", "TrialRunner", {
                    "verdict": "STOP",
                    "iteration": result.training_iteration,
                    "inputs": self._stop_reason(trial, result)})
            self.stop_trial(trial)
            return not self.is_finished()

        if self._m_decide is None:
            decision = self.scheduler.on_result(self, trial, result)
        else:
            p0 = _perf()
            decision = self.scheduler.on_result(self, trial, result)
            self._m_decide.observe((_perf() - p0) * 1e6)
        self._drain_scheduler_decisions()
        self._observe(trial, final=False)
        self._apply(trial, decision)
        return not self.is_finished()

    def _stop_reason(self, trial: Trial, result: Result) -> Dict[str, Any]:
        """Why the runner (not the scheduler) is stopping this trial."""
        if result.done:
            return {"reason": "result_done"}
        for metric, bound in trial.stopping_criteria.items():
            if metric == "training_iteration":
                if result.training_iteration >= bound:
                    return {"reason": "stopping_criterion", "criterion": metric,
                            "bound": bound, "value": result.training_iteration}
            elif metric in result.metrics and result.value(metric) >= bound:
                return {"reason": "stopping_criterion", "criterion": metric,
                        "bound": bound, "value": result.value(metric)}
        return {"reason": "unknown"}

    # -- failure handling --------------------------------------------------------
    def _handle_trial_error(self, trial: Trial, error: str) -> bool:
        if trial.status.is_finished():
            # Stale ERROR racing a clean stop (e.g. the straggler monitor
            # killed a worker whose final result the runner had already
            # consumed): the trial's outcome is decided — drop it, exactly
            # like stale RESULTs below.
            return not self.is_finished()
        trial.num_failures = getattr(trial, "num_failures", 0) + 1
        retryable = (
            self.max_failures > 0
            and trial.num_failures <= self.max_failures
            and not trial.status.is_finished()
        )
        tracer = self.obs.tracer
        if retryable:
            # Tear down the dead instance; the trial re-enters the launch loop
            # PAUSED (restore from last checkpoint) or PENDING (from scratch).
            self.n_restarts += 1
            if self._m_restarts is not None:
                self._m_restarts.inc()
            self.executor.requeue_trial(trial)
            tracer.end(("trial", trial.trial_id), status="REQUEUED")
            if tracer.enabled:
                # Instant marker: the fault boundary between two lifecycle
                # spans of the same trial.
                tracer.record("restart", trial.trial_id, tracer.clock.time(),
                              0.0, cat="fault",
                              num_failures=trial.num_failures)
            clock = getattr(self.executor, "clock", None)
            self.logger.on_event(trial, TrialEvent(
                EventType.RESTARTED, trial.trial_id, error=error,
                checkpoint=trial.checkpoint,
                timestamp=clock.time() if clock is not None else None,
                info={"num_failures": trial.num_failures,
                      "max_failures": self.max_failures,
                      # where the retry restarts from (0 = from scratch) —
                      # durable resume reconstructs the iteration frontier
                      # and failure counters from this (DESIGN.md §12)
                      "checkpoint_iteration": (
                          trial.checkpoint.training_iteration
                          if trial.checkpoint is not None else 0),
                      # keep the cause on record even when the retry succeeds
                      "error": error[-2000:]}))
            return True
        self.executor.stop_trial(trial, error=error)
        tracer.end(("trial", trial.trial_id), status="ERROR")
        self._finalize_error(trial)
        return not self.is_finished()

    def _finalize_error(self, trial: Trial) -> None:
        self.n_errors += 1
        self.scheduler.on_trial_error(self, trial)
        # An error can trigger peer verdicts (HyperBand re-checks its cut when
        # the awaited peer died) — journal them like any result-path decision.
        self._drain_scheduler_decisions()
        # Errored trials get a final journal record too — without it the
        # JSONL stream has no terminal marker for them and post-hoc analysis
        # would report them as still in flight.
        self.logger.on_trial_complete(trial)
        self._observe(trial, final=True)
        if self.max_experiment_failures and self.n_errors > self.max_experiment_failures:
            self.executor.shutdown()
            raise RuntimeError(
                f"experiment aborted: {self.n_errors} errored trials exceed "
                f"max_experiment_failures={self.max_experiment_failures} "
                f"(last error on {trial.trial_id}: {trial.error})"
            )

    def _apply(self, trial: Trial, decision: SchedulerDecision) -> None:
        if decision == SchedulerDecision.CONTINUE:
            if self.broker is not None:
                # Checkpoint boundary: the trial's worker is parked awaiting
                # this resume, so the broker may resize its slice here
                # (DESIGN.md §6) before the gate re-opens.
                self.broker.before_resume(self, trial)
            self.executor.resume_trial(trial)
            return
        if decision == SchedulerDecision.PAUSE:
            self.executor.pause_trial(trial)
            self.obs.tracer.end(("trial", trial.trial_id), status="PAUSED")
        elif decision == SchedulerDecision.STOP:
            self.stop_trial(trial)
        elif decision == SchedulerDecision.RESTART_WITH_CONFIG:
            ckpt = trial.scheduler_state.pop("restore_from", None)
            new_config = trial.scheduler_state.pop("new_config", None)
            if ckpt is None or new_config is None:
                raise RuntimeError(
                    "RESTART_WITH_CONFIG requires scheduler_state['restore_from'/'new_config']"
                )
            try:
                self.executor.restart_trial_with_config(trial, ckpt, new_config)
            finally:
                # Unpin once the donor state was consumed.  A deferred restart
                # (no capacity: executor re-queued the trial with the donor
                # checkpoint attached) keeps the pin until the relaunch's
                # restore actually happens (executors unpin at consumption).
                if trial.checkpoint is not ckpt:
                    ckpt.pinned = False
            if trial.status == TrialStatus.ERROR:
                self._finalize_error(trial)
        else:
            raise ValueError(f"unknown scheduler decision {decision}")

    def run(self, max_steps: int = 10_000_000) -> List[Trial]:
        steps = 0
        while steps < max_steps and self.step():
            steps += 1
        self.executor.shutdown()
        self.logger.on_experiment_end(self.trials)
        return self.trials
