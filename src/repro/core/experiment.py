"""Experiment spec and ``run_experiments`` — the paper's §4.3 entry point.

    def my_func(tune): ...
    tune.run_experiments(my_func, {
        "lr": tune.grid_search([0.01, 0.001, 0.0001]),
        "activation": tune.grid_search(["relu", "tanh"]),
    }, scheduler=HyperBandScheduler(...))

Accepts a function-based trainable, a Trainable subclass, or a registered name.
Grid axes become the initial trial set; ``num_samples`` repeats stochastic
draws; a ``searcher`` (TPE/random) can generate trials on demand instead.
"""
from __future__ import annotations

import inspect
import os
import re
import tempfile
import warnings
from typing import Any, Callable, Dict, List, Optional, Union

from .api import Trainable, wrap_function
from .checkpoint import CheckpointManager
from .concurrent_executor import ConcurrentMeshExecutor
from .executor import SerialMeshExecutor, TrialExecutor
from .loggers import (CompositeLogger, ConsoleLogger, CSVLogger, JSONLLogger,
                      LiveReporter, Logger)
from .object_store import ObjectStore
from .process_executor import ProcessMeshExecutor
from .resources import Resources
from .runner import TrialRunner
from .schedulers.base import TrialScheduler
from .schedulers.fifo import FIFOScheduler
from .search.basic import Searcher
from .search.variants import count_grid_variants, format_variant_tag, generate_variants
from .trial import Trial, TrialStatus
from .workers import (TrainableFactory, factory_from_class,
                      register_worker_factory, resolve_worker_factory)

__all__ = ["run_experiments", "ExperimentAnalysis", "register_trainable"]

_REGISTRY: Dict[str, type] = {}


def register_trainable(name: str, cls_or_fn: Union[type, Callable]) -> None:
    _REGISTRY[name] = (
        cls_or_fn if inspect.isclass(cls_or_fn) else wrap_function(cls_or_fn)
    )
    if inspect.isclass(cls_or_fn):
        # Opportunistically mirror importable classes into the process-worker
        # registry so `executor="process"` works without extra ceremony.
        factory = factory_from_class(cls_or_fn)
        if factory is not None:
            register_worker_factory(name, factory)


class _StatePersister(Logger):
    """Fault tolerance (paper §4.2): trial metadata lives in memory, durability
    comes from checkpoints + this periodic metadata snapshot.  On restart,
    ``run_experiments(..., resume=True)`` rebuilds the trial list: finished
    trials keep their results, interrupted ones restart from their last disk
    checkpoint (or from scratch if none was written).

    Dumps fire on trial completion and experiment end, and — clock-throttled —
    on fault-recovery events (RESTARTED / KILLED / ERROR) plus the first
    result of the run, so a controller killed early or mid-fault-storm still
    leaves a usable pkl behind (DESIGN.md §12)."""

    def __init__(self, path: str, runner_ref, clock=None,
                 min_interval_s: float = 5.0):
        self.path = path
        self.runner_ref = runner_ref
        self.clock = clock
        self.min_interval_s = min_interval_s
        self._last_dump: Optional[float] = None
        self._saw_result = False

    def _dump(self) -> None:
        import pickle
        runner = self.runner_ref()
        if runner is None:
            return
        tmp = self.path + ".tmp"
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(tmp, "wb") as f:
            pickle.dump(runner.trials, f)
        os.replace(tmp, self.path)
        if self.clock is not None:
            self._last_dump = self.clock.time()

    def _throttled_dump(self) -> None:
        if (self.clock is not None and self._last_dump is not None
                and self.clock.time() - self._last_dump < self.min_interval_s):
            return
        self._dump()

    def on_result(self, trial, result) -> None:
        if not self._saw_result:
            self._saw_result = True
            self._throttled_dump()

    def on_event(self, trial, event) -> None:
        kind = getattr(getattr(event, "type", None), "value", None)
        if kind in ("RESTARTED", "KILLED", "ERROR"):
            self._throttled_dump()

    def on_trial_complete(self, trial) -> None:
        self._dump()

    def on_experiment_end(self, trials) -> None:
        self._dump()


def load_experiment_state(log_dir: str) -> List[Trial]:
    """Trials from a previous (possibly interrupted) run in ``log_dir``."""
    import pickle
    path = os.path.join(log_dir, "experiment_state.pkl")
    if not os.path.exists(path):
        return []
    with open(path, "rb") as f:
        trials: List[Trial] = pickle.load(f)
    for t in trials:
        if not t.status.is_finished():
            # interrupted mid-flight: resume from the last durable checkpoint
            if t.checkpoint is not None and t.checkpoint.path \
                    and os.path.exists(t.checkpoint.path):
                t.status = TrialStatus.PAUSED
            else:
                t.status = TrialStatus.PENDING
                t.results.clear()
                t.checkpoint = None
    return trials


def _infer_initial_id_offset(journal_path: str, name: str) -> int:
    """The original process's Trial auto-id counter need not have started at
    zero (other trials may have been created first): recover the offset from
    the smallest ``{name}_{NNNNN}`` suffix the journal recorded."""
    import json
    pat = re.compile(rf"^{re.escape(name)}_(\d+)$")
    best: Optional[int] = None
    try:
        with open(journal_path) as f:
            for line in f:
                try:
                    obj = json.loads(line)
                except (ValueError, TypeError):
                    continue
                tid = obj.get("trial_id") if isinstance(obj, dict) else None
                if isinstance(tid, str):
                    m = pat.match(tid)
                    if m:
                        v = int(m.group(1))
                        if best is None or v < best:
                            best = v
    except OSError:
        return 0
    return best or 0


def _resume_base_trials(log_dir: str, journal_path: str, name: str,
                        space_variants: Optional[List[Dict[str, Any]]],
                        resources: Resources,
                        stop: Optional[Dict[str, float]]) -> List[Trial]:
    """Identity source for the resumed run's *initial* trial set: the legacy
    pkl when one survives (authoritative ids + configs), else the space
    regenerated with the original id offset, else nothing (journal-only —
    configs then come from result records)."""
    import pickle
    pkl = os.path.join(log_dir, "experiment_state.pkl")
    if os.path.exists(pkl):
        try:
            with open(pkl, "rb") as f:
                return pickle.load(f)
        except Exception:
            pass  # torn by the crash: fall through to regeneration
    if space_variants is not None:
        offset = _infer_initial_id_offset(journal_path, name)
        return [Trial(config=config, trainable_name=name, resources=resources,
                      stopping_criteria=stop, tag=format_variant_tag(config),
                      trial_id=f"{name}_{offset + i:05d}")
                for i, config in enumerate(space_variants)]
    return []


class ExperimentAnalysis:
    """Post-hoc queries over a finished experiment (best trial, result table)."""

    def __init__(self, trials: List[Trial], metric: str, mode: str):
        self.trials = trials
        self.metric = metric
        self.mode = mode

    def best_trial(self) -> Optional[Trial]:
        best, best_v = None, None
        for t in self.trials:
            v = t.best_value(self.metric, self.mode)
            if v is None:
                continue
            if best_v is None or (v > best_v if self.mode == "max" else v < best_v):
                best, best_v = t, v
        return best

    def best_config(self) -> Optional[Dict[str, Any]]:
        t = self.best_trial()
        return dict(t.config) if t else None

    def best_value(self) -> Optional[float]:
        t = self.best_trial()
        return t.best_value(self.metric, self.mode) if t else None

    def results_table(self) -> List[Dict[str, Any]]:
        rows = []
        for t in self.trials:
            rows.append({
                "trial_id": t.trial_id,
                "status": t.status.value,
                "iterations": t.training_iteration,
                "best": t.best_value(self.metric, self.mode),
                "config": {k: v for k, v in t.config.items() if not k.startswith("_")},
            })
        return rows

    def total_iterations(self) -> int:
        return sum(t.training_iteration for t in self.trials)


def run_experiments(
    trainable: Union[str, type, Callable],
    space: Optional[Dict[str, Any]] = None,
    *,
    scheduler: Optional[TrialScheduler] = None,
    searcher: Optional[Searcher] = None,
    num_samples: int = 1,
    stop: Optional[Dict[str, float]] = None,
    resources_per_trial: Optional[Resources] = None,
    total_cpu: float = 64.0,
    total_devices: int = 256,
    slice_pool: Optional[Any] = None,
    checkpoint_freq: int = 1,
    log_dir: Optional[str] = None,
    verbose: bool = False,
    seed: int = 0,
    max_steps: int = 10_000_000,
    executor: Union[None, str, TrialExecutor] = None,
    hosts: Any = None,                      # cluster tier: roster (int/str/specs)
    placement: Any = "roofline",            # cluster tier: placement policy
    max_failures: int = 0,
    max_experiment_failures: int = 0,
    heartbeat_timeout: float = 60.0,
    straggler_deadline: float = 0.0,
    elastic: Union[None, str, Any] = None,
    lookahead: int = 1,
    metric: Optional[str] = None,
    mode: Optional[str] = None,
    resume: bool = False,
    clock: Optional[Any] = None,  # repro.core.clock.Clock; None = default
    trace: Union[None, bool, str] = None,   # Chrome trace-event JSON path
    metrics_interval: float = 0.0,          # >0 = JSONL metrics snapshots
    search_state_interval: float = 10.0,    # search_state.json snapshot throttle
    obs: Optional[Any] = None,              # pre-built repro.obs.Observability
    report: Union[None, bool, str] = None,  # HTML run report (needs log_dir)
    live_table: bool = False,               # LiveReporter trial table
    decisions: Union[bool, str] = True,     # DECISION journaling (§10)
    flight_recorder: Union[None, bool, str, Any] = None,  # crash forensics (§10)
) -> ExperimentAnalysis:
    """Run one experiment to completion; returns an ExperimentAnalysis.

    ``executor`` is a TrialExecutor instance, or ``"serial"``/``"concurrent"``/
    ``"process"`` to build one here (``"concurrent"`` steps trials on worker
    threads with heartbeat/straggler detection — DESIGN.md §4; ``"process"``
    runs each trial in a spawned worker process with GIL-free host stepping
    and kill-on-straggle reclamation after ``straggler_deadline`` seconds —
    DESIGN.md §5; it needs a spawn-safe trainable: an importable class or a
    ``TrainableFactory``).  ``max_failures`` restarts a crashed trial from its
    last checkpoint up to that many times before marking it ERROR;
    ``max_experiment_failures`` aborts the whole experiment once more trials
    than that have errored.

    ``elastic`` turns on the elastic resource control plane (DESIGN.md §6):
    ``"greedy"`` (survivors absorb devices freed by early-stopped trials),
    ``"fair"`` (rebalance the pool across running trials), ``"off"``/None, or
    a ``repro.core.elastic.ResizePolicy`` instance.  Resizes happen at
    checkpoint boundaries (SAVE -> swap slice -> rebuild + re-shard ->
    RESTORE) and need a ``slice_pool``.  ``lookahead`` lets each worker run
    up to K un-consumed results ahead of the scheduler on throughput-bound
    sweeps; it is clamped to 1 automatically whenever the scheduler can
    stop/pause/perturb trials (``Scheduler.decision_interval() != 0``), so
    scheduler decisions stay serial-exact.

    ``resume=True`` (requires ``log_dir``) rebuilds an interrupted — even
    kill -9'd — run from its durable artifacts (DESIGN.md §12): trial
    statuses, iteration counts and metric histories replay from
    ``log_dir/events.jsonl``; scheduler and searcher state load from the
    watermarked ``log_dir/search_state.json`` snapshot (the journal tail
    past the watermark is replayed through them); weights restore from the
    per-trial checkpoint mirrors under ``log_dir/ckpt``.  Finished trials
    are kept; a trial with a valid mirror continues from that iteration; a
    trial with none restarts from scratch with its failure counters intact.
    The journal is appended to, not truncated, so a resumed run's decision
    stream continues the original one.  Runs from before the journal era
    fall back to the legacy ``experiment_state.pkl`` path.  ``space=`` is
    only used to regenerate the original trial identities — changing it
    between runs is ignored (and warned about); a changed ``num_samples``
    that conflicts with the restored trial count raises.
    ``search_state_interval`` throttles the search-state snapshots (seconds
    on the injected clock, default 10, independent of ``metrics_interval``).

    ``clock`` injects the time source (DESIGN.md §7) into the executor, the
    event bus, the loggers and the broker in one stroke — a ``VirtualClock``
    here runs the whole control plane on deterministic virtual time (the
    repro.testing harness does exactly this).

    Observability (DESIGN.md §8): ``trace="out.json"`` records per-trial spans
    for every lifecycle phase and exports a Perfetto/chrome://tracing-viewable
    Chrome trace on completion; ``metrics_interval=S`` turns on the metrics
    registry and (with ``log_dir``) snapshots it to ``log_dir/metrics.jsonl``
    every S clock-seconds, plus a status table at experiment end.  Pass a
    pre-built ``repro.obs.Observability`` via ``obs`` to control both.

    ``report=True`` (needs ``log_dir``: the JSONL journal is the source)
    renders the self-contained HTML run report to ``log_dir/report.html`` —
    or to an explicit path when ``report`` is a string — after the run ends,
    even when it ends by abort (DESIGN.md §9).  ``live_table=True`` attaches
    a ``LiveReporter`` rendering the live trial status table, throttled on
    the injected clock.

    Decision provenance (DESIGN.md §10): ``decisions=True`` (default)
    journals every scheduler/searcher/runner verdict as a typed DECISION
    record with its inputs; ``"full"`` includes CONTINUE verdicts; ``False``
    disables.  ``flight_recorder`` arms the crash-forensics ring buffer:
    with a ``log_dir`` it defaults on (dumping to ``log_dir/flightrec``);
    pass True (dump dir from ``$REPRO_FLIGHTREC_DIR``, default
    ``flightrec``), a directory path, or a pre-built ``FlightRecorder``.  On
    SIGTERM, a controller exception, or a max_experiment_failures abort it
    dumps a self-contained forensic bundle; scheduler+searcher state is also
    checkpointed to ``log_dir/search_state.json`` on the metrics-snapshot
    throttle."""
    from .clock import get_default_clock
    clock = clock or get_default_clock()
    scheduler = scheduler or FIFOScheduler()
    metric = metric or scheduler.metric
    mode = mode or scheduler.mode
    if report and not log_dir:
        raise ValueError("report=... requires log_dir (the JSONL journal is "
                         "the report's source)")

    # -- resolve trainable -------------------------------------------------------
    if isinstance(trainable, str):
        name = trainable
        if name not in _REGISTRY:
            raise KeyError(f"trainable {name!r} not registered")
    elif isinstance(trainable, TrainableFactory):
        # Spawn-safe recipe: register the resolved class for in-host executors
        # AND the factory itself for process workers.
        cls = trainable.resolve()
        name = getattr(cls, "__name__", "trainable")
        _REGISTRY[name] = cls
        register_worker_factory(name, trainable)
    else:
        name = getattr(trainable, "__name__", "trainable")
        register_trainable(name, trainable)
    if executor in ("process", "cluster"):
        try:
            resolve_worker_factory(name)
        except KeyError as e:
            raise ValueError(str(e)) from None

    # -- observability (repro.obs, DESIGN.md §8) -----------------------------------
    if obs is None and (trace or metrics_interval > 0):
        from ..obs import Observability
        metrics_target: Any = metrics_interval > 0
        if metrics_target and log_dir:
            metrics_target = os.path.join(log_dir, "metrics.jsonl")
        obs = Observability(trace=trace, metrics=metrics_target,
                            metrics_interval=metrics_interval or 10.0,
                            clock=clock)
    from ..obs import NULL_OBS
    obs = obs or NULL_OBS

    # -- plumbing ------------------------------------------------------------------
    store = ObjectStore(spill_dir=os.path.join(log_dir, "spill") if log_dir else None)
    ckpt_mgr = CheckpointManager(store,
                                 dir=os.path.join(log_dir, "ckpt") if log_dir else None,
                                 durable=log_dir is not None)
    if executor is None or isinstance(executor, str):
        kind = executor or "serial"
        common = dict(
            trainable_cls_resolver=_REGISTRY.__getitem__,
            checkpoint_manager=ckpt_mgr,
            total_cpu=total_cpu,
            total_devices=total_devices,
            slice_pool=slice_pool,
            checkpoint_freq=checkpoint_freq,
            clock=clock,
            obs=obs,
        )
        if kind == "serial":
            executor = SerialMeshExecutor(**common)
        elif kind == "concurrent":
            executor = ConcurrentMeshExecutor(
                heartbeat_timeout=heartbeat_timeout, **common)
        elif kind == "process":
            executor = ProcessMeshExecutor(
                heartbeat_timeout=heartbeat_timeout,
                straggler_deadline=straggler_deadline, **common)
        elif kind == "cluster":
            from ..cluster import ClusterMeshExecutor
            common.pop("slice_pool", None)  # cluster builds per-host pools
            executor = ClusterMeshExecutor(
                hosts=hosts if hosts is not None else 2,
                placement=placement,
                heartbeat_timeout=heartbeat_timeout,
                straggler_deadline=straggler_deadline, **common)
        else:
            raise ValueError(
                f"unknown executor {kind!r}; pass 'serial', 'concurrent', "
                f"'process', 'cluster', or a TrialExecutor instance "
                f"(VmapExecutor needs a VectorTrainableSpec)")
    exec_kind = (executor if isinstance(executor, str)
                 else type(executor).__name__)

    # -- durable resume (DESIGN.md §12): plan BEFORE the journal reopens ----------
    plan = None
    restored: List[Trial] = []
    if resume:
        if not log_dir:
            raise ValueError("resume=True requires log_dir")
        if space is not None:
            warnings.warn(
                "resume=True restores the original run's trials from its "
                "journal; `space=` is only used to regenerate their identity "
                "— any changes to its values are IGNORED on resume",
                UserWarning, stacklevel=2)
        journal_path = os.path.join(log_dir, "events.jsonl")
        if os.path.exists(journal_path):
            from .resume import prepare_resume
            space_variants = (list(generate_variants(
                space, num_samples=num_samples, seed=seed))
                if space is not None else None)
            base = _resume_base_trials(
                log_dir, journal_path, name, space_variants,
                resources_per_trial or Resources(), stop)
            plan = prepare_resume(
                journal_path,
                os.path.join(log_dir, "search_state.json"),
                scheduler, searcher=searcher, base_trials=base,
                checkpoint_dir=os.path.join(log_dir, "ckpt"),
                trainable_name=name,
                default_resources=resources_per_trial or Resources(),
                stopping_criteria=stop)
            if space_variants is not None:
                sugg = re.compile(rf"^{re.escape(name)}_sugg_\d+$")
                n_initial = sum(1 for t in plan.trials
                                if not sugg.match(t.trial_id))
                if n_initial != len(space_variants):
                    raise ValueError(
                        f"resume=True: the restored run has {n_initial} "
                        f"initial trials but space/num_samples would generate "
                        f"{len(space_variants)}; refusing to mix — resume "
                        f"with the original space and num_samples, or start "
                        f"a fresh log_dir")
        else:
            # Pre-journal run: experiment_state.pkl is all there is.
            restored = load_experiment_state(log_dir)

    loggers: List[Logger] = [ConsoleLogger(verbose=verbose, clock=clock,
                                           obs=obs if obs.active else None)]
    if live_table:
        loggers.append(LiveReporter(metric=metric, clock=clock))
    jsonl_logger: Optional[JSONLLogger] = None
    if log_dir:
        loggers.append(CSVLogger(os.path.join(log_dir, "csv")))
        jsonl_logger = JSONLLogger(
            os.path.join(log_dir, "events.jsonl"), clock=clock,
            executor=exec_kind, decisions=decisions is not False,
            resumed=plan is not None,
            initial_records=plan.n_journal_records if plan is not None else 0)
        loggers.append(jsonl_logger)
    logger = CompositeLogger(loggers)

    # -- crash forensics + searcher-state checkpoints (DESIGN.md §10) -------------
    from ..obs.flightrec import FlightRecorder, SearchStateSnapshotter
    if flight_recorder is None and log_dir:
        flight_recorder = os.path.join(log_dir, "flightrec")
    if flight_recorder is True:
        flight_recorder = os.environ.get("REPRO_FLIGHTREC_DIR", "flightrec")
    if isinstance(flight_recorder, str):
        flightrec: Optional[FlightRecorder] = FlightRecorder(
            clock=clock, out_dir=flight_recorder)
    else:
        flightrec = flight_recorder or None
    if flightrec is not None:
        flightrec.bind_clock(clock)
        for lg in loggers:
            if isinstance(lg, JSONLLogger):
                flightrec.run_id = lg.run_id  # one id across journal + dumps
                break
    snapshotter = None
    if log_dir:
        # Watermarked on the journal's record count: a snapshot taken at
        # watermark W reflects exactly journal records [0..W), which is what
        # lets resume replay only the tail (DESIGN.md §12).
        snapshotter = SearchStateSnapshotter(
            os.path.join(log_dir, "search_state.json"), clock=clock,
            interval_s=search_state_interval,
            watermark_fn=((lambda: jsonl_logger.n_records)
                          if jsonl_logger is not None else None))

    broker = None
    if (elastic not in (None, "off")) or lookahead != 1:
        from .elastic import ResourceBroker, resolve_policy
        broker = ResourceBroker(policy=resolve_policy(elastic),
                                lookahead=lookahead, clock=clock)

    runner = TrialRunner(
        scheduler=scheduler,
        executor=executor,
        searcher=searcher,
        logger=logger,
        trainable_name=name,
        default_resources=resources_per_trial or Resources(),
        stopping_criteria=stop,
        max_failures=max_failures,
        max_experiment_failures=max_experiment_failures,
        broker=broker,
        obs=obs,
        decisions=decisions,
        flight_recorder=flightrec,
        state_snapshotter=snapshotter,
    )
    if log_dir:
        import weakref
        loggers.append(_StatePersister(
            os.path.join(log_dir, "experiment_state.pkl"), weakref.ref(runner),
            clock=clock))

    # -- initial trials ---------------------------------------------------------------
    if plan is not None:
        runner.apply_resume_plan(plan)
        for w in plan.warnings:
            warnings.warn(f"resume: {w}", UserWarning, stacklevel=2)
        if verbose:
            print(f"[repro] {plan.summary()}")
    elif restored:
        for trial in restored:
            trial.trainable_name = name  # rebind to this process's registration
            runner.add_trial(trial)
    if plan is not None or restored:
        pass  # resumed experiments keep their original trial set
    elif space is not None:
        for config in generate_variants(space, num_samples=num_samples, seed=seed):
            runner.add_trial(Trial(
                config=config,
                trainable_name=name,
                resources=resources_per_trial or Resources(),
                stopping_criteria=stop,
                tag=format_variant_tag(config),
            ))
    elif searcher is None:
        raise ValueError("provide a space, a searcher, or both")

    # The teardown below runs even when the sweep aborts (max_experiment_
    # failures, KeyboardInterrupt): traces, the metrics snapshot stream, the
    # journal's final records, and the HTML report must survive the abort —
    # an aborted run is exactly the one worth inspecting.
    completed = False
    sigterm_armed = (flightrec.install_signal_handler(runner, executor)
                     if flightrec is not None else False)
    try:
        runner.run(max_steps=max_steps)
        completed = True
    finally:
        if sigterm_armed:
            flightrec.remove_signal_handler()
        if not completed:
            # runner.run does both of these on its clean path; an exception
            # skipped them.  Neither may mask the original exception.
            if flightrec is not None:
                # The abort is exactly what the flight recorder exists for:
                # dump the forensic bundle before anything is torn down.
                try:
                    flightrec.dump(runner, executor, reason="abort")
                except Exception:
                    pass
            try:
                executor.shutdown()
            except Exception:
                pass
            try:
                logger.on_experiment_end(runner.trials)
            except Exception:
                pass
        if snapshotter is not None:
            try:
                snapshotter.snapshot(scheduler, searcher)  # final state
            except Exception:
                if completed:
                    raise
        obs.close(executor)  # final metrics snapshot + Chrome trace export
        logger.close()
        if report and log_dir:
            try:
                from ..obs.report import build_report
                journal = os.path.join(log_dir, "events.jsonl")
                out = (report if isinstance(report, str)
                       else os.path.join(log_dir, "report.html"))
                with open(out, "w") as f:
                    f.write(build_report(
                        journal_path=journal, trace_path=obs.trace_path,
                        metrics_path=obs.metrics_path,
                        metric=metric, mode=mode))
            except Exception:
                if completed:
                    raise
                # aborting run: the abort is the story, not a report failure
    return ExperimentAnalysis(runner.trials, metric=metric, mode=mode)
