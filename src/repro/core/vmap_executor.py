"""VmapExecutor — beyond-paper: model selection as a single SPMD program.

Ray Tune runs each trial as its own actor/process; on a TPU mesh that wastes
the accelerator whenever trials are shape-homogeneous (identical model/batch,
different scalar hyperparameters — the common case for lr/momentum/wd sweeps).
Here N live trials are STACKED: params/opt-states become (N, ...) pytrees and
one jitted ``vmap``-over-hyperparameters step advances every trial at once.
Per-trial dispatch overhead vanishes and the stacked step saturates the mesh
(lanes can additionally shard over the data axes — a dimension Ray cannot use).

Scheduling semantics are preserved exactly: each tick yields one Result per
live lane into the runner's event queue; PAUSE/STOP mask a lane out (its state
slot is retained for checkpoint/restore); PBT clone copies lane i's slice onto
lane j.  Lanes are compacted lazily: a stopped lane is recycled for the next
PENDING trial so the stacked step never recompiles for lane-count changes.

Contract: the user supplies a ``VectorTrainableSpec`` —
    init_fn(seed, hypers)        -> state pytree (one trial)
    step_fn(state, hypers)       -> (state, metrics dict of scalars)
    hyper_space: the scalar hyperparameter names vmap maps over.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .checkpoint import CheckpointManager
from .resources import ResourceAccountant, Resources
from .executor import TrialExecutor
from .trial import Checkpoint, Result, Trial, TrialStatus

__all__ = ["VectorTrainableSpec", "VmapExecutor"]


@dataclasses.dataclass(frozen=True)
class VectorTrainableSpec:
    init_fn: Callable[[int, Dict[str, float]], Any]
    step_fn: Callable[[Any, Dict[str, jax.Array]], Tuple[Any, Dict[str, jax.Array]]]
    hyper_names: Tuple[str, ...]
    steps_per_iter: int = 1


class VmapExecutor(TrialExecutor):
    def __init__(
        self,
        spec: VectorTrainableSpec,
        checkpoint_manager: CheckpointManager,
        n_lanes: int = 8,
        total_cpu: float = 64.0,
        total_devices: int = 256,
        checkpoint_freq: int = 1,
    ):
        self.spec = spec
        self.ckpt = checkpoint_manager
        self.n_lanes = n_lanes
        self.accountant = ResourceAccountant(total_cpu, total_devices)
        self.checkpoint_freq = checkpoint_freq

        self._lane_trial: List[Optional[Trial]] = [None] * n_lanes
        self._iterations: List[int] = [0] * n_lanes
        self._stacked: Any = None          # (N, ...) state pytree
        self._hypers: Dict[str, np.ndarray] = {
            name: np.zeros(n_lanes, np.float64) for name in spec.hyper_names}
        self._step_jit = None
        self._pending_events: deque = deque()

        def one_step(state, hypers):
            for _ in range(spec.steps_per_iter):
                state, metrics = spec.step_fn(state, hypers)
            return state, metrics

        self._vstep = jax.jit(jax.vmap(one_step))

    # -- helpers -----------------------------------------------------------------
    def _free_lane(self) -> Optional[int]:
        for i, t in enumerate(self._lane_trial):
            if t is None:
                return i
        return None

    def _lane_of(self, trial: Trial) -> Optional[int]:
        for i, t in enumerate(self._lane_trial):
            if t is not None and t.trial_id == trial.trial_id:
                return i
        return None

    def _lane_state(self, lane: int) -> Any:
        return jax.tree_util.tree_map(lambda x: x[lane], self._stacked)

    def _set_lane_state(self, lane: int, state: Any) -> None:
        self._stacked = jax.tree_util.tree_map(
            lambda full, s: full.at[lane].set(s), self._stacked, state)

    def _hyper_dict(self, trial: Trial) -> Dict[str, float]:
        return {k: float(trial.config[k]) for k in self.spec.hyper_names}

    # -- TrialExecutor interface ---------------------------------------------------
    def has_resources(self, trial: Trial) -> bool:
        return self._free_lane() is not None and self.accountant.has_room(trial.resources)

    def has_running(self) -> bool:
        return any(t is not None for t in self._lane_trial)

    def start_trial(self, trial: Trial, checkpoint: Optional[Checkpoint] = None) -> bool:
        lane = self._free_lane()
        if lane is None:
            return False
        self.accountant.acquire(trial.resources)
        hypers = self._hyper_dict(trial)
        if checkpoint is not None:
            snap = self.ckpt.restore(checkpoint)
            state = jax.tree_util.tree_map(jnp.asarray, snap["state"])
            self._iterations[lane] = snap["iteration"]
        else:
            state = self.spec.init_fn(int(trial.config.get("init_seed", 0)), hypers)
            self._iterations[lane] = 0
        if self._stacked is None:
            self._stacked = jax.tree_util.tree_map(
                lambda x: jnp.stack([x] * self.n_lanes), state)
        else:
            self._set_lane_state(lane, state)
        for k, v in hypers.items():
            self._hypers[k][lane] = v
        self._lane_trial[lane] = trial
        trial.set_status(TrialStatus.RUNNING)
        return True

    def save_checkpoint(self, trial: Trial) -> Checkpoint:
        lane = self._lane_of(trial)
        snap = {"state": jax.device_get(self._lane_state(lane)),
                "iteration": self._iterations[lane]}
        ckpt = self.ckpt.save(trial.trial_id, self._iterations[lane], snap)
        trial.checkpoint = ckpt
        return ckpt

    def pause_trial(self, trial: Trial) -> None:
        lane = self._lane_of(trial)
        if lane is not None:
            self.save_checkpoint(trial)
            self._lane_trial[lane] = None
            self.accountant.release(trial.resources)
        trial.set_status(TrialStatus.PAUSED)

    def stop_trial(self, trial: Trial, error: Optional[str] = None) -> None:
        lane = self._lane_of(trial)
        if lane is not None:
            self._lane_trial[lane] = None
            self.accountant.release(trial.resources)
        if error:
            trial.error = error
            trial.set_status(TrialStatus.ERROR)
        else:
            trial.set_status(TrialStatus.TERMINATED)

    def requeue_trial(self, trial: Trial) -> None:
        lane = self._lane_of(trial)
        if lane is not None:
            self._lane_trial[lane] = None
            self.accountant.release(trial.resources)
        trial.set_status(
            TrialStatus.PAUSED if trial.checkpoint is not None else TrialStatus.PENDING)

    def restart_trial_with_config(self, trial, checkpoint, new_config) -> None:
        """PBT exploit: load donor snapshot into this trial's lane with the
        mutated hypers — an O(1) lane-slice copy, no process churn."""
        trial.config = dict(new_config)
        lane = self._lane_of(trial)
        snap = self.ckpt.restore(checkpoint)
        state = jax.tree_util.tree_map(jnp.asarray, snap["state"])
        if lane is None:
            self.start_trial(trial)
            lane = self._lane_of(trial)
        self._set_lane_state(lane, state)
        self._iterations[lane] = snap["iteration"]
        for k in self.spec.hyper_names:
            self._hypers[k][lane] = float(new_config[k])

    def get_next_result(self) -> Optional[Tuple[Trial, Any]]:
        if self._pending_events:
            return self._pending_events.popleft()
        live = [i for i, t in enumerate(self._lane_trial) if t is not None]
        if not live:
            return None
        hypers = {k: jnp.asarray(v) for k, v in self._hypers.items()}
        try:
            self._stacked, metrics = self._vstep(self._stacked, hypers)
        except Exception as e:  # noqa: BLE001
            trial = self._lane_trial[live[0]]
            return trial, e
        metrics_np = jax.device_get(metrics)
        for lane in live:
            trial = self._lane_trial[lane]
            self._iterations[lane] += 1
            result = Result(
                trial_id=trial.trial_id,
                training_iteration=self._iterations[lane],
                metrics={k: float(np.asarray(v)[lane]) for k, v in metrics_np.items()},
            )
            if self.checkpoint_freq and self._iterations[lane] % self.checkpoint_freq == 0:
                self.save_checkpoint(trial)
            self._pending_events.append((trial, result))
        return self._pending_events.popleft()

    def shutdown(self) -> None:
        for i, t in enumerate(self._lane_trial):
            if t is not None:
                self.accountant.release(t.resources)
            self._lane_trial[i] = None
