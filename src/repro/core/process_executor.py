"""ProcessMeshExecutor — one OS process per RUNNING trial, with reclamation.

The third execution tier (DESIGN.md §5).  Where ``ConcurrentMeshExecutor``
gives each trial a worker *thread* (overlapped device work, but host-side code
serializes on the GIL and a hung step leaks its slice forever), this executor
gives each trial a spawned worker *process* driven over the ``repro.core.workers``
command protocol:

- host-compute-heavy trainables step truly in parallel (no shared GIL);
- checkpoint bytes cross the boundary through the ObjectStore's spill surface
  (keys on the pipe, ``tree_to_bytes`` payloads on disk) — live JAX objects
  never pickle across;
- a straggler is *reclaimed*, not abandoned: the monitor escalates a
  ``HEARTBEAT_MISSED`` that exceeds ``straggler_deadline`` to SIGKILL,
  publishes ``KILLED`` + ``ERROR``, and the runner's existing ``max_failures``
  machinery requeues the trial from its last checkpoint while the freed slice
  goes back to the SlicePool for the next trial (the kill-on-straggle state
  machine: RUNNING -> deadline exceeded -> KILLED -> slice released ->
  PAUSED/PENDING -> RESTARTED).

Threading contract: the *runner thread* owns trial lifecycle and all
ResourceAccountant/SlicePool mutation, exactly as in the thread tier.  A
*pump thread* multiplexes every worker pipe, translating child messages into
``EventBus`` events (RESULT/ERROR/CHECKPOINTED) and routing synchronous
replies (SAVED/RESTORED/RESET/STOPPED) to the runner-side waiter.  A *monitor
thread* watches step ages and spawn ages and is the only other place a kill
originates.  Killing a process from the monitor is safe — resource release
still happens on the runner thread when it processes the resulting ERROR.

Clock seam (DESIGN.md §7): children are real OS processes, so their pipes
and the synchronous reply waits stay on *real* time — but all deadline math
(step/spawn ages, monitor interval, kill escalation) reads the injected
``Clock``.  Under a ``VirtualClock`` the monitor's straggler arithmetic can
be fast-forwarded deterministically while the child itself stays wall-bound;
the pump thread deliberately never registers with the clock (it blocks on
real child pipes the clock cannot see).
"""
from __future__ import annotations

import multiprocessing.connection as mp_conn
import os
import queue
import threading
import time
import traceback
from typing import Any, Callable, Dict, Optional

from .checkpoint import CheckpointManager
from .clock import Clock
from .events import EventBus, EventType, TrialEvent
from .executor import BusDrivenExecutor
from .trial import Checkpoint, Result, Trial, TrialStatus
from .workers import (CMD_RESET_CONFIG, CMD_RESIZE, CMD_RESTORE, CMD_SAVE,
                      CMD_STEP, CMD_STOP, ProcessWorker, TrainableFactory,
                      resolve_worker_factory)
from . import workers as _w

__all__ = ["ProcessMeshExecutor"]


class _WorkerHandle:
    """Per-trial bookkeeping for one worker process."""

    def __init__(self, trial: Trial, worker: ProcessWorker, clock: Clock):
        self.trial = trial
        self.worker = worker
        self.reply_q: "queue.Queue" = queue.Queue()  # SAVED/RESTORED/RESET/STOPPED
        self.ready = False
        self.in_step = False
        # Lookahead credits (DESIGN.md §6): STEP commands sent but whose
        # RESULT has not come back.  k=1 is PR 3's binary resume gate; k>1
        # queues STEPs in the pipe so the child never idles a round-trip
        # between a RESULT and its next step.  Mutated by the runner thread
        # (_kick via resume) and the pump thread (_kick via READY, decrement
        # on RESULT) — guarded by ctr_lock.
        self.outstanding = 0
        self.ctr_lock = threading.Lock()
        self.step_started = 0.0
        self.spawned_at = clock.monotonic()
        self.last_warned = 0.0
        self.dead = False      # pipe closed / child exited / ERROR published
        self.killed = False    # we SIGKILLed it (straggler or teardown)
        self.stopping = False  # runner-driven teardown in progress
        self.restore_key: Optional[str] = None  # un-consumed export_copy payload
        self.restore_ckpt: Optional[Checkpoint] = None  # pinned until consumed
        # True while a runner-side call (SAVE/RESTORE/RESET) awaits its reply:
        # a child failure then belongs to that caller, NOT the event bus — the
        # caller handles it inline (e.g. PBT falls back to a full rebuild), and
        # a bus ERROR would later hit the healthy rebuilt worker.
        self.expecting_reply = False

    @property
    def transport(self):
        """The worker's duplex message channel (pipe Connection or a cluster
        Transport).  None while a socket worker is still dialing in."""
        return self.worker.transport


class ProcessMeshExecutor(BusDrivenExecutor):
    def __init__(
        self,
        trainable_cls_resolver: Optional[Callable[[str], type]] = None,
        checkpoint_manager: Optional[CheckpointManager] = None,
        total_cpu: float = 64.0,
        total_devices: int = 256,
        slice_pool: Optional[Any] = None,  # dist.submesh.SlicePool
        checkpoint_freq: int = 0,
        heartbeat_timeout: float = 60.0,    # <=0 disables HEARTBEAT_MISSED
        straggler_deadline: float = 0.0,    # <=0 disables kill-on-straggle
        event_bus: Optional[EventBus] = None,
        factory_resolver: Optional[Callable[[str], TrainableFactory]] = None,
        join_timeout: float = 5.0,          # STOP -> SIGKILL escalation window
        spawn_timeout: float = 120.0,       # spawn -> READY deadline
        reply_timeout: float = 30.0,        # synchronous SAVE/RESTORE/RESET waits
        mp_context: Optional[str] = None,   # None = forkserver-preloaded/spawn
        worker_nice: int = 1,               # children yield to the control plane
        clock: Optional[Clock] = None,      # deadline math only; children stay wall
        obs: Optional[Any] = None,
    ):
        # trainable_cls_resolver is accepted for signature parity with the
        # in-host executors but never used to instantiate: the child rebuilds
        # from the factory.
        if checkpoint_manager is None:
            from .object_store import ObjectStore
            checkpoint_manager = CheckpointManager(ObjectStore())
        super().__init__(trainable_cls_resolver or (lambda name: None),
                         checkpoint_manager, total_cpu, total_devices,
                         slice_pool, checkpoint_freq, event_bus=event_bus,
                         clock=clock, obs=obs)
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_deadline = straggler_deadline
        self.join_timeout = join_timeout
        self.spawn_timeout = spawn_timeout
        self.reply_timeout = reply_timeout
        self.mp_context = mp_context
        self.worker_nice = worker_nice
        self._resolve_factory = factory_resolver or resolve_worker_factory
        self._owns_spill_dir = self.ckpt.store.spill_dir is None
        self._spill_dir = self.ckpt.store.ensure_spill_dir()
        self._ckpt_lock = threading.Lock()  # CheckpointManager access (pump + runner)
        self._shutdown_evt = self.clock.event()
        # The pump blocks on real child pipes the clock cannot see, so it
        # needs a real shutdown signal of its own (a virtual event would
        # require the pump to park through the clock to observe it).
        self._pump_shutdown = threading.Event()
        self.n_killed = 0
        self._pump_thread = threading.Thread(
            target=self._pump, name="repro-proc-pump", daemon=True)
        self._pump_thread.start()
        # The monitor doubles as the spawn watchdog, so it always runs; the
        # per-feature timeouts (<=0) disable their own escalations only.
        ready = threading.Event()
        self._monitor_thread = threading.Thread(
            target=self._monitor, args=(ready,),
            name="repro-proc-monitor", daemon=True)
        self._monitor_thread.start()
        # Roster handshake (virtual determinism): fail loudly on timeout
        # rather than let virtual time advance around a booting monitor.
        if not ready.wait(timeout=10.0):
            raise RuntimeError(
                "process monitor failed to enroll with the clock within 10s")

    def _events_guaranteed(self) -> bool:
        # An unbounded runner wait is safe only when the monitor covers BOTH
        # hang phases: heartbeats / kill deadline for a child stuck mid-step,
        # and the spawn watchdog for one that never becomes READY.
        return ((self.heartbeat_timeout > 0 or self.straggler_deadline > 0)
                and self.spawn_timeout > 0)

    # -- pump: child messages -> events / replies -------------------------------------
    def _pump(self) -> None:
        # Transport-agnostic multiplexing: ``mp_conn.wait`` accepts pipe
        # Connections AND sockets, so one pump serves both tiers.  A framed
        # transport exposes its selectable object via ``waitable``; a raw
        # Connection is its own waitable.
        while not self._pump_shutdown.is_set():
            handles: Dict[Any, _WorkerHandle] = {}
            transports: Dict[Any, Any] = {}
            for ws in list(self._workers.values()):
                if ws.dead:
                    continue
                t = ws.transport
                if t is None:
                    continue  # socket worker still dialing in
                w = getattr(t, "waitable", t)
                handles[w] = ws
                transports[w] = t
            if not handles:
                self._pump_shutdown.wait(0.05)
                continue
            try:
                ready = mp_conn.wait(list(handles), timeout=0.2)
            except OSError:
                continue  # a conn was torn down mid-wait; re-snapshot
            for w in ready:
                ws = handles[w]
                try:
                    msg = transports[w].recv()
                except (EOFError, OSError) as exc:
                    if ws.transport is not transports[w]:
                        # The worker re-attached a fresh transport (cluster
                        # reconnect) while this snapshot was in flight; the
                        # stale stream's EOF is not a death.
                        continue
                    self._on_recv_error(ws, exc)
                    continue
                try:
                    self._handle_message(ws, msg)
                except Exception:  # noqa: BLE001 — never let the pump die silently
                    ws.dead = True
                    ws.reply_q.put(("DEAD",))
                    self.bus.publish(TrialEvent(
                        EventType.ERROR, ws.trial.trial_id,
                        error=traceback.format_exc()))
            # No clock kick needed here: bus.publish kicks its own queue
            # channel, and reply_q is consumed by _await_reply's *real*
            # queue.get (reply latency is real-child latency by design).

    def _on_recv_error(self, ws: _WorkerHandle, exc: BaseException) -> None:
        """A transport recv failed.  For pipes every failure is child death;
        the cluster tier overrides this to escalate framing corruption to
        host eviction (DESIGN.md §11) — the pump itself never wedges."""
        self._on_worker_death(ws)

    def _on_worker_death(self, ws: _WorkerHandle) -> None:
        """Pipe hit EOF: the child exited without a protocol goodbye."""
        if ws.dead:
            return
        ws.dead = True
        ws.in_step = False
        ws.reply_q.put(("DEAD",))
        if (ws.killed or ws.stopping or ws.expecting_reply
                or self._shutdown_evt.is_set()):
            return  # deliberate teardown or a synchronous caller owns the outcome
        exitcode = ws.worker.process.exitcode
        self.bus.publish(TrialEvent(
            EventType.ERROR, ws.trial.trial_id,
            error=(f"worker process for {ws.trial.trial_id} died unexpectedly "
                   f"(exitcode={exitcode}); restarting from last checkpoint "
                   "is governed by max_failures"),
            info={"exitcode": exitcode, "pid": ws.worker.pid}))

    def _handle_message(self, ws: _WorkerHandle, msg: tuple) -> None:
        kind = msg[0]
        trial_id = ws.trial.trial_id
        if kind == _w.MSG_READY:
            ws.ready = True
            ws.restore_key = None  # child restored and consumed the payload
            if ws.restore_ckpt is not None:
                # The restore actually happened — only now may rotation
                # reclaim the source (a boot crash instead keeps the pin so
                # the max_failures retry can re-export it).
                ws.restore_ckpt.pinned = False
                ws.restore_ckpt = None
            self._kick(ws, n=self.lookahead)  # initial credit grant
        elif kind == _w.MSG_RESULT:
            _, iteration, metrics, done = msg
            with ws.ctr_lock:
                ws.outstanding = max(0, ws.outstanding - 1)
                ws.in_step = ws.outstanding > 0
                # One result back = the next queued step begins now; restart
                # the straggler clock so k queued steps aren't judged as one.
                ws.step_started = self.clock.monotonic()
            self.bus.publish(TrialEvent(
                EventType.RESULT, trial_id,
                result=Result(trial_id=trial_id, training_iteration=iteration,
                              metrics=dict(metrics), done=bool(done),
                              timestamp=self.clock.time())))
        elif kind == _w.MSG_CHECKPOINTED:
            _, key, iteration = msg
            with self._ckpt_lock:
                ckpt = self.ckpt.adopt(trial_id, iteration, key)
            ws.trial.checkpoint = ckpt
            self.bus.publish(TrialEvent(
                EventType.CHECKPOINTED, trial_id, checkpoint=ckpt))
        elif kind == _w.MSG_SPANS:
            # Child-side trace spans (build/step/ckpt.*): republish on the bus
            # so the runner's obs adopts them onto the parent trace — the
            # child's spans nest inside the trial's lifecycle span.
            self.bus.publish(TrialEvent(
                EventType.SPAN, trial_id, info={"spans": msg[1]}))
        elif kind == _w.MSG_ERROR:
            ws.dead = True
            ws.in_step = False
            ws.reply_q.put(("DEAD", msg[1]))
            if not ws.expecting_reply and not ws.stopping:
                self.bus.publish(TrialEvent(EventType.ERROR, trial_id, error=msg[1]))
        else:  # SAVED / RESTORED / RESET / RESIZED / STOPPED — a runner-side call waits
            ws.reply_q.put(msg)

    def _kick(self, ws: _WorkerHandle, n: int = 1) -> None:
        """Grant ``n`` step credits: send that many STEPs down the pipe (the
        resume gate re-opened ``n`` results wide).  Pump or runner thread."""
        with ws.ctr_lock:
            if ws.outstanding == 0:
                ws.step_started = self.clock.monotonic()
            for _ in range(max(1, n)):
                if not ws.worker.send(CMD_STEP):
                    break  # pipe dead; pump will surface the EOF
                ws.outstanding += 1
            ws.in_step = ws.outstanding > 0

    # -- monitor: heartbeats, spawn watchdog, kill-on-straggle ------------------------
    def _monitor(self, ready: threading.Event) -> None:
        beats = [t for t in (self.heartbeat_timeout, self.straggler_deadline) if t > 0]
        interval = max(0.05, min([1.0] + [t / 4 for t in beats]))
        with self.clock.running():
            ready.set()
            self._monitor_loop(interval)

    def _monitor_loop(self, interval: float) -> None:
        while not self._shutdown_evt.wait(interval):
            self._monitor_tick(self.clock.monotonic())

    def _monitor_tick(self, now: float) -> None:
        """One monitor pass over the roster; every age compare rides
        ``clock.monotonic()`` (wall-jump-safe — DESIGN.md §7).  The cluster
        tier extends this with host-level heartbeat ages."""
        for ws in list(self._workers.values()):
            if ws.dead or ws.killed or ws.stopping:
                continue
            if not ws.ready:
                if self.spawn_timeout > 0 and now - ws.spawned_at > self.spawn_timeout:
                    self._kill_straggler(ws, now - ws.spawned_at, phase="spawn")
                continue
            if not ws.in_step:
                continue
            elapsed = now - ws.step_started
            if (self.heartbeat_timeout > 0 and elapsed > self.heartbeat_timeout
                    and now - ws.last_warned > self.heartbeat_timeout):
                ws.last_warned = now
                self.bus.publish(TrialEvent(
                    EventType.HEARTBEAT_MISSED, ws.trial.trial_id,
                    info={"stalled_s": round(elapsed, 3),
                          "deadline_s": self.straggler_deadline}))
            if self.straggler_deadline > 0 and elapsed > self.straggler_deadline:
                self._kill_straggler(ws, elapsed, phase="step")

    def _kill_straggler(self, ws: _WorkerHandle, elapsed: float, phase: str) -> None:
        """Escalation: SIGKILL the worker, then hand the failure to the
        runner's retry machinery as an ERROR.  The slice itself is released on
        the runner thread when it requeues/stops the trial."""
        ws.killed = True
        ws.dead = True
        pid = ws.worker.pid
        ws.worker.kill(join_timeout=self.join_timeout)
        ws.in_step = False
        ws.reply_q.put(("DEAD",))
        self.n_killed += 1
        self.bus.publish(TrialEvent(
            EventType.KILLED, ws.trial.trial_id,
            info={"stalled_s": round(elapsed, 3), "pid": pid, "phase": phase,
                  "deadline_s": (self.straggler_deadline if phase == "step"
                                 else self.spawn_timeout)}))
        self.bus.publish(TrialEvent(
            EventType.ERROR, ws.trial.trial_id,
            error=(f"straggling worker (pid {pid}) killed: {phase} exceeded "
                   f"{elapsed:.1f}s (kill-on-straggle deadline); slice "
                   "reclaimed, restart governed by max_failures")))

    # -- lifecycle --------------------------------------------------------------------
    def _worker_config(self, trial: Trial) -> Dict[str, Any]:
        config = dict(trial.config)
        if trial.trial_id in self._slices:
            sl = self._slices[trial.trial_id]
            # Device handles can't cross a process boundary: ship the slice as
            # a virtual (start, size) window; the child's make_mesh tiles its
            # own devices (dist/submesh.py virtual mode).
            from ..dist.submesh import MeshSlice
            config["_slice"] = MeshSlice(sl.start, sl.size, None)
        return config

    def start_trial(self, trial: Trial, checkpoint: Optional[Checkpoint] = None) -> bool:
        if not self.has_resources(trial):
            return False
        try:
            factory = self._resolve_factory(trial.trainable_name)
        except KeyError:
            trial.error = traceback.format_exc()
            trial.set_status(TrialStatus.ERROR)
            return False
        restore_key, restore_iter = None, 0
        if checkpoint is not None:
            try:
                with self._ckpt_lock:
                    # a private snapshot: the child consumes it asynchronously,
                    # so the source may be unpinned/rotated from here on
                    restore_key = self.ckpt.export_copy(checkpoint)
            except Exception:  # noqa: BLE001
                trial.error = traceback.format_exc()
                trial.set_status(TrialStatus.ERROR)
                return False
            restore_iter = checkpoint.training_iteration
        self._acquire_slice(trial)
        try:
            worker = ProcessWorker(
                factory, trial.trial_id, self._worker_config(trial),
                self._spill_dir, checkpoint_freq=self.checkpoint_freq,
                restore_key=restore_key, restore_iteration=restore_iter,
                mp_context=self.mp_context, nice=self.worker_nice,
                trace=self.obs.tracer.enabled)
        except Exception:  # noqa: BLE001 — unpicklable config, spawn failure, ...
            self._release(trial)
            trial.error = traceback.format_exc()
            trial.set_status(TrialStatus.ERROR)
            return False
        # Spawn is asynchronous on purpose: the child's interpreter boot and
        # optional restore overlap across trials; the pump sends the first
        # STEP on READY, and a child that errors during build publishes ERROR
        # into the normal retry path.
        ws = _WorkerHandle(trial, worker, self.clock)
        ws.restore_key = restore_key
        ws.restore_ckpt = checkpoint
        self._workers[trial.trial_id] = ws
        trial.set_status(TrialStatus.RUNNING)
        return True

    def _sync_exchange(self, ws: _WorkerHandle, cmd: tuple, tag: str,
                       timeout: Optional[float] = None) -> Optional[tuple]:
        """Send a command and wait for its reply (runner thread only).

        While the exchange is open, a child failure is routed here (None
        return) instead of the event bus — the caller owns the fallback, and
        the runner must not later apply a stale ERROR to a rebuilt worker.
        """
        # Drain leftovers from an earlier timed-out exchange first: a late
        # reply with the SAME tag (e.g. a slow SAVE's MSG_SAVED arriving
        # after its caller gave up) must never satisfy this exchange — it
        # would hand back a stale checkpoint key and skew every subsequent
        # reply by one.  Only this (runner) thread opens exchanges, so
        # anything queued here predates this call; a DEAD sentinel is the
        # one message that stays meaningful.
        while True:
            try:
                stale = ws.reply_q.get_nowait()
            except queue.Empty:
                break
            if stale[0] == "DEAD":
                return None
            if stale[0] == _w.MSG_SAVED:
                self._discard_stale_saved(stale[1])
        ws.expecting_reply = True
        try:
            if not ws.worker.send(*cmd):
                return None
            return self._await_reply(ws, tag, timeout)
        finally:
            ws.expecting_reply = False

    def _discard_stale_saved(self, key: str) -> None:
        """A timed-out SAVE's payload was spilled but never adopted: delete
        it or it strands a checkpoint-sized file for the life of the spill
        dir.  Safe here because pipe-tier keys are unique per save — the
        cluster tier overrides this for content-addressed keys, which CAN be
        shared with an adopted checkpoint."""
        try:
            self.ckpt.store.delete(key)
        except OSError:
            pass

    def _await_reply(self, ws: _WorkerHandle, tag: str,
                     timeout: Optional[float] = None) -> Optional[tuple]:
        """Wait for a synchronous reply routed by the pump; None on timeout or
        worker death.  Real (monotonic) time on purpose, even under a virtual
        clock: the reply is produced by a real child process whose latency
        virtual time cannot model — and monotonic, not wall, so an NTP step
        can neither strand nor instantly expire the wait."""
        deadline = time.monotonic() + (timeout if timeout is not None else self.reply_timeout)
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return None
            try:
                msg = ws.reply_q.get(timeout=remaining)
            except queue.Empty:
                return None
            if msg[0] == tag:
                return msg
            if msg[0] == "DEAD":
                return None
            # stale reply from an earlier, timed-out exchange: drop it

    def _reap(self, trial: Trial) -> Optional[_WorkerHandle]:
        """Stop (or kill) the worker process and release its resources.

        Unlike the thread tier there is no abandonment branch: a worker that
        ignores STOP is SIGKILLed, so the slice is *always* reclaimed."""
        ws = self._workers.pop(trial.trial_id, None)
        if ws is None:
            return None
        ws.stopping = True
        if not ws.dead and ws.worker.alive():
            ws.worker.send(CMD_STOP)
            if not ws.worker.join(timeout=self.join_timeout):
                ws.worker.kill(join_timeout=self.join_timeout)
        elif ws.worker.alive():
            ws.worker.kill(join_timeout=self.join_timeout)
        ws.dead = True
        ws.worker.close()
        if ws.restore_key:  # child died before consuming its export snapshot
            self.ckpt.store.delete(ws.restore_key)
            ws.restore_key = None
        self._release(trial)
        return ws

    # -- checkpoints ------------------------------------------------------------------
    def _adopt_saved(self, ws: _WorkerHandle, trial: Trial) -> Optional[Checkpoint]:
        """Sync SAVE -> adopt the child-written key -> trial.checkpoint.
        None when the worker didn't reply in time (caller owns the fallback)."""
        rep = self._sync_exchange(ws, (CMD_SAVE,), _w.MSG_SAVED)
        if rep is None:
            return None
        _, key, iteration = rep
        with self._ckpt_lock:
            ckpt = self.ckpt.adopt(trial.trial_id, iteration, key)
        trial.checkpoint = ckpt
        return ckpt

    def save_checkpoint(self, trial: Trial) -> Checkpoint:
        ws = self._workers[trial.trial_id]
        if ws.dead or not ws.ready:
            raise RuntimeError(
                f"cannot checkpoint {trial.trial_id}: worker not serving "
                f"(ready={ws.ready}, dead={ws.dead})")
        ckpt = self._adopt_saved(ws, trial)
        if ckpt is None:
            raise RuntimeError(f"worker for {trial.trial_id} did not SAVE in time")
        return ckpt

    # -- runner-driven transitions ----------------------------------------------------
    def resume_trial(self, trial: Trial) -> None:
        ws = self._workers.get(trial.trial_id)
        if ws is not None and ws.ready and not ws.dead:
            self._kick(ws)

    def trial_idle(self, trial: Trial) -> bool:
        # Unlike the thread tier, a worker mid-step is still resizable: the
        # pipe serializes, so a queued SAVE lands *after* any outstanding
        # STEPs — it is its own drain barrier and no result is ever torn.
        ws = self._workers.get(trial.trial_id)
        return ws is not None and ws.ready and not ws.dead

    def resize_trial(self, trial: Trial, new_devices: int) -> bool:
        """Checkpoint-boundary slice resize over the pipe protocol
        (DESIGN.md §6): sync SAVE (queued behind any outstanding STEPs — the
        pipe is the drain barrier — and adopted so a failed resize restarts
        from *this* state), swap the pool slice on the runner thread, then
        CMD_RESIZE — the child rebuilds the trainable over the new virtual
        window and restores, all inside the warm process.  A child-side
        rebuild failure is non-fatal: the old trainable keeps serving, and
        the pool swap is rolled back to the exact old range.  A SAVE that
        can't drain within reply_timeout aborts the resize (its late reply
        is reaped by the _sync_exchange drain)."""
        ws = self._workers.get(trial.trial_id)
        if (ws is None or ws.dead or not ws.ready
                or self._pool_for(trial) is None
                or new_devices == trial.resources.devices):
            return False
        ckpt = self._adopt_saved(ws, trial)
        if ckpt is None:
            if ws.dead:
                # Child died during the boundary SAVE.  _sync_exchange
                # swallowed the pipe-EOF ERROR (the caller owns the outcome),
                # so surface it here or the trial is stranded RUNNING forever.
                self.bus.publish(TrialEvent(
                    EventType.ERROR, trial.trial_id,
                    error=(f"worker for {trial.trial_id} died during the "
                           "resize boundary SAVE; restart from the last "
                           "checkpoint is governed by max_failures")))
            return False
        key, iteration = ckpt.store_key, ckpt.training_iteration
        try:
            old_res, old_sl, new_sl = self._swap_slice(trial, new_devices)
        except RuntimeError:
            return False
        rep = self._sync_exchange(
            ws, (CMD_RESIZE, self._worker_config(trial), key, iteration),
            _w.MSG_RESIZED, timeout=max(self.reply_timeout, self.spawn_timeout))
        if rep is None:
            # Child died (or hung) mid-resize.  Roll the bookkeeping back to
            # the old range so the retry restarts at the original size, and
            # surface the death as a normal trial ERROR — _sync_exchange
            # swallowed the pipe-EOF event, so publish it here.
            ws.dead = True
            self._unswap_slice(trial, old_res, old_sl, new_sl)
            self.bus.publish(TrialEvent(
                EventType.ERROR, trial.trial_id,
                error=(f"worker for {trial.trial_id} died during RESIZE "
                       f"({old_sl.size} -> {new_devices} devices); restart "
                       "from the boundary checkpoint is governed by "
                       "max_failures")))
            return False
        if not rep[1]:  # child kept the old trainable; fall back to old slice
            self._unswap_slice(trial, old_res, old_sl, new_sl)
            return False
        # No credit top-up: the window maintains itself.  STEPs sent = initial
        # k + one per consumed CONTINUE, so at this boundary (outstanding 0)
        # exactly k results sit un-consumed, and each of their resumes will
        # kick one STEP — granting more here would inflate the window past k.
        return True

    def pause_trial(self, trial: Trial) -> None:
        ws = self._workers.get(trial.trial_id)
        if ws is not None:
            if ws.ready and not ws.dead and not ws.in_step:
                try:
                    self.save_checkpoint(trial)
                except Exception:  # noqa: BLE001 — fall back to last periodic ckpt
                    pass
            self._reap(trial)
        trial.set_status(TrialStatus.PAUSED)

    def stop_trial(self, trial: Trial, error: Optional[str] = None) -> None:
        self._reap(trial)
        if error:
            trial.error = error
            trial.set_status(TrialStatus.ERROR)
        else:
            trial.set_status(TrialStatus.TERMINATED)

    def requeue_trial(self, trial: Trial) -> None:
        """Tear down a failed (possibly killed) worker, keeping the trial
        restartable from its last checkpoint.  This is where a straggler's
        slice actually returns to the SlicePool — before the runner's launch
        loop runs again, so a waiting trial can take it within one step."""
        self._reap(trial)
        self._set_requeue_status(trial)

    def restart_trial_with_config(
        self, trial: Trial, checkpoint: Checkpoint, new_config: Dict[str, Any]
    ) -> None:
        """PBT exploit: in-place RESET_CONFIG + RESTORE when the child
        cooperates, full process rebuild otherwise."""
        trial.config = dict(new_config)
        ws = self._workers.get(trial.trial_id)
        if ws is not None:
            if ws.ready and not ws.dead and not ws.in_step:
                try:
                    with self._ckpt_lock:
                        ws.restore_key = self.ckpt.export_copy(checkpoint)
                except Exception:  # noqa: BLE001
                    trial.error = traceback.format_exc()
                    trial.set_status(TrialStatus.ERROR)
                    self._reap(trial)
                    return
                rep = self._sync_exchange(
                    ws, (CMD_RESET_CONFIG, dict(new_config)), _w.MSG_RESET)
                if rep is not None and rep[1]:
                    restored = self._sync_exchange(
                        ws, (CMD_RESTORE, ws.restore_key,
                             checkpoint.training_iteration), _w.MSG_RESTORED)
                    if restored is not None:
                        ws.restore_key = None  # consumed (deleted) by the child
                        checkpoint.pinned = False
                        self._kick(ws)
                        return
            self._reap(trial)
            trial.set_status(TrialStatus.PAUSED)
        # Full rebuild: fresh process restoring the donor state before READY.
        if not self.has_resources(trial):
            trial.checkpoint = checkpoint  # re-queue; next launch restores donor
            trial.set_status(TrialStatus.PAUSED)
            return
        self.start_trial(trial, checkpoint=checkpoint)

    # -- introspection ----------------------------------------------------------------
    def worker_pid(self, trial_id: str) -> Optional[int]:
        ws = self._workers.get(trial_id)
        return ws.worker.pid if ws is not None else None

    def shutdown(self) -> None:
        self._shutdown_evt.set()
        self._pump_shutdown.set()
        for trial_id in list(self._workers):
            self._reap(self._workers[trial_id].trial)
        if self._pump_thread.is_alive():
            self._pump_thread.join(timeout=2.0)  # real thread, real join
        if self._monitor_thread is not None and self._monitor_thread.is_alive():
            self.clock.join_thread(self._monitor_thread, timeout=2.0)
        if self._owns_spill_dir:
            # We mkdtemp'd this dir (the user configured no spill): the
            # checkpoint payloads in it die with the experiment.
            import shutil
            shutil.rmtree(self._spill_dir, ignore_errors=True)
