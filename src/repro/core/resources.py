"""Resource requests and accounting.

The paper requires "the ability to handle resource requirements of arbitrary
user code" — each trial declares the resources it needs (there: CPUs/GPUs via
Ray; here: host CPUs plus a *device slice* of the TPU mesh).  The executor's
``SlicePool`` (dist/submesh.py) turns ``devices`` into an actual sub-mesh.
"""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["Resources", "ResourceAccountant"]


@dataclass(frozen=True)
class Resources:
    cpu: float = 1.0
    devices: int = 1  # number of mesh devices (chips) the trial wants

    def __post_init__(self):
        if self.cpu < 0 or self.devices < 0:
            raise ValueError(f"negative resource request: {self}")


class ResourceAccountant:
    """Tracks committed vs available resources; never goes negative."""

    def __init__(self, total_cpu: float, total_devices: int):
        self.total = Resources(cpu=total_cpu, devices=total_devices)
        self._used_cpu = 0.0
        self._used_devices = 0

    @property
    def available(self) -> Resources:
        return Resources(
            cpu=self.total.cpu - self._used_cpu,
            devices=self.total.devices - self._used_devices,
        )

    def has_room(self, req: Resources) -> bool:
        return (
            self._used_cpu + req.cpu <= self.total.cpu + 1e-9
            and self._used_devices + req.devices <= self.total.devices
        )

    def acquire(self, req: Resources) -> None:
        if not self.has_room(req):
            raise RuntimeError(f"over-commit: {req} on top of used "
                               f"({self._used_cpu} cpu, {self._used_devices} dev)")
        self._used_cpu += req.cpu
        self._used_devices += req.devices

    def release(self, req: Resources) -> None:
        self._used_cpu -= req.cpu
        self._used_devices -= req.devices
        if self._used_cpu < -1e-9 or self._used_devices < 0:
            raise RuntimeError("resource accounting went negative")
        self._used_cpu = max(self._used_cpu, 0.0)
