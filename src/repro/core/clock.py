"""Pluggable time — the clock seam under every timing site (DESIGN.md §7).

Heartbeat timeouts, straggler deadlines, logger flush throttling and elastic
boundaries all used to read ``time.time()`` directly, which welded the test
suite to real wall-clock: exercising a 60s heartbeat meant *waiting* 60s.
This module makes time an injected dependency instead:

- ``Clock`` — the protocol.  ``time()`` is the timestamp axis (epoch-like,
  what event records and loggers show); ``monotonic()`` is the deadline axis
  (never jumps backwards, what timeout arithmetic must use); ``sleep``/
  ``wait_for`` and the factory methods (``event()``/``semaphore()``) are the
  blocking primitives executors park on.
- ``WallClock`` — production: thin veneer over ``time``/``threading``.
- ``VirtualClock`` — a cooperative deterministic scheduler for tests: every
  participating thread registers, all blocking goes through the clock, and
  virtual time advances **only when every registered thread is parked**, to
  the earliest pending deadline.  A 60s heartbeat then fires in microseconds
  of real time, in a deterministic order (repro.testing builds on this).

Cooperative contract for ``VirtualClock`` (violations deadlock or mis-time):
registered threads may block *only* through clock primitives — ``sleep``,
``wait_for``, ``queue_get``, ``join_thread``, and the acquire/wait methods of
objects from ``clock.event()``/``clock.semaphore()``.  A registered thread
that blocks on a bare OS primitive while others sleep stalls the virtual
epoch (time cannot advance — the clock believes the thread is runnable).
State changes made *outside* clock objects that could unblock a waiter must
be announced with ``kick()``.
"""
from __future__ import annotations

import contextlib
import math as _math
import queue as _queue
import threading
import time as _time
from typing import Any, Callable, Dict, Iterator, Optional, Set

__all__ = ["Clock", "WallClock", "VirtualClock", "get_default_clock",
           "set_default_clock", "use_clock"]


class Clock:
    """Time + blocking-primitive provider.  Executors, the event bus, loggers
    and trials read all time through one of these."""

    # -- time axes ------------------------------------------------------------------
    def time(self) -> float:
        """Timestamp axis (epoch-like; event records, logger throttling)."""
        raise NotImplementedError

    def monotonic(self) -> float:
        """Deadline axis: never jumps with wall-clock adjustments.  ALL
        timeout arithmetic (``deadline = monotonic() + timeout``) must use
        this, never ``time()``."""
        raise NotImplementedError

    def sleep(self, seconds: float) -> None:
        raise NotImplementedError

    def sleep_until(self, t: float) -> None:
        """Park until the timestamp axis reaches ``t``.  The default delegates
        to relative ``sleep``; VirtualClock overrides it to land on ``t``
        *bit-exactly* — ``now + (t - now)`` re-associates the float sum, and
        resume phase targets (DESIGN.md §12) cannot afford the ulp."""
        delay = t - self.time()
        if delay > 0:
            self.sleep(delay)

    # -- blocking primitives ---------------------------------------------------------
    def event(self) -> Any:
        """A ``threading.Event``-compatible object whose ``wait`` parks
        through this clock."""
        raise NotImplementedError

    def semaphore(self, value: int = 1) -> Any:
        """A ``threading.Semaphore``-compatible object whose ``acquire``
        parks through this clock."""
        raise NotImplementedError

    def queue_get(self, q: "_queue.Queue", timeout: float) -> Optional[Any]:
        """Next item from ``q`` or None after ``timeout``; producers that do
        not go through clock objects must ``kick(q)`` after putting."""
        raise NotImplementedError

    def join_thread(self, thread: threading.Thread,
                    timeout: Optional[float] = None) -> bool:
        """Wait for ``thread`` to exit; False on timeout."""
        raise NotImplementedError

    def wait_for(self, predicate: Callable[[], bool],
                 timeout: Optional[float] = None,
                 channel: Any = None) -> bool:
        """Park until ``predicate()`` is true (True) or ``timeout`` elapses
        (False).  ``channel`` scopes wakeups: the waiter is re-checked when
        that channel is kicked (plus on any broadcast ``kick()``)."""
        raise NotImplementedError

    def kick(self, channel: Any = None) -> None:
        """Announce an out-of-band state change to parked waiters: wake the
        waiters on ``channel``, or every predicate waiter when None.  No-op
        on the wall clock, where the OS delivers wakeups."""

    # -- thread participation (virtual determinism bookkeeping) ------------------------
    def register_thread(self) -> None:
        """Mark the calling thread as a participant whose runnability gates
        virtual-time advancement.  No-op on the wall clock."""

    def unregister_thread(self) -> None:
        """Participant is exiting; it no longer gates advancement."""

    @contextlib.contextmanager
    def running(self) -> Iterator[None]:
        """Wrap a participating thread's body: register on entry, unregister
        on exit (even via exception)."""
        self.register_thread()
        try:
            yield
        finally:
            self.unregister_thread()


class WallClock(Clock):
    """Production time: defer everything to ``time``/``threading``."""

    def time(self) -> float:
        return _time.time()

    def monotonic(self) -> float:
        return _time.monotonic()

    def sleep(self, seconds: float) -> None:
        _time.sleep(seconds)

    def event(self) -> threading.Event:
        return threading.Event()

    def semaphore(self, value: int = 1) -> threading.Semaphore:
        return threading.Semaphore(value)

    def queue_get(self, q: "_queue.Queue", timeout: float) -> Optional[Any]:
        try:
            return q.get(timeout=timeout)
        except _queue.Empty:
            return None

    def join_thread(self, thread: threading.Thread,
                    timeout: Optional[float] = None) -> bool:
        thread.join(timeout)
        return not thread.is_alive()

    def wait_for(self, predicate: Callable[[], bool],
                 timeout: Optional[float] = None,
                 channel: Any = None) -> bool:
        # Rarely used on the wall clock (real code parks on events/queues);
        # poll coarsely as a fallback so misuse degrades instead of spinning.
        deadline = None if timeout is None else _time.monotonic() + timeout
        while True:
            if predicate():
                return True
            if deadline is not None and _time.monotonic() >= deadline:
                return False
            _time.sleep(0.01)


class _VirtualEvent:
    """``threading.Event`` veneer over a VirtualClock (waiters channel on the
    event object itself, so ``set`` wakes exactly them)."""

    def __init__(self, clock: "VirtualClock"):
        self._clock = clock
        self._flag = False

    def is_set(self) -> bool:
        return self._flag

    def set(self) -> None:
        with self._clock._lock:
            self._flag = True
            self._clock._notify_channel(self)

    def clear(self) -> None:
        with self._clock._lock:
            self._flag = False

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._clock.wait_for(lambda: self._flag, timeout, channel=self)


class _VirtualSemaphore:
    """``threading.Semaphore`` veneer over a VirtualClock (waiters channel on
    the semaphore object, so ``release`` wakes exactly them)."""

    def __init__(self, clock: "VirtualClock", value: int):
        self._clock = clock
        self._value = value

    def _try_acquire(self) -> bool:
        # only ever evaluated under the clock lock (wait_for predicate)
        if self._value > 0:
            self._value -= 1
            return True
        return False

    def acquire(self, blocking: bool = True,
                timeout: Optional[float] = None) -> bool:
        if not blocking:
            with self._clock._lock:
                return self._try_acquire()
        return self._clock.wait_for(self._try_acquire, timeout, channel=self)

    def release(self, n: int = 1) -> None:
        with self._clock._lock:
            self._value += n
            self._clock._notify_channel(self)


class _Waiter:
    """One parked thread: its private condition (targeted wakeups), absolute
    virtual deadline, wake channel, and whether a wakeup is in flight."""

    __slots__ = ("cv", "deadline", "channel", "is_sleep", "woken")

    def __init__(self, cv: threading.Condition, deadline: Optional[float],
                 channel: Any, is_sleep: bool):
        self.cv = cv
        self.deadline = deadline
        self.channel = channel
        self.is_sleep = is_sleep
        self.woken = False


class VirtualClock(Clock):
    """Deterministic cooperative virtual time.

    One lock serializes all clock state; each parked thread waits on its own
    condition over that lock, so wakeups are *targeted*: a semaphore release
    wakes that semaphore's waiters, an advance wakes only the sleepers whose
    deadline arrived, a ``kick(channel)`` wakes that channel.  Advancement —
    moving ``_now`` to the earliest pending deadline — happens only when
    every registered thread is parked AND none has a wakeup in flight (a
    notified-but-not-yet-scheduled thread is runnable; advancing "around" it
    would, e.g., expire a join timeout against a worker that was about to
    exit).  Unregistered threads may park too — they are woken normally but
    never gate advancement (the process tier's pump thread, which blocks on
    real child pipes, stays unregistered).

    ``time()`` reports ``epoch + now`` so timestamps look wall-ish in logs;
    ``monotonic()`` reports raw virtual seconds.  If every registered thread
    parks with no deadline anywhere, no event can ever fire again — that is a
    harness deadlock and raises RuntimeError in the last thread to park.
    """

    def __init__(self, start: float = 0.0, epoch: float = 1_000_000_000.0,
                 register_creator: bool = True):
        self._lock = threading.Lock()
        self._now = float(start)
        self._epoch = float(epoch)
        self._threads: Set[int] = set()
        self._finished: Set[int] = set()
        self._waiting: Dict[int, _Waiter] = {}
        self._cvs: Dict[int, threading.Condition] = {}  # per-thread, reused
        self.n_advances = 0
        if register_creator:
            self._threads.add(threading.get_ident())

    # -- time axes ------------------------------------------------------------------
    def time(self) -> float:
        with self._lock:
            return self._epoch + self._now

    def monotonic(self) -> float:
        with self._lock:
            return self._now

    # -- participation ----------------------------------------------------------------
    def register_thread(self) -> None:
        with self._lock:
            ident = threading.get_ident()
            self._threads.add(ident)
            self._finished.discard(ident)  # OS thread idents get recycled

    def unregister_thread(self) -> None:
        with self._lock:
            ident = threading.get_ident()
            self._threads.discard(ident)
            self._finished.add(ident)
            # Wake joiners (join_thread channels on the ident), then check
            # whether the *remaining* participants are all parked — this
            # thread leaving may be the event that unblocks time.
            self._notify_channel(ident)
            self._maybe_advance()

    # -- wakeup plumbing (caller holds _lock) ------------------------------------------
    def _wake(self, ident: int, waiter: _Waiter) -> None:
        if not waiter.woken:
            waiter.woken = True
            waiter.cv.notify()

    def _notify_channel(self, channel: Any) -> None:
        for ident, waiter in self._waiting.items():
            # == not `is`: join channels are thread idents (equal ints need
            # not be the same object); all other channels are clock-owned
            # objects whose equality IS identity.
            if waiter.channel is channel or waiter.channel == channel:
                self._wake(ident, waiter)

    def _notify_all_predicates(self) -> None:
        for ident, waiter in self._waiting.items():
            if not waiter.is_sleep:
                self._wake(ident, waiter)

    def kick(self, channel: Any = None) -> None:
        with self._lock:
            if channel is None:
                self._notify_all_predicates()
            else:
                self._notify_channel(channel)

    # -- core park/advance machinery ---------------------------------------------------
    def _maybe_advance(self) -> None:
        """Caller holds ``_lock``.  If every registered thread is parked with
        no wakeup in flight, advance to the earliest deadline and wake the
        sleepers/waiters it expires."""
        if not self._threads:
            return
        for ident in self._threads:
            waiter = self._waiting.get(ident)
            if waiter is None or waiter.woken:
                return  # runnable (or about to be): time must hold still
        deadlines = [w.deadline for w in self._waiting.values()
                     if w.deadline is not None]
        if not deadlines:
            raise RuntimeError(
                "VirtualClock deadlock: every registered thread is parked "
                "with no pending deadline — no event can ever fire.  A "
                "non-clock blocking call or a missing kick() is the usual "
                f"cause (registered={len(self._threads)}, "
                f"parked={len(self._waiting)}, now={self._now:.3f})")
        nxt = min(deadlines)
        if nxt > self._now:
            # Quantize the advance to the timestamp axis: pick the smallest
            # ``now' >= nxt`` for which ``epoch + now'`` is exactly
            # representable.  Timestamps (``time()``) then round-trip losslessly
            # through journals, so a resumed run re-entering the timeline via
            # ``sleep_until(journaled_t)`` lands on the *bit-identical* clock
            # state the original process had (DESIGN.md §12).
            tq = self._epoch + nxt
            q = tq - self._epoch  # exact: Sterbenz (operands within 2x)
            if q < nxt:
                q = _math.nextafter(tq, _math.inf) - self._epoch
            self._now = q
            self.n_advances += 1
        for ident, waiter in self._waiting.items():
            if waiter.deadline is not None and waiter.deadline <= self._now:
                self._wake(ident, waiter)

    def _park_cv(self, ident: int) -> threading.Condition:
        cv = self._cvs.get(ident)
        if cv is None:
            cv = self._cvs[ident] = threading.Condition(self._lock)
        return cv

    def wait_for(self, predicate: Optional[Callable[[], bool]],
                 timeout: Optional[float] = None,
                 channel: Any = None) -> bool:
        """``predicate=None`` is a pure sleep: immune to kicks, woken only by
        time reaching its deadline."""
        me = threading.get_ident()
        with self._lock:
            cv = self._park_cv(me)
            deadline = None if timeout is None else self._now + max(0.0, timeout)
            while True:
                if predicate is not None and predicate():
                    return True
                if deadline is not None and self._now >= deadline:
                    return False
                waiter = _Waiter(cv, deadline, channel, predicate is None)
                self._waiting[me] = waiter
                try:
                    self._maybe_advance()
                    if waiter.woken:
                        continue  # the advance expired/woke us: re-check now
                    cv.wait()
                finally:
                    self._waiting.pop(me, None)

    def sleep(self, seconds: float) -> None:
        self.wait_for(None, timeout=max(0.0, seconds))

    def sleep_until(self, t: float) -> None:
        # A pure sleep whose deadline is the absolute target itself, not
        # now + delta: the advance then sets _now to exactly t - epoch.
        me = threading.get_ident()
        with self._lock:
            cv = self._park_cv(me)
            deadline = t - self._epoch
            while self._now < deadline:
                waiter = _Waiter(cv, deadline, None, True)
                self._waiting[me] = waiter
                try:
                    self._maybe_advance()
                    if waiter.woken:
                        continue
                    cv.wait()
                finally:
                    self._waiting.pop(me, None)

    # -- blocking primitives -----------------------------------------------------------
    def event(self) -> _VirtualEvent:
        return _VirtualEvent(self)

    def semaphore(self, value: int = 1) -> _VirtualSemaphore:
        return _VirtualSemaphore(self, value)

    def queue_get(self, q: "_queue.Queue", timeout: float) -> Optional[Any]:
        got = []

        def pred() -> bool:
            if got:
                return True
            try:
                got.append(q.get_nowait())
                return True
            except _queue.Empty:
                return False

        if self.wait_for(pred, timeout, channel=q):
            return got[0]
        return None

    def join_thread(self, thread: threading.Thread,
                    timeout: Optional[float] = None) -> bool:
        ident = thread.ident

        def exited() -> bool:
            return not thread.is_alive() or ident in self._finished

        if not self.wait_for(exited, timeout, channel=ident):
            return False
        # The participant already unregistered (its last act); the OS thread
        # has at most a few instructions left — settle it for real.
        thread.join()
        return True

    def debug_string(self) -> str:
        with self._lock:
            return (f"VirtualClock(now={self._now:.3f}, "
                    f"registered={len(self._threads)}, "
                    f"parked={len(self._waiting)}, advances={self.n_advances})")


# -- default clock ---------------------------------------------------------------------
# Construction-time seam: components take ``clock=None`` and fall back to this
# module default, so a test can place an entire stack (executors, bus, trials,
# loggers) on virtual time with one ``use_clock(...)`` block.
_DEFAULT = WallClock()
_default_clock: Clock = _DEFAULT


def get_default_clock() -> Clock:
    return _default_clock


def set_default_clock(clock: Optional[Clock]) -> Clock:
    """Install ``clock`` (None restores the wall clock); returns the previous
    default so callers can put it back."""
    global _default_clock
    prev = _default_clock
    _default_clock = clock if clock is not None else _DEFAULT
    return prev


@contextlib.contextmanager
def use_clock(clock: Clock) -> Iterator[Clock]:
    """Scoped default-clock override (the repro.testing harness entry)."""
    prev = set_default_clock(clock)
    try:
        yield clock
    finally:
        set_default_clock(prev)
