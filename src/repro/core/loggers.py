"""Result loggers — the paper's "monitoring and visualization of trial progress".

Console progress table (periodic, like Tune's reporter), per-trial CSV, and an
experiment-level JSONL event log (the TensorBoard-integration analogue: any
external tool can tail the JSONL).
"""
from __future__ import annotations

import csv
import json
import os
import sys
from typing import Any, Dict, List, Optional, TextIO

from .clock import Clock, get_default_clock
from .trial import Result, Trial

__all__ = ["Logger", "ConsoleLogger", "CSVLogger", "JSONLLogger",
           "CompositeLogger", "LiveReporter"]


class Logger:
    def on_result(self, trial: Trial, result: Result) -> None:
        pass

    def on_event(self, trial: Trial, event: Any) -> None:
        """Non-result TrialEvents (CHECKPOINTED / HEARTBEAT_MISSED / RESTARTED)."""

    def on_trial_complete(self, trial: Trial) -> None:
        pass

    def on_experiment_end(self, trials: List[Trial]) -> None:
        pass

    def close(self) -> None:
        pass


class ConsoleLogger(Logger):
    def __init__(self, interval_s: float = 5.0, stream: Optional[TextIO] = None,
                 verbose: bool = True, clock: Optional[Clock] = None,
                 obs: Optional[Any] = None):
        self.interval_s = interval_s
        self.stream = stream or sys.stdout
        self.verbose = verbose
        self.clock = clock or get_default_clock()
        self.obs = obs  # repro.obs.Observability; enables the status table
        self._last = 0.0
        self._n_results = 0
        self._pending: Optional[tuple] = None  # last throttled (trial_id, result)

    def _emit(self, trial_id: str, result: Result) -> None:
        metrics = ", ".join(
            f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
            for k, v in list(result.metrics.items())[:4]
        )
        print(f"[tune] {trial_id} iter={result.training_iteration} {metrics}",
              file=self.stream)

    def on_result(self, trial: Trial, result: Result) -> None:
        self._n_results += 1
        if not self.verbose:
            return
        # Flush throttling reads the injected clock, so a virtual-time run
        # prints on virtual seconds (and tests can drive the throttle
        # deterministically) instead of real-time wall gaps.
        now = self.clock.time()
        if now - self._last >= self.interval_s:
            self._last = now
            self._pending = None
            self._emit(trial.trial_id, result)
        else:
            # Throttled: remember it so a final flush() can still report the
            # run's last status instead of silently dropping it.
            self._pending = (trial.trial_id, result)

    def flush(self) -> None:
        """Emit the last throttled result (and the metrics status table when
        an Observability bundle is attached) even inside the throttle window.
        The runner calls this at experiment end — the final status of a run
        must never be lost to the throttle."""
        if not self.verbose:
            return
        if self._pending is not None:
            trial_id, result = self._pending
            self._pending = None
            self._last = self.clock.time()
            self._emit(trial_id, result)
        if self.obs is not None and self.obs.metrics is not None:
            for line in self.status_table().splitlines():
                print(line, file=self.stream)

    def status_table(self) -> str:
        """Compact control-plane status table from the attached metrics
        registry (DESIGN.md §8).  Empty string when no registry is attached."""
        if self.obs is None or self.obs.metrics is None:
            return ""
        snap = self.obs.metrics.snapshot()

        def c(name: str) -> Any:
            v = snap.get(name, 0)
            return v if not isinstance(v, dict) else v.get("count", 0)

        def mean_us(name: str) -> str:
            v = snap.get(name)
            if not isinstance(v, dict) or not v.get("count"):
                return "-"
            return f"{v['mean']:.1f}us"

        return "\n".join([
            "[tune] --- control-plane status ---",
            f"[tune] events: results={c('events.result')} "
            f"errors={c('events.error')} restarts={c('trials.restarts')} "
            f"kills={c('events.killed')} resizes={c('trials.resized')}",
            f"[tune] bus: published={c('bus.published')} depth={c('bus.depth')} "
            f"fanin={mean_us('bus.fanin_us')}",
            f"[tune] sched: choose={mean_us('sched.choose_us')} "
            f"decision={mean_us('sched.decision_us')}",
            f"[tune] pool: util={snap.get('pool.utilization', 0)} "
            f"fragments={snap.get('pool.fragments', 0)} "
            f"acquire={mean_us('pool.acquire_us')}",
            f"[tune] ckpt: saves={c('ckpt.save_us')} "
            f"save={mean_us('ckpt.save_us')} "
            f"restore={mean_us('ckpt.restore_us')}",
        ])

    def on_event(self, trial: Trial, event: Any) -> None:
        if not self.verbose:
            return
        kind = getattr(event, "type", None)
        kind = getattr(kind, "value", str(kind))
        if kind == "HEARTBEAT_MISSED":
            print(f"[tune] WARNING {trial.trial_id} straggling: no progress for "
                  f"{event.info.get('stalled_s', '?')}s", file=self.stream)
        elif kind == "KILLED":
            print(f"[tune] WARNING {trial.trial_id} straggler killed "
                  f"(pid={event.info.get('pid', '?')}, stalled "
                  f"{event.info.get('stalled_s', '?')}s > deadline "
                  f"{event.info.get('deadline_s', '?')}s); slice reclaimed",
                  file=self.stream)
        elif kind == "RESTARTED":
            where = ("last checkpoint" if event.checkpoint is not None else "scratch")
            print(f"[tune] {trial.trial_id} failed "
                  f"({event.info.get('num_failures', '?')}/"
                  f"{event.info.get('max_failures', '?')}); restarting from {where}",
                  file=self.stream)
        elif kind == "RESIZED":
            info = event.info
            print(f"[tune] {trial.trial_id} slice resized "
                  f"{info.get('from_devices', '?')} -> {info.get('to_devices', '?')} "
                  f"devices ({info.get('policy', '?')}; pool "
                  f"{info.get('utilization', 0) * 100:.0f}% used, "
                  f"{info.get('holes', '?')} holes)", file=self.stream)
        elif kind == "RESIZE_FAILED":
            info = event.info
            print(f"[tune] WARNING {trial.trial_id} resize "
                  f"{info.get('from_devices', '?')} -> {info.get('to_devices', '?')} "
                  f"failed; trial falls back to its old slice "
                  f"(largest free block {info.get('largest_free_block', '?')})",
                  file=self.stream)
        elif kind == "CREDITS":
            info = event.info
            print(f"[tune] {trial.trial_id} lookahead credits: "
                  f"{info.get('granted', '?')} granted "
                  f"(requested {info.get('requested', '?')}, scheduler decision "
                  f"interval {info.get('decision_interval', '?')})",
                  file=self.stream)

    def on_experiment_end(self, trials: List[Trial]) -> None:
        self.flush()  # always surface the run's final status (satellite fix)
        if not self.verbose:
            return
        from .trial import TrialStatus

        by_status: Dict[str, int] = {}
        for t in trials:
            by_status[t.status.value] = by_status.get(t.status.value, 0) + 1
        print(f"[tune] experiment done: {len(trials)} trials, "
              f"{self._n_results} results, status={by_status}", file=self.stream)


class LiveReporter(Logger):
    """The paper's live trial table (§"monitoring of trial progress").

    Renders a status table of every trial — status / iteration / last and
    best metric / slice devices / restarts — re-drawn at most once per
    ``interval_s`` on the injected clock, plus one unthrottled final render
    at experiment end.  Everything printed is a pure function of trial state
    and virtual timestamps, so a VirtualClock run renders byte-identically
    across repeats (DESIGN.md §9); rendering cost is bounded by ``max_rows``
    (in-flight trials take precedence, finished ones fill the remainder).
    """

    def __init__(self, metric: Optional[str] = None, interval_s: float = 5.0,
                 stream: Optional[TextIO] = None, clock: Optional[Clock] = None,
                 max_rows: int = 24):
        self.metric = metric
        self.interval_s = interval_s
        self.stream = stream or sys.stdout
        self.clock = clock or get_default_clock()
        self.max_rows = max_rows
        self._trials: Dict[str, Trial] = {}
        self._last = None  # None = never rendered (first result renders)
        self._dirty = False

    # -- tracking ---------------------------------------------------------------
    def _track(self, trial: Trial) -> None:
        self._trials[trial.trial_id] = trial
        self._dirty = True

    def on_result(self, trial: Trial, result: Result) -> None:
        self._track(trial)
        self._maybe_render()

    def on_event(self, trial: Trial, event: Any) -> None:
        self._track(trial)
        self._maybe_render()

    def on_trial_complete(self, trial: Trial) -> None:
        self._track(trial)
        self._maybe_render()

    def on_experiment_end(self, trials: List[Trial]) -> None:
        for t in trials:
            self._trials[t.trial_id] = t
        self.render(final=True)

    def _maybe_render(self) -> None:
        now = self.clock.time()
        if self._last is not None and now - self._last < self.interval_s:
            return
        self._last = now
        self.render()

    # -- rendering ---------------------------------------------------------------
    def _metric_name(self) -> Optional[str]:
        if self.metric is not None:
            return self.metric
        for t in self._trials.values():
            if t.last_result is not None and t.last_result.metrics:
                return next(iter(t.last_result.metrics))
        return None

    def _row(self, t: Trial, metric: Optional[str]) -> List[str]:
        last = best = "-"
        if metric is not None and t.last_result is not None \
                and metric in t.last_result.metrics:
            last = f"{t.last_result.value(metric):.4g}"
            bv = t.best_value(metric, "min")  # display-only; both shown
            hv = t.best_value(metric, "max")
            best = f"{bv:.4g}/{hv:.4g}" if bv != hv else f"{bv:.4g}"
        prof = ""
        if t.profile:
            prof = str(t.profile.get("dominant", ""))
        return [
            t.trial_id, t.status.value, str(t.training_iteration),
            last, best, str(t.resources.devices), str(t.num_failures), prof,
        ]

    def render(self, final: bool = False) -> None:
        if not self._dirty and not final:
            return
        self._dirty = False
        metric = self._metric_name()
        by_status: Dict[str, int] = {}
        for t in self._trials.values():
            by_status[t.status.value] = by_status.get(t.status.value, 0) + 1
        counts = " ".join(f"{k}:{v}" for k, v in sorted(by_status.items()))
        head = ["trial", "status", "iter",
                metric or "metric", "best(min/max)", "dev", "fails", "profile"]
        # In-flight trials first (the table is about progress), then finished
        # ones, both in id order; cap at max_rows so 10^4-trial sweeps stay
        # renderable.
        live = sorted((t for t in self._trials.values()
                       if not t.status.is_finished()), key=lambda t: t.trial_id)
        done = sorted((t for t in self._trials.values()
                       if t.status.is_finished()), key=lambda t: t.trial_id)
        shown = (live + done)[: self.max_rows]
        rows = [self._row(t, metric) for t in shown]
        widths = [max(len(head[i]), *(len(r[i]) for r in rows)) if rows
                  else len(head[i]) for i in range(len(head))]
        out = [f"== trials: {len(self._trials)} ({counts}) =="]
        out.append("  ".join(h.ljust(widths[i]) for i, h in enumerate(head)))
        for r in rows:
            out.append("  ".join(c.ljust(widths[i]) for i, c in enumerate(r)))
        hidden = len(self._trials) - len(shown)
        if hidden > 0:
            out.append(f".. {hidden} more trial(s) not shown")
        print("\n".join(out), file=self.stream)


class CSVLogger(Logger):
    def __init__(self, dir: str):
        self.dir = dir
        self._writers: Dict[str, tuple] = {}

    def on_result(self, trial: Trial, result: Result) -> None:
        if trial.trial_id not in self._writers:
            os.makedirs(self.dir, exist_ok=True)
            f = open(os.path.join(self.dir, f"{trial.trial_id}.csv"), "w", newline="")
            fields = ["training_iteration", "timestamp"] + sorted(result.metrics)
            w = csv.DictWriter(f, fieldnames=fields, extrasaction="ignore")
            w.writeheader()
            self._writers[trial.trial_id] = (f, w)
        f, w = self._writers[trial.trial_id]
        row = {"training_iteration": result.training_iteration, "timestamp": result.timestamp}
        row.update({k: v for k, v in result.metrics.items()})
        w.writerow(row)
        f.flush()  # a crashed run must not lose the tail of the metrics log

    def close(self) -> None:
        for f, _ in self._writers.values():
            f.close()
        self._writers.clear()


class JSONLLogger(Logger):
    """Experiment-level JSONL event log.

    The stream opens with a ``run_header`` record carrying the schema version,
    a run id, the clock type, and the executor tier, so a detached reader can
    interpret the stream without the producing process.  Readers must stay
    unknown-field (and unknown-record) tolerant: filter on ``event`` and
    ignore keys you don't know — that is what keeps pre-header readers of the
    v1 stream working against v2 files, and v2 readers working against v3
    (which adds ``decision`` records and the ``decisions`` capability flag).
    """

    SCHEMA_VERSION = 3

    def __init__(self, path: str, clock: Optional[Clock] = None,
                 run_id: Optional[str] = None, executor: Optional[str] = None,
                 decisions: bool = True, resumed: bool = False,
                 initial_records: int = 0):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self.clock = clock or get_default_clock()
        t0 = self.clock.time()
        self.run_id = run_id or f"run-{int(t0)}-{os.getpid()}"
        # ``n_records`` counts data records (the run_header excluded): it is
        # the watermark the SearchStateSnapshotter stamps into snapshots so
        # resume knows exactly which journal prefix the saved search state
        # has already been fed.  A resumed run appends to the existing
        # journal and starts the counter at the surviving record count.
        self.n_records = int(initial_records)
        self.f = open(path, "a" if resumed else "w")
        header = {
            "event": "run_header",
            "schema_version": self.SCHEMA_VERSION,
            "run_id": self.run_id,
            "clock": type(self.clock).__name__,
            "executor": executor,
            "decisions": bool(decisions),
            "t": t0,
        }
        if resumed:
            # Readers keep the first header and skip later ones, so a
            # resumed journal parses as one continuous run.
            header["resumed"] = True
        self.f.write(json.dumps(header) + "\n")
        self.f.flush()

    def on_result(self, trial: Trial, result: Result) -> None:
        self.n_records += 1
        self.f.write(json.dumps({
            "event": "result",
            "trial_id": trial.trial_id,
            "iteration": result.training_iteration,
            "config": {k: v for k, v in trial.config.items()
                       if isinstance(v, (int, float, str, bool, type(None)))},
            "metrics": {k: v for k, v in result.metrics.items()
                        if isinstance(v, (int, float, str, bool, type(None)))},
            "t": result.timestamp,
        }) + "\n")
        self.f.flush()  # a crashed run must not lose the tail of the event log

    def on_event(self, trial: Trial, event: Any) -> None:
        kind = getattr(event, "type", None)
        # Events that never crossed a bus (runner-side RESTARTED, the
        # broker's CREDITS/RESIZED records) arrive unstamped: fall back to
        # this logger's clock so the JSONL time axis stays consistent.
        ts = getattr(event, "timestamp", None)
        if ts is None:
            ts = self.clock.time()
        self.n_records += 1
        self.f.write(json.dumps({
            "event": getattr(kind, "value", str(kind)).lower(),
            "trial_id": trial.trial_id,
            "seq": getattr(event, "seq", -1),
            "info": getattr(event, "info", {}),
            "t": ts,
        }) + "\n")
        self.f.flush()

    def on_trial_complete(self, trial: Trial) -> None:
        self.n_records += 1
        self.f.write(json.dumps({
            "event": "complete", "trial_id": trial.trial_id,
            "status": trial.status.value, "iterations": trial.training_iteration,
        }) + "\n")
        self.f.flush()

    def close(self) -> None:
        self.f.close()


class CompositeLogger(Logger):
    def __init__(self, loggers: List[Logger]):
        self.loggers = loggers

    def on_result(self, trial, result):
        for lg in self.loggers:
            lg.on_result(trial, result)

    def on_event(self, trial, event):
        for lg in self.loggers:
            lg.on_event(trial, event)

    def on_trial_complete(self, trial):
        for lg in self.loggers:
            lg.on_trial_complete(trial)

    def on_experiment_end(self, trials):
        for lg in self.loggers:
            lg.on_experiment_end(trials)

    def close(self):
        for lg in self.loggers:
            lg.close()
