"""Checkpoint serialization: pytree <-> bytes (msgpack) and a manager.

The paper relies on checkpoints for (a) fault tolerance, (b) PBT-style clone /
hyperparameter mutation, (c) pause/resume under HyperBand.  In functional JAX
the trial state *is* a pytree, so a checkpoint is an exact, race-free snapshot.

We serialize with msgpack: tree structure as nested lists/dicts, leaves as
(dtype, shape, raw bytes).  No pickle on the wire for arrays (portable), and a
CRC over the payload catches truncation.  The codec covers the narrow dtypes
(``bfloat16``/``float16``/``float8_*`` via ml_dtypes) because for process
workers (DESIGN.md §5) the bytes path is the *only* path — a dtype the codec
can't round-trip is a hard trial failure, not a fallback.

This module deliberately avoids importing ``jax`` at module scope: spawned
worker processes import it on every boot, and a trainable that never touches
device arrays should not pay the ~2s jax import just to checkpoint scalars.
"""
from __future__ import annotations

import itertools
import os
import zlib
from typing import Any, Dict, List, Optional

import msgpack
import numpy as np

from .object_store import ObjectStore
from .trial import Checkpoint

__all__ = ["tree_to_bytes", "tree_from_bytes", "CheckpointManager", "save_pytree", "load_pytree"]

_ARR = "__arr__"
_SCALAR = "__scalar__"
_EXPORT_SEQ = itertools.count()  # uniquifies export_copy keys within a host


def _resolve_dtype(name: str) -> "np.dtype":
    """dtype-by-name, including the ml_dtypes extension types.

    ``np.dtype("bfloat16")`` only resolves once ml_dtypes has been imported
    (jax does that implicitly; a jax-free worker process does not), so fall
    back to looking the name up on ml_dtypes directly.
    """
    try:
        return np.dtype(name)
    except TypeError:
        try:
            import ml_dtypes
            return np.dtype(getattr(ml_dtypes, name))
        except (ImportError, AttributeError):
            raise TypeError(f"unsupported checkpoint dtype {name!r}")


def _encode_leaf(leaf: Any):
    if isinstance(leaf, (bool, int, float, str)) or leaf is None:
        return {_SCALAR: leaf}
    if isinstance(leaf, (np.integer, np.floating)):
        return {_SCALAR: leaf.item()}
    if isinstance(leaf, np.ndarray) or (hasattr(leaf, "dtype") and hasattr(leaf, "shape")):
        arr = np.asarray(leaf)  # jax.Array included — np.asarray devices-gets it
        return {_ARR: [str(arr.dtype), list(arr.shape), arr.tobytes()]}
    raise TypeError(f"unsupported checkpoint leaf type: {type(leaf)}")


def _decode_leaf(obj):
    if isinstance(obj, dict) and _ARR in obj:
        dtype, shape, raw = obj[_ARR]
        return np.frombuffer(raw, dtype=_resolve_dtype(dtype)).reshape(shape).copy()
    if isinstance(obj, dict) and _SCALAR in obj:
        return obj[_SCALAR]
    raise TypeError(f"bad checkpoint leaf: {obj!r}")


def _encode(node: Any):
    if isinstance(node, dict) and _ARR not in node and _SCALAR not in node:
        return {"__dict__": {k: _encode(v) for k, v in node.items()}}
    if isinstance(node, (list, tuple)):
        return {"__list__" if isinstance(node, list) else "__tuple__": [_encode(v) for v in node]}
    return _encode_leaf(node)


def _decode(obj: Any):
    if isinstance(obj, dict):
        if "__dict__" in obj:
            return {k: _decode(v) for k, v in obj["__dict__"].items()}
        if "__list__" in obj:
            return [_decode(v) for v in obj["__list__"]]
        if "__tuple__" in obj:
            return tuple(_decode(v) for v in obj["__tuple__"])
    return _decode_leaf(obj)


def tree_to_bytes(tree: Any) -> bytes:
    payload = msgpack.packb(_encode(tree), use_bin_type=True)
    crc = zlib.crc32(payload)
    return crc.to_bytes(4, "little") + payload


def tree_from_bytes(data: bytes) -> Any:
    crc, payload = int.from_bytes(data[:4], "little"), data[4:]
    if zlib.crc32(payload) != crc:
        raise IOError("checkpoint CRC mismatch (truncated or corrupt)")
    return _decode(msgpack.unpackb(payload, raw=False, strict_map_key=False))


def _write_atomic(data: bytes, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)  # atomic


def save_pytree(tree: Any, path: str) -> None:
    _write_atomic(tree_to_bytes(tree), path)


def load_pytree(path: str) -> Any:
    with open(path, "rb") as f:
        return tree_from_bytes(f.read())


class CheckpointManager:
    """Stores trial checkpoints in the object store, optionally mirrored to disk.

    ``keep_last`` bounds per-trial retained checkpoints: rotation deletes both
    the store entry *and* its durable ``iter_N.ckpt`` mirror, unless the
    ``Checkpoint`` is pinned (``Checkpoint.pinned``, set by a scheduler that
    staged it — e.g. a PBT donor awaiting exploit), in which case both survive.

    Stored values are either live pytrees (in-host executors) or
    ``tree_to_bytes`` payloads (process workers); ``restore`` decodes bytes
    transparently so the two execution tiers share one checkpoint namespace.
    """

    def __init__(self, store: ObjectStore, dir: Optional[str] = None,
                 keep_last: int = 2, durable: bool = False):
        self.store = store
        self.dir = dir
        self.keep_last = keep_last
        self.durable = durable  # mirror every checkpoint to disk (fault tolerance)
        self._per_trial: Dict[str, List[Checkpoint]] = {}

    def _mirror_path(self, trial_id: str, iteration: int) -> str:
        safe_id = trial_id.replace("/", "_")
        return os.path.join(self.dir, safe_id, f"iter_{iteration}.ckpt")

    def _record(self, ckpt: Checkpoint) -> Checkpoint:
        """Append to the per-trial history and rotate out old checkpoints —
        store entry and disk mirror both — keeping pinned ones alive.

        A store key or mirror path may be shared by a *newer* history entry
        (a PBT rewind re-reaches an iteration and checkpoints it again);
        deleting through the old entry would destroy the live one's data, so
        shared references are left in place.
        """
        hist = self._per_trial.setdefault(ckpt.trial_id, [])
        hist.append(ckpt)
        keep: List[Checkpoint] = []
        # pinned entries are moved out of hist as they're found, so the loop
        # condition counts only unpinned candidates against keep_last
        while len(hist) > self.keep_last:
            old = hist.pop(0)
            if old.pinned:
                keep.append(old)  # a scheduler staged this one; both copies survive
                continue
            live = hist + keep
            if old.store_key and all(c.store_key != old.store_key for c in live):
                self.store.delete(old.store_key)
            if old.path and all(c.path != old.path for c in live) \
                    and os.path.exists(old.path):
                os.remove(old.path)
        hist[:0] = keep
        return ckpt

    def save(self, trial_id: str, iteration: int, state: Any, to_disk: bool = False) -> Checkpoint:
        key = f"ckpt/{trial_id}/{iteration}"
        self.store.put(state, key=key)
        path = None
        if (to_disk or self.durable) and self.dir:
            path = self._mirror_path(trial_id, iteration)
            save_pytree(state, path)
        return self._record(Checkpoint(trial_id=trial_id, training_iteration=iteration,
                                       store_key=key, path=path))

    def adopt(self, trial_id: str, iteration: int, store_key: str) -> Checkpoint:
        """Record a checkpoint whose payload a worker process already placed in
        the (shared-spill) store as ``tree_to_bytes`` bytes.  The durable mirror
        writes those bytes raw — the file format is identical to
        ``save_pytree``'s, so ``load_pytree`` reads either."""
        path = None
        if self.durable and self.dir:
            # peek, not get: mirroring must not re-admit every checkpoint blob
            # into the host LRU (nor cache a copy a worker may rewrite later)
            data = self.store.peek(store_key)
            path = self._mirror_path(trial_id, iteration)
            if isinstance(data, (bytes, bytearray)):
                _write_atomic(bytes(data), path)
            else:
                save_pytree(data, path)
        return self._record(Checkpoint(trial_id=trial_id, training_iteration=iteration,
                                       store_key=store_key, path=path))

    def export_copy(self, ckpt: Checkpoint) -> str:
        """Snapshot ``ckpt``'s payload under a fresh private key on the spill
        surface for a worker process to consume *asynchronously*.

        A private copy, not the original key: the source may be rotated out or
        unpinned the moment the caller returns (PBT donors keep training and
        checkpointing while the exploited trial's child is still booting), and
        that must not invalidate what the child is about to read."""
        if ckpt.store_key and self.store.contains(ckpt.store_key):
            payload = self.store.peek(ckpt.store_key)
        elif ckpt.path and os.path.exists(ckpt.path):
            with open(ckpt.path, "rb") as f:
                payload = f.read()
        else:
            raise KeyError(f"checkpoint {ckpt.location} unavailable")
        key = (f"export/{ckpt.trial_id}/{ckpt.training_iteration}"
               f".{next(_EXPORT_SEQ)}")
        return self.store.put_spilled(payload, key=key)

    def restore(self, ckpt: Checkpoint) -> Any:
        if ckpt.store_key and self.store.contains(ckpt.store_key):
            state = self.store.get(ckpt.store_key)
            if isinstance(state, (bytes, bytearray)):
                return tree_from_bytes(bytes(state))  # process-worker payload
            return state
        if ckpt.path:
            return load_pytree(ckpt.path)
        raise KeyError(f"checkpoint {ckpt.location} unavailable")

    def latest(self, trial_id: str) -> Optional[Checkpoint]:
        hist = self._per_trial.get(trial_id)
        return hist[-1] if hist else None
