"""Checkpoint serialization: pytree <-> bytes (msgpack) and a manager.

The paper relies on checkpoints for (a) fault tolerance, (b) PBT-style clone /
hyperparameter mutation, (c) pause/resume under HyperBand.  In functional JAX
the trial state *is* a pytree, so a checkpoint is an exact, race-free snapshot.

We serialize with msgpack: tree structure as nested lists/dicts, leaves as
(dtype, shape, raw bytes).  No pickle on the wire for arrays (portable), and a
CRC over the payload catches truncation.
"""
from __future__ import annotations

import os
import zlib
from typing import Any, Dict, Optional

import jax
import msgpack
import numpy as np

from .object_store import ObjectStore
from .trial import Checkpoint

__all__ = ["tree_to_bytes", "tree_from_bytes", "CheckpointManager", "save_pytree", "load_pytree"]

_ARR = "__arr__"
_SCALAR = "__scalar__"


def _encode_leaf(leaf: Any):
    if isinstance(leaf, (jax.Array, np.ndarray)):
        arr = np.asarray(leaf)
        return {_ARR: [str(arr.dtype), list(arr.shape), arr.tobytes()]}
    if isinstance(leaf, (int, float, bool, str)) or leaf is None:
        return {_SCALAR: leaf}
    if isinstance(leaf, (np.integer, np.floating)):
        return {_SCALAR: leaf.item()}
    raise TypeError(f"unsupported checkpoint leaf type: {type(leaf)}")


def _decode_leaf(obj):
    if isinstance(obj, dict) and _ARR in obj:
        dtype, shape, raw = obj[_ARR]
        return np.frombuffer(raw, dtype=np.dtype(dtype)).reshape(shape).copy()
    if isinstance(obj, dict) and _SCALAR in obj:
        return obj[_SCALAR]
    raise TypeError(f"bad checkpoint leaf: {obj!r}")


def _encode(node: Any):
    if isinstance(node, dict) and _ARR not in node and _SCALAR not in node:
        return {"__dict__": {k: _encode(v) for k, v in node.items()}}
    if isinstance(node, (list, tuple)):
        return {"__list__" if isinstance(node, list) else "__tuple__": [_encode(v) for v in node]}
    return _encode_leaf(node)


def _decode(obj: Any):
    if isinstance(obj, dict):
        if "__dict__" in obj:
            return {k: _decode(v) for k, v in obj["__dict__"].items()}
        if "__list__" in obj:
            return [_decode(v) for v in obj["__list__"]]
        if "__tuple__" in obj:
            return tuple(_decode(v) for v in obj["__tuple__"])
    return _decode_leaf(obj)


def tree_to_bytes(tree: Any) -> bytes:
    payload = msgpack.packb(_encode(tree), use_bin_type=True)
    crc = zlib.crc32(payload)
    return crc.to_bytes(4, "little") + payload


def tree_from_bytes(data: bytes) -> Any:
    crc, payload = int.from_bytes(data[:4], "little"), data[4:]
    if zlib.crc32(payload) != crc:
        raise IOError("checkpoint CRC mismatch (truncated or corrupt)")
    return _decode(msgpack.unpackb(payload, raw=False, strict_map_key=False))


def save_pytree(tree: Any, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(tree_to_bytes(tree))
    os.replace(tmp, path)  # atomic


def load_pytree(path: str) -> Any:
    with open(path, "rb") as f:
        return tree_from_bytes(f.read())


class CheckpointManager:
    """Stores trial checkpoints in the object store, optionally mirrored to disk.

    ``keep_last`` bounds per-trial retained checkpoints (older ones deleted);
    a checkpoint pinned by the scheduler (e.g. PBT donor) survives via the
    object store's own references.
    """

    def __init__(self, store: ObjectStore, dir: Optional[str] = None,
                 keep_last: int = 2, durable: bool = False):
        self.store = store
        self.dir = dir
        self.keep_last = keep_last
        self.durable = durable  # mirror every checkpoint to disk (fault tolerance)
        self._per_trial: Dict[str, list] = {}

    def save(self, trial_id: str, iteration: int, state: Any, to_disk: bool = False) -> Checkpoint:
        key = f"ckpt/{trial_id}/{iteration}"
        self.store.put(state, key=key)
        path = None
        if (to_disk or self.durable) and self.dir:
            safe_id = trial_id.replace("/", "_")
            path = os.path.join(self.dir, safe_id, f"iter_{iteration}.ckpt")
            save_pytree(state, path)
        ckpt = Checkpoint(trial_id=trial_id, training_iteration=iteration,
                          store_key=key, path=path)
        hist = self._per_trial.setdefault(trial_id, [])
        hist.append(ckpt)
        while len(hist) > self.keep_last:
            old = hist.pop(0)
            if old.store_key:
                self.store.delete(old.store_key)
        return ckpt

    def restore(self, ckpt: Checkpoint) -> Any:
        if ckpt.store_key and self.store.contains(ckpt.store_key):
            return self.store.get(ckpt.store_key)
        if ckpt.path:
            return load_pytree(ckpt.path)
        raise KeyError(f"checkpoint {ckpt.location} unavailable")

    def latest(self, trial_id: str) -> Optional[Checkpoint]:
        hist = self._per_trial.get(trial_id)
        return hist[-1] if hist else None
