"""Trial executors — the Ray-actor analogue on a TPU mesh (DESIGN.md §2).

``SerialMeshExecutor`` steps RUNNING trainables round-robin from the host loop:
TPU slices are the scarce resource, so cooperative time-slicing on the host
preserves the paper's event semantics (irregular trial lengths, intermediate
results, pause/clone) while the accelerator work inside each ``step`` is the
jitted, sharded computation.  The ``SlicePool`` (dist/submesh.py) hands each
trial a sub-mesh sized to its resource request.

``VmapExecutor`` lives in vmap_executor.py (beyond-paper optimization).
"""
from __future__ import annotations

import traceback
from collections import deque
from time import perf_counter as _perf
from typing import Any, Callable, Dict, Optional, Tuple

from ..obs import NULL_OBS
from .api import Trainable
from .checkpoint import CheckpointManager
from .clock import Clock, get_default_clock
from .events import EventBus, EventType, TrialEvent
from .resources import ResourceAccountant, Resources
from .trial import Checkpoint, Result, Trial, TrialStatus

__all__ = ["TrialExecutor", "SerialMeshExecutor", "BusDrivenExecutor"]


class TrialExecutor:
    """Interface the runner drives."""

    lookahead = 1  # un-consumed results a worker may run ahead of the scheduler

    def set_lookahead(self, k: int) -> None:
        """Installed by the elastic ResourceBroker (DESIGN.md §6) before any
        trial starts.  Gated tiers spawn workers with this many step credits;
        poll-style executors are inherently one-at-a-time and ignore it."""
        self.lookahead = max(1, int(k))

    def resize_trial(self, trial: Trial, new_devices: int) -> bool:
        """Grow/shrink the trial's mesh slice at a checkpoint boundary
        (SAVE -> swap slice -> rebuild + re-shard -> RESTORE).  Returns False
        when unsupported or rolled back — the trial then keeps stepping on its
        old slice.  Default: unsupported."""
        return False

    def trial_idle(self, trial: Trial) -> bool:
        """True when the trial's worker is parked at the resume gate with no
        granted-but-unfinished steps — the only state a resize may interrupt.
        Poll-style executors only step while the runner waits, so whenever the
        runner holds control every trial is at a boundary."""
        return True

    def held_slice(self, trial_id: str):
        """The MeshSlice the trial currently holds, or None."""
        return None

    def start_trial(self, trial: Trial, checkpoint: Optional[Checkpoint] = None) -> bool:
        raise NotImplementedError

    def pause_trial(self, trial: Trial) -> None:
        raise NotImplementedError

    def stop_trial(self, trial: Trial, error: Optional[str] = None) -> None:
        raise NotImplementedError

    def requeue_trial(self, trial: Trial) -> None:
        """Tear down a failed trial instance without finishing the trial, so the
        runner can restart it from its last checkpoint (max_failures retry)."""
        raise NotImplementedError

    def restart_trial_with_config(
        self, trial: Trial, checkpoint: Checkpoint, new_config: Dict[str, Any]
    ) -> None:
        raise NotImplementedError

    def get_next_result(self) -> Optional[Tuple[Trial, Any]]:
        raise NotImplementedError

    def get_next_event(self) -> Optional[TrialEvent]:
        """Next ``TrialEvent`` for the runner's event loop.

        Compat shim for poll-style executors: wraps ``get_next_result()``
        pairs into typed events.  Push-style executors (concurrent_executor)
        override this to drain their EventBus instead.
        """
        pair = self.get_next_result()
        if pair is None:
            return None
        trial, payload = pair
        if isinstance(payload, Exception):
            return TrialEvent(EventType.ERROR, trial.trial_id, error=str(payload))
        return TrialEvent(EventType.RESULT, trial.trial_id, result=payload)

    def resume_trial(self, trial: Trial) -> None:
        """CONTINUE decision applied; gated executors let the trial's next
        step proceed.  Poll-style executors advance implicitly — no-op."""

    def has_resources(self, trial: Trial) -> bool:
        raise NotImplementedError

    def has_running(self) -> bool:
        raise NotImplementedError

    def save_checkpoint(self, trial: Trial) -> Checkpoint:
        raise NotImplementedError

    def shutdown(self) -> None:
        pass


class _SlicedExecutor(TrialExecutor):
    """Shared capacity/placement accounting for executors that place each
    trial on a SlicePool sub-mesh (serial and concurrent).  One copy of the
    acquire/instantiate/release logic keeps their placement behavior from
    drifting apart."""

    def __init__(
        self,
        trainable_cls_resolver: Callable[[str], type],
        checkpoint_manager: CheckpointManager,
        total_cpu: float = 64.0,
        total_devices: int = 256,
        slice_pool: Optional[Any] = None,  # dist.submesh.SlicePool
        checkpoint_freq: int = 0,
        clock: Optional[Clock] = None,
        obs: Optional[Any] = None,  # repro.obs.Observability
    ):
        self._resolve = trainable_cls_resolver
        self.ckpt = checkpoint_manager
        self.accountant = ResourceAccountant(total_cpu, total_devices)
        self.slice_pool = slice_pool
        self.checkpoint_freq = checkpoint_freq
        self.clock = clock or get_default_clock()
        self.obs = obs or NULL_OBS
        self._slices: Dict[str, Any] = {}
        # Pre-resolved hot-path instruments (DESIGN.md §8): with obs off each
        # guard is a single None test.
        m = self.obs.metrics
        if m is not None:
            self._m_acquire = m.histogram("pool.acquire_us")
            self._m_ckpt_save = m.histogram("ckpt.save_us")
            self._m_ckpt_restore = m.histogram("ckpt.restore_us")
        else:
            self._m_acquire = self._m_ckpt_save = self._m_ckpt_restore = None

    def _pool_for(self, trial: Trial) -> Optional[Any]:
        """The SlicePool this trial places on.  Single-host tiers share one
        pool; the cluster tier overrides this to the trial's host pool, which
        is what lets ``resize_trial`` / the elastic broker / slice release all
        stay host-correct without knowing about hosts."""
        return self.slice_pool

    def has_resources(self, trial: Trial) -> bool:
        pool = self._pool_for(trial)
        if pool is not None and not pool.can_fit(trial.resources.devices):
            return False
        return self.accountant.has_room(trial.resources)

    def _acquire_slice(self, trial: Trial) -> None:
        """Accountant + pool placement for one trial — the shared first-fit
        hot path, timed (``pool.acquire_us``) and traced (``slice.acquire``)."""
        self.accountant.acquire(trial.resources)
        pool = self._pool_for(trial)
        if pool is None:
            return
        tracer = self.obs.tracer
        if self._m_acquire is None and not tracer.enabled:
            self._slices[trial.trial_id] = \
                pool.acquire(trial.resources.devices)
            return
        t0 = tracer.clock.time() if tracer.enabled else 0.0
        p0 = _perf()
        sl = pool.acquire(trial.resources.devices)
        if self._m_acquire is not None:
            self._m_acquire.observe((_perf() - p0) * 1e6)
        self._slices[trial.trial_id] = sl
        if tracer.enabled:
            tracer.record("slice.acquire", trial.trial_id, t0,
                          tracer.clock.time() - t0, cat="placement",
                          devices=trial.resources.devices, start=sl.start)

    def _instantiate(self, trial: Trial) -> Trainable:
        cls = self._resolve(trial.trainable_name)
        config = dict(trial.config)
        if trial.trial_id in self._slices:
            config["_slice"] = self._slices[trial.trial_id]
        return cls(config)

    def _release(self, trial: Trial) -> None:
        self.accountant.release(trial.resources)
        pool = self._pool_for(trial)
        if pool is not None and trial.trial_id in self._slices:
            pool.release(self._slices.pop(trial.trial_id))

    def _set_requeue_status(self, trial: Trial) -> None:
        trial.set_status(
            TrialStatus.PAUSED if trial.checkpoint is not None else TrialStatus.PENDING)

    def held_slice(self, trial_id: str):
        return self._slices.get(trial_id)

    # -- elastic slice swap (DESIGN.md §6) ------------------------------------------
    def _swap_slice(self, trial: Trial, new_devices: int) -> Tuple[Any, Any, Any]:
        """Move the trial's pool slice and accounting to ``new_devices``.

        Returns ``(old_resources, old_slice, new_slice)`` for a later rollback
        via ``_unswap_slice``; raises RuntimeError (pool or accountant full)
        with everything unchanged.  No trainable side effects — the caller
        rebuilds the mesh around this.
        """
        from .resources import Resources
        pool = self._pool_for(trial)
        old_res = trial.resources
        new_res = Resources(cpu=old_res.cpu, devices=new_devices)
        old_sl = self._slices[trial.trial_id]
        new_sl = pool.resize(old_sl, new_devices)
        try:
            self.accountant.release(old_res)
            self.accountant.acquire(new_res)
        except RuntimeError:
            # Pool moved but the accountant refused: put the exact old range
            # back (nothing else allocated in between — runner thread).
            self.accountant.acquire(old_res)
            pool.release(new_sl)
            restored = pool.acquire_at(old_sl.start, old_sl.size)
            self._slices[trial.trial_id] = restored
            raise
        self._slices[trial.trial_id] = new_sl
        trial.resources = new_res
        return old_res, old_sl, new_sl

    def _unswap_slice(self, trial: Trial, old_res: Any, old_sl: Any,
                      new_sl: Any) -> None:
        """Roll a ``_swap_slice`` back after a failed rebuild: the trial ends
        up on the *exact* old device range its live mesh still covers."""
        pool = self._pool_for(trial)
        pool.release(new_sl)
        restored = pool.acquire_at(old_sl.start, old_sl.size)
        self.accountant.release(trial.resources)
        self.accountant.acquire(old_res)
        self._slices[trial.trial_id] = restored
        trial.resources = old_res

    def _resize_rebuild(self, trial: Trial, trainable: Trainable,
                        new_devices: int):
        """The in-host resize core shared by the serial and thread tiers:
        SAVE (in-memory) -> swap the pool slice -> rebuild the trainable over
        the new sub-mesh (its setup re-shards via repro.dist.sharding from
        the new ``_slice``) -> RESTORE, iteration preserved.  Returns the
        rebuilt trainable, or None with the swap fully rolled back — the
        caller then keeps ``trainable`` serving on the old slice."""
        try:
            state = trainable.save()
        except Exception:  # noqa: BLE001 — unsaveable trainables can't resize
            return None
        try:
            old_res, old_sl, new_sl = self._swap_slice(trial, new_devices)
        except RuntimeError:
            return None
        new_trainable = None
        try:
            new_trainable = self._instantiate(trial)
            new_trainable.restore(state)
            new_trainable.iteration = trainable.iteration
        except Exception:  # noqa: BLE001 — fall back to the old slice
            if new_trainable is not None:  # built but failed to restore
                try:
                    new_trainable.cleanup()
                except Exception:  # noqa: BLE001
                    pass
            self._unswap_slice(trial, old_res, old_sl, new_sl)
            return None
        try:
            trainable.cleanup()
        except Exception:  # noqa: BLE001
            pass
        return new_trainable


class BusDrivenExecutor(_SlicedExecutor):
    """Base for push-style executors whose workers (threads or processes)
    publish ``TrialEvent``s on a shared ``EventBus`` while the runner blocks in
    ``get_next_event``.  Subclasses keep live workers in ``self._workers``
    (mutated only from the runner thread) and may run a monitor thread in
    ``self._monitor_thread`` that guarantees an eventual event for stuck steps.
    """

    def __init__(self, *args, event_bus: Optional[EventBus] = None, **kwargs):
        super().__init__(*args, **kwargs)
        self.bus = event_bus or EventBus(clock=self.clock,
                                         metrics=self.obs.metrics)
        self._workers: Dict[str, Any] = {}
        self._monitor_thread: Optional[Any] = None
        self._event_wait_bound = 60.0

    def _events_guaranteed(self) -> bool:
        """True when a monitor thread will eventually publish an event even if
        every worker is stuck (so an unbounded runner wait is safe)."""
        return self._monitor_thread is not None

    def has_running(self) -> bool:
        return bool(self._workers)

    def get_next_event(self, timeout: Optional[float] = None) -> Optional[TrialEvent]:
        """Block until an event arrives or no worker can produce one.

        With live workers this waits (bounded only by their progress — the
        monitor thread guarantees an eventual event for stuck steps); with
        none it drains whatever is queued and then returns None.  When the
        monitor is disabled that guarantee is gone, so the wait is bounded
        (~60s) instead: the runner's stall detector stays reachable and a
        hung step surfaces as a stall error rather than a silent hang.

        Deadline arithmetic runs on ``clock.monotonic()`` — never the wall
        timestamp axis, which NTP steps or a suspended laptop can jump by
        hours, silently expiring (or never expiring) a 0.5s wait.
        """
        deadline = None if timeout is None else self.clock.monotonic() + timeout
        if deadline is None and not self._events_guaranteed():
            deadline = self.clock.monotonic() + self._event_wait_bound
        while True:
            # _workers is mutated only by this (runner) thread, so the check
            # can't race; block on the queue in long slices instead of polling.
            if not self._workers:
                return self.bus.get()
            wait = 0.5
            if deadline is not None:
                wait = min(wait, deadline - self.clock.monotonic())
                if wait <= 0:
                    return None
            ev = self.bus.get(timeout=wait)
            if ev is not None:
                return ev


class SerialMeshExecutor(_SlicedExecutor):
    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._running: Dict[str, Trainable] = {}
        self._queue: deque = deque()  # round-robin order of trial_ids
        self._trials: Dict[str, Trial] = {}

    def has_running(self) -> bool:
        return bool(self._running)

    def start_trial(self, trial: Trial, checkpoint: Optional[Checkpoint] = None) -> bool:
        if not self.has_resources(trial):
            return False
        self._acquire_slice(trial)
        tracer = self.obs.tracer
        try:
            with tracer.span("build", trial.trial_id, cat="lifecycle"):
                trainable = self._instantiate(trial)
            if checkpoint is not None:
                with tracer.span("ckpt.restore", trial.trial_id, cat="ckpt",
                                 iteration=checkpoint.training_iteration):
                    p0 = _perf()
                    state = self.ckpt.restore(checkpoint)
                    trainable.restore(state)
                if self._m_ckpt_restore is not None:
                    self._m_ckpt_restore.observe((_perf() - p0) * 1e6)
                trainable.iteration = checkpoint.training_iteration
                checkpoint.pinned = False  # consumed; rotation may reclaim it
        except Exception:
            self._release(trial)
            trial.error = traceback.format_exc()
            trial.set_status(TrialStatus.ERROR)
            return False
        self._running[trial.trial_id] = trainable
        self._trials[trial.trial_id] = trial
        self._queue.append(trial.trial_id)
        trial.set_status(TrialStatus.RUNNING)
        return True

    def _teardown(self, trial: Trial) -> None:
        trainable = self._running.pop(trial.trial_id, None)
        if trainable is not None:
            try:
                trainable.cleanup()
            except Exception:
                pass
            self._release(trial)
        try:
            self._queue.remove(trial.trial_id)
        except ValueError:
            pass

    def save_checkpoint(self, trial: Trial) -> Checkpoint:
        trainable = self._running[trial.trial_id]
        with self.obs.tracer.span("ckpt.save", trial.trial_id, cat="ckpt",
                                  iteration=trainable.iteration):
            p0 = _perf()
            state = trainable.save()
            ckpt = self.ckpt.save(trial.trial_id, trainable.iteration, state)
        if self._m_ckpt_save is not None:
            self._m_ckpt_save.observe((_perf() - p0) * 1e6)
        trial.checkpoint = ckpt
        return ckpt

    def pause_trial(self, trial: Trial) -> None:
        if trial.trial_id in self._running:
            self.save_checkpoint(trial)
            self._teardown(trial)
        trial.set_status(TrialStatus.PAUSED)

    def stop_trial(self, trial: Trial, error: Optional[str] = None) -> None:
        self._teardown(trial)
        if error:
            trial.error = error
            trial.set_status(TrialStatus.ERROR)
        else:
            trial.set_status(TrialStatus.TERMINATED)

    def requeue_trial(self, trial: Trial) -> None:
        """Tear down a failed instance, keeping the trial restartable from its
        last checkpoint (the runner's max_failures retry path)."""
        self._teardown(trial)
        self._set_requeue_status(trial)

    def restart_trial_with_config(self, trial, checkpoint, new_config) -> None:
        """PBT exploit: restore donor state under a mutated config.

        Tries in-place ``reset_config`` first (cheap); falls back to full
        teardown + rebuild, exactly like Ray Tune's reuse_actors path.
        """
        trial.config = dict(new_config)
        trainable = self._running.get(trial.trial_id)
        state = self.ckpt.restore(checkpoint)
        if trainable is not None and trainable.reset_config(new_config):
            trainable.restore(state)
            trainable.iteration = checkpoint.training_iteration
        else:
            if trainable is not None:
                self._teardown(trial)
                trial.set_status(TrialStatus.PAUSED)
            started = self.start_trial(trial, checkpoint=None)
            if not started:
                if trial.status != TrialStatus.ERROR:
                    # No capacity to rebuild right now: re-queue PAUSED with
                    # the donor checkpoint attached so the next launch
                    # restores it — never leave the trial sliceless in limbo.
                    trial.checkpoint = checkpoint
                    trial.set_status(TrialStatus.PAUSED)
                return
            new_trainable = self._running[trial.trial_id]
            new_trainable.restore(state)
            new_trainable.iteration = checkpoint.training_iteration

    # -- elastic resize (DESIGN.md §6) ----------------------------------------------
    def resize_trial(self, trial: Trial, new_devices: int) -> bool:
        """Checkpoint-boundary slice resize; on any rebuild failure the swap
        is rolled back and the old trainable keeps running on its old slice
        (see ``_resize_rebuild``)."""
        trainable = self._running.get(trial.trial_id)
        if (trainable is None or self.slice_pool is None
                or new_devices == trial.resources.devices):
            return False
        new_trainable = self._resize_rebuild(trial, trainable, new_devices)
        if new_trainable is None:
            return False
        self._running[trial.trial_id] = new_trainable
        return True

    # -- stepping -------------------------------------------------------------------
    def get_next_result(self) -> Optional[Tuple[Trial, Any]]:
        """Step the next running trainable one unit; return (trial, Result|Exception)."""
        while self._queue:
            trial_id = self._queue[0]
            self._queue.rotate(-1)
            trainable = self._running.get(trial_id)
            if trainable is None:
                try:
                    self._queue.remove(trial_id)
                except ValueError:
                    pass
                continue
            trial = self._trials[trial_id]
            tracer = self.obs.tracer
            try:
                if tracer.enabled:
                    t0 = tracer.clock.time()
                    metrics = trainable.train()
                    tracer.record("step", trial_id, t0,
                                  tracer.clock.time() - t0, cat="train",
                                  iteration=trainable.iteration)
                else:
                    metrics = trainable.train()
            except Exception as e:  # noqa: BLE001 — trial error, not framework error
                return trial, e
            done = bool(metrics.pop("done", False))
            result = Result(
                trial_id=trial_id,
                training_iteration=trainable.iteration,
                metrics=metrics,
                done=done,
                timestamp=self.clock.time(),
            )
            if (
                self.checkpoint_freq
                and trainable.iteration % self.checkpoint_freq == 0
                and not done
            ):
                try:
                    self.save_checkpoint(trial)
                except NotImplementedError:
                    pass
                except Exception as e:  # noqa: BLE001 — checkpoint failure is a
                    return trial, e     # trial error (retryable), not framework death
            return trial, result
        return None

    def get_trainable(self, trial_id: str) -> Optional[Trainable]:
        return self._running.get(trial_id)

    def shutdown(self) -> None:
        for trial_id in list(self._running):
            trial = self._trials[trial_id]
            self._teardown(trial)
