"""User-facing API — the paper's two development interfaces (§4.1, Figure 2).

Class-based (Figure 2b): subclass ``Trainable`` and implement ``step`` (one unit
of training; return a metrics dict), ``save`` (return a state pytree) and
``restore`` (accept that pytree).  Tune schedulers call these to incrementally
train, snapshot, clone and mutate trials.

Function-based cooperative API (Figure 2a): write an ordinary training loop
taking a ``tune`` handle; call ``tune.report(**metrics)`` per unit, consult
``tune.should_checkpoint()`` and hand state to ``tune.record_checkpoint(state)``.
Internally (exactly as the paper notes) we insert an adapter that presents the
cooperative function as a class-based Trainable: the function runs on a worker
thread, ``report`` blocks until the runner requests the next unit.
"""
from __future__ import annotations

import queue
import threading
import traceback
from collections import deque
from typing import Any, Callable, Dict, Optional

__all__ = ["Trainable", "FunctionHandle", "FunctionTrainable", "wrap_function"]


class Trainable:
    """Class-based trainable (direct control)."""

    def __init__(self, config: Dict[str, Any]):
        self.config = dict(config)
        self.iteration = 0
        self.setup(self.config)

    # -- user hooks ------------------------------------------------------------
    def setup(self, config: Dict[str, Any]) -> None:  # optional
        pass

    def step(self) -> Dict[str, Any]:
        """Run one unit of training and return a metrics dict."""
        raise NotImplementedError

    def save(self) -> Any:
        """Return a checkpointable pytree of the full training state."""
        raise NotImplementedError

    def restore(self, state: Any) -> None:
        raise NotImplementedError

    def reset_config(self, new_config: Dict[str, Any]) -> bool:
        """In-place hyperparameter mutation (PBT). Return False if unsupported —
        the executor will then tear down and rebuild the trainable."""
        return False

    def cleanup(self) -> None:  # optional
        pass

    # -- framework-driven ------------------------------------------------------
    def train(self) -> Dict[str, Any]:
        metrics = self.step()
        if not isinstance(metrics, dict):
            raise TypeError(f"step() must return a dict, got {type(metrics)}")
        self.iteration += 1
        return metrics


class _StopToken:
    pass


class FunctionHandle:
    """The ``tune`` handle passed into function-based trainables."""

    def __init__(self, params: Dict[str, Any]):
        self.params = dict(params)
        self._result_q: "queue.Queue" = queue.Queue(maxsize=1)
        self._control_q: "queue.Queue" = queue.Queue(maxsize=1)
        self._checkpoint_requested = False
        self._recorded_checkpoint: Any = None
        self._stopped = False

    # -- called from user code (worker thread) ---------------------------------
    def report(self, **metrics: Any) -> None:
        """Report intermediate results; blocks until the runner wants more."""
        self._result_q.put(("result", metrics))
        cmd = self._control_q.get()
        if isinstance(cmd, _StopToken):
            self._stopped = True
            raise StopIteration("trial stopped by scheduler")

    def should_checkpoint(self) -> bool:
        return self._checkpoint_requested

    def record_checkpoint(self, state: Any) -> None:
        self._recorded_checkpoint = state
        self._checkpoint_requested = False


class FunctionTrainable(Trainable):
    """Adapter presenting a cooperative function as a class-based Trainable.

    The function runs on a daemon thread; each ``train()`` lets it advance to
    its next ``report`` call.  ``save`` asks the function (via
    ``should_checkpoint``) to record state at its next report boundary.
    """

    _fn: Callable[[FunctionHandle], None]  # set by wrap_function subclassing

    def setup(self, config: Dict[str, Any]) -> None:
        self.handle = FunctionHandle(config)
        self._done = False
        self._error: Optional[str] = None
        self._thread = threading.Thread(target=self._entry, daemon=True)
        self._started = False
        self._pending_metrics: deque = deque()

    def _entry(self) -> None:
        try:
            type(self)._fn(self.handle)
            self.handle._result_q.put(("done", {}))
        except StopIteration:
            self.handle._result_q.put(("done", {}))
        except BaseException:  # noqa: BLE001 — report trial error upward
            self._error = traceback.format_exc()
            self.handle._result_q.put(("error", self._error))

    def step(self) -> Dict[str, Any]:
        # A save() may have advanced the function to reach a checkpoint
        # boundary; the result it reported then is owed to the caller first.
        if self._pending_metrics:
            return self._pending_metrics.popleft()
        return self._advance()

    def _advance(self) -> Dict[str, Any]:
        """Let the function run to its next report; return those metrics."""
        if self._done:
            raise RuntimeError("function trainable already finished")
        if not self._started:
            self._thread.start()
            self._started = True
        else:
            self.handle._control_q.put("continue")
        kind, payload = self.handle._result_q.get()
        if kind == "error":
            raise RuntimeError(f"trial function raised:\n{payload}")
        if kind == "done":
            self._done = True
            return {"done": True}
        return dict(payload)

    def save(self) -> Any:
        if self.handle._recorded_checkpoint is None:
            # Ask the function to checkpoint at its next report boundary; the
            # metrics reported there are queued so the next step() yields them
            # instead of silently dropping a reported result.
            self.handle._checkpoint_requested = True
            self._pending_metrics.append(self._advance())
            if self.handle._recorded_checkpoint is None:
                raise RuntimeError(
                    "function trainable did not record_checkpoint() when asked; "
                    "call tune.record_checkpoint(state) when tune.should_checkpoint()"
                )
        state = self.handle._recorded_checkpoint
        self.handle._recorded_checkpoint = None  # consume: next save re-asks
        return state

    def restore(self, state: Any) -> None:
        raise NotImplementedError(
            "function trainables restore by re-running from config; use the "
            "class-based API for schedulers that pause/clone (HyperBand, PBT)"
        )

    def cleanup(self) -> None:
        if self._started and not self._done and self._thread.is_alive():
            self.handle._control_q.put(_StopToken())
            self._thread.join(timeout=5.0)


def wrap_function(fn: Callable[[FunctionHandle], None]) -> type:
    """Make a FunctionTrainable subclass from a cooperative training function."""
    return type(f"Function[{getattr(fn, '__name__', 'fn')}]",
                (FunctionTrainable,), {"_fn": staticmethod(fn)})
