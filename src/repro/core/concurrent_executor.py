"""ConcurrentMeshExecutor — asynchronous trial execution over mesh slices.

``SerialMeshExecutor`` time-slices RUNNING trainables one at a time on the
host thread, so trials holding *disjoint* SlicePool sub-meshes still step
sequentially.  Here each RUNNING trial gets its own worker thread:

- the worker loops ``train()`` → publish RESULT on the shared ``EventBus``,
  then parks on a resume gate until the runner has applied the scheduler's
  decision (``resume_trial`` re-opens the gate on CONTINUE);
- JAX dispatch from concurrent host threads overlaps device work across the
  disjoint slices — while the runner processes trial A's result, trials
  B..N have their steps in flight;
- a heartbeat monitor publishes HEARTBEAT_MISSED when a step exceeds the
  straggler timeout, so the runner's event loop always makes progress (and
  can surface stuck trials) even when no result arrives.

Scheduler semantics are preserved exactly at the default ``lookahead=1``: at
most one un-consumed result per trial is ever in flight, so PAUSE/STOP/
PBT-clone decisions apply before the trial advances past the result they
were made on.  The gate is a credit *semaphore* (DESIGN.md §6): the elastic
broker may grant ``k>1`` credits — but only for schedulers that declare
``decision_interval() == 0`` (pure run-to-completion), where no decision can
be stale.  With ``k>1`` a stop can land mid-step; teardown then waits out
``join_timeout`` and falls back to the same abandoned-worker contract as a
straggler (at most k-1 extra steps are computed and fenced as stale).
Failure handling is checkpoint-based (paper §4.2): a worker that raises
publishes ERROR and the runner re-queues the trial from its last checkpoint,
bounded by ``max_failures`` (runner.py).

Threading contract (DESIGN.md §4): the runner thread owns trial lifecycle
(start/pause/stop/restart) and all ResourceAccountant/SlicePool mutation;
worker threads own their trainable and touch only the bus and the checkpoint
manager (serialized by ``_ckpt_lock``).  ``ws.lock`` guards the trainable so
``save_checkpoint`` from the runner thread waits out an in-flight step.
"""
from __future__ import annotations

import threading
import traceback
from time import perf_counter as _perf
from typing import Any, Callable, Dict, Optional

from .api import Trainable
from .checkpoint import CheckpointManager
from .clock import Clock
from .events import EventBus, EventType, TrialEvent
from .executor import BusDrivenExecutor
from .trial import Checkpoint, Result, Trial, TrialStatus

__all__ = ["ConcurrentMeshExecutor"]


class _WorkerState:
    """Per-trial worker bookkeeping; one instance per (re)launched thread."""

    def __init__(self, trial: Trial, trainable: Trainable, clock: Clock,
                 credits: int = 1):
        self.trial = trial
        self.trainable = trainable
        self.thread: Optional[threading.Thread] = None
        # Credit-counting resume gate (DESIGN.md §6): each credit is one step
        # the runner has granted.  credits=1 is exactly PR 2's binary gate —
        # at most one un-consumed result per trial; k>1 lets the worker run
        # ahead for run-to-completion schedulers.  The semaphore comes from
        # the clock so a parked worker is visible to virtual time (§7).
        self.credits = clock.semaphore(credits)
        self.granted = credits            # runner-thread writes only
        self.published = 0                # worker-thread writes only
        self.stop = threading.Event()     # runner halt request (checked, never waited)
        self.registered = threading.Event()  # thread joined the clock's roster
        self.lock = threading.Lock()      # guards the trainable
        self.in_step = False
        self.step_started = 0.0
        self.last_warned = 0.0
        self.dead = False                 # worker exited after publishing ERROR

    @property
    def parked(self) -> bool:
        """No granted-but-unpublished steps: the worker thread is blocked on
        the credit gate (or about to be) and the trainable is quiescent.  Each
        counter has a single writer; `published` is incremented *before* the
        bus publish, so by the time the runner processes a result the counters
        already agree."""
        return self.granted == self.published


class ConcurrentMeshExecutor(BusDrivenExecutor):
    def __init__(
        self,
        trainable_cls_resolver: Callable[[str], type],
        checkpoint_manager: CheckpointManager,
        total_cpu: float = 64.0,
        total_devices: int = 256,
        slice_pool: Optional[Any] = None,  # dist.submesh.SlicePool
        checkpoint_freq: int = 0,
        heartbeat_timeout: float = 60.0,   # <=0 disables the monitor
        event_bus: Optional[EventBus] = None,
        join_timeout: float = 10.0,
        clock: Optional[Clock] = None,
        obs: Optional[Any] = None,
    ):
        super().__init__(trainable_cls_resolver, checkpoint_manager,
                         total_cpu, total_devices, slice_pool, checkpoint_freq,
                         event_bus=event_bus, clock=clock, obs=obs)
        self.heartbeat_timeout = heartbeat_timeout
        self.join_timeout = join_timeout
        self._event_wait_bound = max(60.0, join_timeout)
        self._ckpt_lock = threading.Lock()  # CheckpointManager/ObjectStore access
        self._shutdown_evt = self.clock.event()
        if heartbeat_timeout and heartbeat_timeout > 0:
            ready = threading.Event()
            self._monitor_thread = threading.Thread(
                target=self._monitor, args=(ready,),
                name="repro-heartbeat", daemon=True)
            self._monitor_thread.start()
            # Wait out the roster handshake so virtual time can never advance
            # while the monitor is still booting (its interval phase would
            # drift nondeterministically otherwise).  Microseconds in real
            # time; the monitor has not parked yet so this cannot block long.
            if not ready.wait(timeout=10.0):
                raise RuntimeError(
                    "heartbeat monitor failed to enroll with the clock "
                    "within 10s")

    # -- worker loop ----------------------------------------------------------------
    def _worker_main(self, ws: _WorkerState) -> None:
        """Thread body: enroll in the clock roster (virtual time only advances
        when every enrolled thread is parked in a clock primitive), then run."""
        with self.clock.running():
            ws.registered.set()
            self._run_worker(ws)

    def _run_worker(self, ws: _WorkerState) -> None:
        trial_id = ws.trial.trial_id
        # Worker-side spans (step, ckpt.save) are batched per result and
        # shipped on the bus as ONE SPAN event just before the RESULT, so the
        # runner adopts them onto the trial's trace row (DESIGN.md §8).
        # Timestamps come from the shared clock — deterministic under virtual
        # time.  With tracing off this adds one attribute test per step.
        traced = self.obs.tracer.enabled
        # Durable resume (DESIGN.md §12): a restored trial carries the virtual
        # timestamp it had reached when the original controller died.  Sleep
        # the clock to that point before the first step so every subsequent
        # result lands at the same virtual time — and hence in the same
        # cross-trial arrival order — as in the uninterrupted run.  One-shot:
        # consumed here so respawns (resize, exploit) never re-apply it.
        phase_t = ws.trial.resume_phase_t
        if phase_t is not None:
            ws.trial.resume_phase_t = None
            self.clock.sleep_until(phase_t)
        while True:
            # Acquire one step credit; the runner grants them on CONTINUE
            # (and _halt releases one after setting stop, so a halted worker
            # wakes here exactly once and exits; no polling).
            ws.credits.acquire()
            if ws.stop.is_set():
                return
            spans = []
            if traced:
                t_step = self.clock.time()
            with ws.lock:
                ws.step_started = self.clock.monotonic()
                ws.in_step = True
                try:
                    metrics = ws.trainable.train()
                except Exception:  # noqa: BLE001 — trial error, not framework error
                    ws.dead = True
                    self.bus.publish(TrialEvent(
                        EventType.ERROR, trial_id, error=traceback.format_exc()))
                    return
                finally:
                    ws.in_step = False
            if ws.stop.is_set():
                # Halted mid-step (shutdown, abort, or abandoned after a join
                # timeout): the runner has moved on — possibly relaunched this
                # trial — so publishing this result or checkpointing now would
                # corrupt the live instance's state.  Discard and exit.
                return
            if traced:
                spans.append(("step", t_step, self.clock.time() - t_step,
                              "train", "host",
                              {"iteration": ws.trainable.iteration}))
            done = bool(metrics.pop("done", False))
            result = Result(
                trial_id=trial_id,
                training_iteration=ws.trainable.iteration,
                metrics=metrics,
                done=done,
                timestamp=self.clock.time(),
            )
            if (
                self.checkpoint_freq
                and ws.trainable.iteration % self.checkpoint_freq == 0
                and not done
            ):
                try:
                    if traced:
                        t_ck = self.clock.time()
                    with ws.lock:
                        ckpt = self._save_locked(ws)
                    if traced:
                        spans.append(("ckpt.save", t_ck,
                                      self.clock.time() - t_ck, "ckpt", "host",
                                      {"iteration": ws.trainable.iteration}))
                    self.bus.publish(TrialEvent(
                        EventType.CHECKPOINTED, trial_id, checkpoint=ckpt,
                        info={"iteration": ws.trainable.iteration}))
                except NotImplementedError:
                    pass
                except Exception:  # noqa: BLE001 — checkpoint failure kills the trial
                    ws.dead = True
                    self.bus.publish(TrialEvent(
                        EventType.ERROR, trial_id, error=traceback.format_exc()))
                    return
            if spans:
                self.bus.publish(TrialEvent(
                    EventType.SPAN, trial_id, info={"spans": spans}))
            ws.published += 1  # before publish: see _WorkerState.parked
            self.bus.publish(TrialEvent(EventType.RESULT, trial_id, result=result))
            if done:
                return  # the runner will stop_trial on the final result

    def _monitor(self, ready: threading.Event) -> None:
        interval = max(0.05, min(1.0, self.heartbeat_timeout / 4))
        with self.clock.running():
            ready.set()
            while not self._shutdown_evt.wait(interval):
                now = self.clock.monotonic()
                for ws in list(self._workers.values()):
                    stalled = ws.in_step and now - ws.step_started > self.heartbeat_timeout
                    if stalled and now - ws.last_warned > self.heartbeat_timeout:
                        ws.last_warned = now
                        self.bus.publish(TrialEvent(
                            EventType.HEARTBEAT_MISSED, ws.trial.trial_id,
                            info={"stalled_s": round(now - ws.step_started, 3)}))

    # -- lifecycle ------------------------------------------------------------------
    def _spawn(self, trial: Trial, trainable: Trainable,
               credits: Optional[int] = None) -> None:
        # A fresh trial starts with the full lookahead grant; a worker
        # respawned mid-decision (resize) starts with 0 — the k un-consumed
        # results' CONTINUEs re-grant the window one resume at a time.
        ws = _WorkerState(trial, trainable, self.clock,
                          credits=self.lookahead if credits is None else credits)
        ws.thread = threading.Thread(
            target=self._worker_main, args=(ws,),
            name=f"repro-worker-{trial.trial_id}", daemon=True)
        self._workers[trial.trial_id] = ws
        trial.set_status(TrialStatus.RUNNING)
        ws.thread.start()
        # Roster handshake (see _worker_main): once start_trial returns, the
        # worker counts toward the virtual clock's all-parked check, so time
        # can never advance "around" a thread that is still booting.  A
        # timeout here is pathological (thread never started registering) —
        # fail loudly rather than run with silently nondeterministic time.
        if not ws.registered.wait(timeout=10.0):
            raise RuntimeError(
                f"worker thread for {trial.trial_id} failed to enroll with "
                "the clock within 10s")

    def _acquire_and_build(
        self, trial: Trial, state: Any = None, iteration: int = 0
    ) -> Optional[Trainable]:
        """Acquire resources + slice and build the trainable (restoring
        ``state`` first, so a worker can never step before the restore lands);
        on any failure roll back the acquisition and mark the trial ERROR."""
        self._acquire_slice(trial)
        try:
            with self.obs.tracer.span("build", trial.trial_id, cat="lifecycle"):
                trainable = self._instantiate(trial)
                if state is not None:
                    trainable.restore(state)
                    trainable.iteration = iteration
            return trainable
        except Exception:
            self._release(trial)
            trial.error = traceback.format_exc()
            trial.set_status(TrialStatus.ERROR)
            return None

    def start_trial(self, trial: Trial, checkpoint: Optional[Checkpoint] = None) -> bool:
        if not self.has_resources(trial):
            return False
        state, iteration = None, 0
        if checkpoint is not None:
            try:
                with self.obs.tracer.span("ckpt.restore", trial.trial_id,
                                          cat="ckpt",
                                          iteration=checkpoint.training_iteration):
                    p0 = _perf()
                    with self._ckpt_lock:
                        state = self.ckpt.restore(checkpoint)
                if self._m_ckpt_restore is not None:
                    self._m_ckpt_restore.observe((_perf() - p0) * 1e6)
            except Exception:
                trial.error = traceback.format_exc()
                trial.set_status(TrialStatus.ERROR)
                return False
            iteration = checkpoint.training_iteration
        trainable = self._acquire_and_build(trial, state, iteration)
        if trainable is None:
            return False
        if checkpoint is not None:
            checkpoint.pinned = False  # consumed; rotation may reclaim it
        self._spawn(trial, trainable)
        return True

    def _halt(self, ws: _WorkerState) -> bool:
        """Stop the worker thread and wait for it to exit (runner thread only).
        Returns False when the join timed out — the worker is still inside a
        straggling step and must be treated as abandoned."""
        ws.stop.set()
        ws.credits.release()  # wake a parked worker; it re-checks stop first
        if ws.thread is not None and ws.thread.is_alive():
            # clock.join_thread, not thread.join: under virtual time the
            # worker may be asleep inside its step, and only the clock can
            # run that sleep down while we wait.
            return self.clock.join_thread(ws.thread, timeout=self.join_timeout)
        return True

    def _reap(self, trial: Trial) -> Optional[_WorkerState]:
        """Halt + remove the worker, clean up the trainable, release resources.

        An abandoned worker (join timed out mid-step) keeps its resources and
        slice leaked on purpose: the thread is still dispatching on that
        sub-mesh, and releasing it would let a new trial step on the same
        devices concurrently."""
        ws = self._workers.pop(trial.trial_id, None)
        if ws is None:
            return None
        if not self._halt(ws):
            return ws
        try:
            ws.trainable.cleanup()
        except Exception:  # noqa: BLE001
            pass
        self._release(trial)
        return ws

    # -- checkpoints ------------------------------------------------------------------
    def _save_locked(self, ws: _WorkerState) -> Checkpoint:
        """Caller holds ws.lock (or the thread is joined)."""
        p0 = _perf()
        state = ws.trainable.save()
        with self._ckpt_lock:
            ckpt = self.ckpt.save(ws.trial.trial_id, ws.trainable.iteration, state)
        if self._m_ckpt_save is not None:
            self._m_ckpt_save.observe((_perf() - p0) * 1e6)
        ws.trial.checkpoint = ckpt
        return ckpt

    def save_checkpoint(self, trial: Trial) -> Checkpoint:
        ws = self._workers[trial.trial_id]
        # Never block bare on ws.lock: a worker mid-step holds it while
        # parked in clock.sleep, and a runnable-but-OS-blocked runner would
        # freeze virtual time (the worker's step could then never finish).
        # Pacing the acquisition through the clock lets virtual time run the
        # in-flight step down while we wait; on the wall clock the contended
        # path degrades to a 5ms poll of a lock held for a full step anyway.
        while not ws.lock.acquire(blocking=False):
            self.clock.sleep(0.005)
        try:
            return self._save_locked(ws)
        finally:
            ws.lock.release()

    # -- runner-driven transitions -------------------------------------------------
    def resume_trial(self, trial: Trial) -> None:
        ws = self._workers.get(trial.trial_id)
        if ws is not None and not ws.dead:
            ws.granted += 1
            ws.credits.release()

    def trial_idle(self, trial: Trial) -> bool:
        ws = self._workers.get(trial.trial_id)
        return ws is not None and not ws.dead and ws.parked

    def resize_trial(self, trial: Trial, new_devices: int) -> bool:
        """Checkpoint-boundary slice resize (DESIGN.md §6): the worker is
        parked at the credit gate, so halting it is immediate.  The rebuild
        core (`_resize_rebuild`) rolls back to the exact old slice on any
        failure, in which case the old trainable is respawned — the trial
        never observes a torn state."""
        ws = self._workers.get(trial.trial_id)
        if (ws is None or ws.dead or self.slice_pool is None
                or new_devices == trial.resources.devices
                or not ws.parked):
            return False
        # The worker is parked (no granted-but-unpublished steps), so once
        # stop is set its only remaining action is the side-effect-free
        # stop-check right after the credit gate — it can never touch the
        # trainable again.  Even a starved join (timeout) is therefore safe
        # to proceed past; the thread exits on its own without stepping.
        self._halt(ws)
        del self._workers[trial.trial_id]  # resources stay acquired
        new_trainable = self._resize_rebuild(trial, ws.trainable, new_devices)
        # Respawn with 0 credits: at this boundary exactly k results are
        # un-consumed (credits granted = k + consumed, all stepped), and each
        # of their CONTINUEs — starting with the resume_trial that follows
        # this resize — grants one credit, restoring the k-wide window.
        # Seeding more here would inflate it past k.
        self._spawn(trial, new_trainable if new_trainable is not None
                    else ws.trainable, credits=0)
        return new_trainable is not None

    def pause_trial(self, trial: Trial) -> None:
        ws = self._workers.get(trial.trial_id)
        if ws is not None:
            joined = self._halt(ws)
            if joined and not ws.dead:
                self._save_locked(ws)  # safe: thread exited, no torn state
            self._reap(trial)
        trial.set_status(TrialStatus.PAUSED)

    def stop_trial(self, trial: Trial, error: Optional[str] = None) -> None:
        self._reap(trial)
        if error:
            trial.error = error
            trial.set_status(TrialStatus.ERROR)
        else:
            trial.set_status(TrialStatus.TERMINATED)

    def requeue_trial(self, trial: Trial) -> None:
        """Tear down a failed instance, keeping the trial restartable from its
        last checkpoint (the runner's max_failures retry path).  The runner
        logs the RESTARTED event itself — publishing here too would deliver
        every retry twice."""
        self._reap(trial)
        self._set_requeue_status(trial)

    def restart_trial_with_config(
        self, trial: Trial, checkpoint: Checkpoint, new_config: Dict[str, Any]
    ) -> None:
        """PBT exploit: restore donor state under a mutated config.

        The worker is parked at the resume gate when this is called (the
        decision was made on its latest result), so halting it is immediate.
        """
        trial.config = dict(new_config)
        with self._ckpt_lock:
            state = self.ckpt.restore(checkpoint)
        ws = self._workers.get(trial.trial_id)
        if ws is not None:
            joined = self._halt(ws)
            if joined and not ws.dead and ws.trainable.reset_config(new_config):
                ws.trainable.restore(state)
                ws.trainable.iteration = checkpoint.training_iteration
                del self._workers[trial.trial_id]  # resources stay acquired
                self._spawn(trial, ws.trainable)
                return
            self._reap(trial)
            trial.set_status(TrialStatus.PAUSED)
        # Full rebuild with the donor state restored before launch.
        if not self.has_resources(trial):
            trial.checkpoint = checkpoint  # re-queue; next launch restores donor
            trial.set_status(TrialStatus.PAUSED)
            return
        trainable = self._acquire_and_build(
            trial, state, checkpoint.training_iteration)
        if trainable is not None:
            self._spawn(trial, trainable)

    # -- event delivery: BusDrivenExecutor.get_next_event -----------------------------
    def get_trainable(self, trial_id: str) -> Optional[Trainable]:
        ws = self._workers.get(trial_id)
        return ws.trainable if ws is not None else None

    def shutdown(self) -> None:
        self._shutdown_evt.set()
        for trial_id in list(self._workers):
            self._reap(self._workers[trial_id].trial)
        if self._monitor_thread is not None and self._monitor_thread.is_alive():
            self.clock.join_thread(self._monitor_thread, timeout=2.0)
