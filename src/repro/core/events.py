"""Typed trial events on a thread-safe bus — the async execution substrate.

The paper's runner is event-based (§4.2): schedulers react to intermediate
results as they arrive, not in lockstep.  With one executor thread that was
implicit — ``get_next_result()`` polled.  Once trials step concurrently on
worker threads (concurrent_executor.py), events need an explicit carrier:

- ``TrialEvent`` — a typed record (RESULT / ERROR / CHECKPOINTED /
  HEARTBEAT_MISSED / RESTARTED) tagged with the trial id and a bus-assigned
  monotone sequence number.
- ``EventBus`` — a thread-safe FIFO.  ``publish`` is callable from any worker
  thread; sequence assignment and enqueue are atomic, so consumers observe
  events in exactly the order they were sequenced (the ordering contract the
  runner's bookkeeping and the JSONL event log rely on).

Only RESULT and ERROR drive scheduler decisions; the rest are observability
events the runner forwards to loggers (DESIGN.md §4).
"""
from __future__ import annotations

import enum
import itertools
import queue
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from time import perf_counter as _perf

from .clock import Clock, get_default_clock
from .trial import Checkpoint, Result

__all__ = ["EventType", "TrialEvent", "EventBus"]


class EventType(str, enum.Enum):
    RESULT = "RESULT"                      # an intermediate (or final) Result
    ERROR = "ERROR"                        # trainable raised; error carries the traceback
    CHECKPOINTED = "CHECKPOINTED"          # a periodic checkpoint was written
    HEARTBEAT_MISSED = "HEARTBEAT_MISSED"  # a step exceeded the straggler timeout
    RESTARTED = "RESTARTED"                # trial re-queued for restart-from-checkpoint
    KILLED = "KILLED"                      # straggling worker process SIGKILLed (DESIGN.md §5)
    RESIZED = "RESIZED"                    # elastic slice resize applied (DESIGN.md §6)
    RESIZE_FAILED = "RESIZE_FAILED"        # resize rejected/rolled back; trial keeps its old slice
    CREDITS = "CREDITS"                    # lookahead credit grant changed for a trial
    SPAN = "SPAN"                          # batch of trace spans from a worker (repro.obs)
    PROFILE = "PROFILE"                    # per-trial hardware profile (repro.obs, §9)
    DECISION = "DECISION"                  # scheduler/searcher verdict + inputs (DESIGN.md §10)


@dataclass
class TrialEvent:
    type: EventType
    trial_id: str
    result: Optional[Result] = None        # RESULT
    error: Optional[str] = None            # ERROR (formatted traceback)
    checkpoint: Optional[Checkpoint] = None  # CHECKPOINTED
    info: Dict[str, Any] = field(default_factory=dict)
    # Stamped by the bus on publish (or by whoever hands the event straight
    # to a logger); None = "not yet stamped", loggers fall back to their own
    # clock so an unstamped event still gets a usable time.
    timestamp: Optional[float] = None
    seq: int = -1                          # assigned by the bus on publish
    # Real (perf_counter) publish stamp, set only when the bus carries a
    # metrics registry: fan-in latency = how long an event sat queued before
    # the runner drained it.  Profiling only — never on the virtual axis.
    _mono_pub: Optional[float] = None


class EventBus:
    """Thread-safe FIFO of ``TrialEvent``s with atomic sequence numbering.

    Multiple producers (executor worker threads, the heartbeat monitor) and a
    single consumer (the runner's event loop).  ``publish`` holds one lock
    across seq assignment *and* enqueue, so ``seq`` order equals delivery
    order even under concurrent publishers.

    All timing runs through the injected ``Clock`` (DESIGN.md §7): publish
    stamps ``event.timestamp`` from it, blocking ``get`` parks through it (so
    a consumer on a ``VirtualClock`` wakes in virtual time), and publish
    ``kick``s the clock so parked virtual waiters re-check the queue.
    """

    def __init__(self, maxsize: int = 0, clock: Optional[Clock] = None,
                 metrics: Optional[Any] = None):
        self._q: "queue.Queue[TrialEvent]" = queue.Queue(maxsize=maxsize)
        self._lock = threading.Lock()
        self._seq = itertools.count()
        self.clock = clock or get_default_clock()
        self.n_published = 0
        # Hot-path discipline (repro.obs): resolve instruments once; with no
        # registry every publish/get pays a single None test.
        if metrics is not None:
            self._m_pub = metrics.counter("bus.published")
            self._m_depth = metrics.gauge("bus.depth")
            self._m_fanin = metrics.histogram("bus.fanin_us")
        else:
            self._m_pub = self._m_depth = self._m_fanin = None

    def publish(self, event: TrialEvent) -> TrialEvent:
        with self._lock:
            event.seq = next(self._seq)
            if event.timestamp is None:
                event.timestamp = self.clock.time()
            self._q.put(event)
            self.n_published += 1
        if self._m_pub is not None:
            self._m_pub.inc()
            self._m_depth.set(self._q.qsize())
            event._mono_pub = _perf()
        self.clock.kick(self._q)  # wake a virtual consumer parked on this queue
        return event

    def get(self, timeout: Optional[float] = None) -> Optional[TrialEvent]:
        """Next event, or None after ``timeout`` seconds (None = non-blocking)."""
        if timeout is not None:
            ev = self.clock.queue_get(self._q, timeout)
        else:
            try:
                ev = self._q.get_nowait()
            except queue.Empty:
                return None
        if ev is not None and self._m_fanin is not None and ev._mono_pub is not None:
            self._m_fanin.observe((_perf() - ev._mono_pub) * 1e6)
        return ev

    def drain(self) -> List[TrialEvent]:
        """All currently queued events, in order, without blocking."""
        out: List[TrialEvent] = []
        while True:
            ev = self.get()
            if ev is None:
                return out
            out.append(ev)

    def __len__(self) -> int:
        return self._q.qsize()

    def empty(self) -> bool:
        return self._q.empty()
