"""Trial, Result and Checkpoint — the paper's §3 vocabulary.

A *trial* is a single training run with a fixed initial hyperparameter
configuration; an *experiment* is a collection of trials supervised by a trial
scheduler.  Trials carry:

- ``config``     — the hyperparameter map handed to the trainable,
- ``status``     — PENDING / RUNNING / PAUSED / TERMINATED / ERROR,
- ``resources``  — the slice request (see resources.py),
- a result history (intermediate results are first-class: schedulers make
  early-stopping / cloning / mutation decisions from them),
- the latest checkpoint reference (fault tolerance is checkpoint-based; trial
  metadata itself lives in memory, per the paper §4.2).
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from .clock import get_default_clock
from .resources import Resources

__all__ = ["Trial", "TrialStatus", "Result", "Checkpoint"]

_trial_counter = itertools.count()


class TrialStatus(str, enum.Enum):
    PENDING = "PENDING"
    RUNNING = "RUNNING"
    PAUSED = "PAUSED"
    TERMINATED = "TERMINATED"
    ERROR = "ERROR"

    def is_finished(self) -> bool:
        return self in (TrialStatus.TERMINATED, TrialStatus.ERROR)


@dataclass
class Result:
    """One intermediate (or final) report from a trial.

    ``metrics`` carries whatever the user reported (``tune.report(...)``).
    ``training_iteration`` is maintained by the framework and is the canonical
    resource/rung axis for HyperBand/ASHA/median-stopping.
    """

    trial_id: str
    training_iteration: int
    metrics: Dict[str, Any]
    # Executors stamp results from their injected Clock; the default factory
    # covers Results built outside an executor (tests, ad-hoc tooling).
    timestamp: float = field(default_factory=lambda: get_default_clock().time())
    done: bool = False

    def value(self, metric: str) -> float:
        if metric == "training_iteration":
            return float(self.training_iteration)
        v = self.metrics[metric]
        return float(v)


@dataclass
class Checkpoint:
    """A reference to saved trial state (object-store key or disk path).

    ``pinned`` marks a checkpoint a scheduler has staged for later use (e.g. a
    PBT donor awaiting exploit): the CheckpointManager's ``keep_last`` rotation
    keeps both the store entry and the disk mirror alive while it is set.
    """

    trial_id: str
    training_iteration: int
    store_key: Optional[str] = None
    path: Optional[str] = None
    pinned: bool = False

    @property
    def location(self) -> str:
        return self.store_key or self.path or "<empty>"


class Trial:
    def __init__(
        self,
        config: Dict[str, Any],
        trainable_name: str = "trainable",
        resources: Optional[Resources] = None,
        stopping_criteria: Optional[Dict[str, float]] = None,
        tag: str = "",
        trial_id: Optional[str] = None,
    ):
        self.trial_id = trial_id or f"{trainable_name}_{next(_trial_counter):05d}"
        self.trainable_name = trainable_name
        self.config = dict(config)
        self.resources = resources or Resources()
        self.stopping_criteria = dict(stopping_criteria or {})
        self.tag = tag
        self._status = TrialStatus.PENDING
        # Status-transition hook (runner's indexed ready-queue).  Installed by
        # TrialRunner.add_trial; every assignment to ``status`` notifies it, so
        # the index can never drift from the attribute.  Dropped on pickle
        # (__getstate__) — it closes over the runner.
        self._status_listener = None
        self.results: List[Result] = []
        self.checkpoint: Optional[Checkpoint] = None
        self.error: Optional[str] = None
        # Hardware profile published by the trainable (repro.obs, DESIGN.md
        # §9): compile/steady step-time split, device-memory bytes, roofline
        # tag.  None until the first profiled result arrives.
        self.profile: Optional[Dict[str, Any]] = None
        self.num_failures = 0  # restarts consumed against the runner's max_failures
        self.start_time: Optional[float] = None
        # bookkeeping for schedulers (e.g. PBT perturbation history)
        self.scheduler_state: Dict[str, Any] = {}
        # Durable resume (DESIGN.md §12): virtual-clock phase target.  A
        # restored trial's worker sleeps the clock to this point before its
        # first step, so post-resume results land at the same virtual
        # timestamps — and hence in the same cross-trial order — as in the
        # uninterrupted run.  Consumed (reset to None) by the executor on the
        # trial's first post-resume step.
        self.resume_phase_t: Optional[float] = None

    # -- status ----------------------------------------------------------------
    @property
    def status(self) -> TrialStatus:
        return self._status

    @status.setter
    def status(self, value: TrialStatus) -> None:
        old = self._status
        self._status = value
        if self._status_listener is not None and old is not value:
            self._status_listener(self, old, value)

    def __getstate__(self) -> Dict[str, Any]:
        # The listener is a bound method of the owning runner — unpicklable
        # and wrong to resurrect (a resumed run re-attaches via add_trial).
        state = self.__dict__.copy()
        state["_status_listener"] = None
        return state

    # -- result bookkeeping ---------------------------------------------------
    @property
    def last_result(self) -> Optional[Result]:
        return self.results[-1] if self.results else None

    @property
    def training_iteration(self) -> int:
        return self.results[-1].training_iteration if self.results else 0

    def record_result(self, result: Result) -> None:
        self.results.append(result)

    def best_value(self, metric: str, mode: str = "max") -> Optional[float]:
        vals = [r.value(metric) for r in self.results if metric in r.metrics]
        if not vals:
            return None
        return max(vals) if mode == "max" else min(vals)

    def should_stop(self, result: Result) -> bool:
        """Check user-provided stopping criteria (e.g. max iterations, target acc)."""
        for metric, bound in self.stopping_criteria.items():
            if metric == "training_iteration":
                if result.training_iteration >= bound:
                    return True
            elif metric in result.metrics and result.value(metric) >= bound:
                return True
        return False

    def set_status(self, status: TrialStatus) -> None:
        if self.status.is_finished() and status == TrialStatus.RUNNING:
            raise RuntimeError(f"cannot restart finished trial {self.trial_id}")
        if status == TrialStatus.RUNNING and self.start_time is None:
            # Trials are constructed by user code long before an executor
            # exists, so they read the module-default clock rather than an
            # injected one — use_clock(...) places them on virtual time.
            self.start_time = get_default_clock().time()
        self.status = status

    def __repr__(self) -> str:
        return (
            f"Trial({self.trial_id}, {self.status.value}, iter={self.training_iteration}"
            + (f", tag={self.tag}" if self.tag else "")
            + ")"
        )
