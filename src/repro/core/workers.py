"""Process-based trial workers — the Ray-actor analogue as real OS processes.

The thread tier (concurrent_executor.py) overlaps *device* work, but host-side
trainable code still serializes on the GIL, and a hung step can only be
abandoned — its thread (and SlicePool slice) leak forever.  This module gives
each trial its own **spawned process**, driven over a pipe with a small command
protocol; because it is a process, the host can ``SIGKILL`` it and reclaim the
slice (DESIGN.md §5).

Three pieces:

- ``TrainableFactory`` — a *spawn-safe* recipe for rebuilding the trainable in
  the child: an importable ``"module:attr"`` target (optionally called with
  args/kwargs to produce the class) plus sys.path entries.  Nothing live
  crosses the boundary — the child re-imports and re-builds.
  ``register_worker_factory``/``resolve_worker_factory`` is the process-tier
  registry mirroring ``register_trainable``.
- The command protocol — parent sends ``STEP`` / ``SAVE`` / ``RESTORE`` /
  ``RESET_CONFIG`` / ``RESIZE`` / ``STOP``; the child replies ``READY`` /
  ``RESULT`` / ``CHECKPOINTED`` / ``SAVED`` / ``RESTORED`` / ``RESET`` /
  ``RESIZED`` / ``STOPPED`` / ``ERROR``.  Checkpoint **bytes**
  (``checkpoint.tree_to_bytes``) travel through the spill surface of an
  ``ObjectStore`` both sides point at — only keys cross the pipe, and no live
  JAX object is ever pickled.  ``RESIZE`` rebuilds the trainable in place
  over a new mesh slice (elastic tier, DESIGN.md §6) without paying a
  process teardown; the parent may also queue up to *k* STEP commands at
  once (lookahead credits) — the pipe itself is the resume gate, so a
  queued STEP costs the child no round-trip wait.
- ``ProcessWorker`` — the parent-side handle: spawn, thread-safe send, kill,
  join.  The child is started with the ``spawn`` method (fork is unsafe once
  JAX/XLA threads exist) and is a daemon, so a dying host reaps its workers.

This module (and everything it imports) stays jax-free at import time: a
worker whose trainable never touches device arrays boots in fractions of a
second instead of paying the jax import.
"""
from __future__ import annotations

import importlib
import itertools
import multiprocessing as mp
import os
import sys
import threading
import time as _time
import traceback
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from .object_store import ObjectStore

__all__ = [
    "TrainableFactory", "register_worker_factory", "resolve_worker_factory",
    "factory_from_class", "ProcessWorker",
    "CMD_STEP", "CMD_SAVE", "CMD_RESTORE", "CMD_RESET_CONFIG", "CMD_RESIZE",
    "CMD_STOP",
]

# parent -> child commands
CMD_STEP = "STEP"
CMD_SAVE = "SAVE"
CMD_RESTORE = "RESTORE"
CMD_RESET_CONFIG = "RESET_CONFIG"
CMD_RESIZE = "RESIZE"
CMD_STOP = "STOP"

# child -> parent messages
MSG_READY = "READY"
MSG_RESULT = "RESULT"
MSG_CHECKPOINTED = "CHECKPOINTED"
MSG_SAVED = "SAVED"
MSG_RESTORED = "RESTORED"
MSG_RESET = "RESET"
MSG_RESIZED = "RESIZED"
MSG_STOPPED = "STOPPED"
MSG_ERROR = "ERROR"
MSG_SPANS = "SPANS"  # batch of trace spans (repro.obs wire tuples)


@dataclass(frozen=True)
class TrainableFactory:
    """Spawn-safe recipe for building a trainable class in a worker process.

    ``target`` is ``"module:attr"`` (dots allowed in ``attr``).  With
    ``call=True`` the imported attr is called with ``args``/``kwargs`` and must
    return the Trainable class (the ``make_model_trainable`` pattern);
    otherwise the attr *is* the class.  ``sys_path`` entries are prepended in
    the child before the import — how test-local and script-local trainables
    become importable from a fresh interpreter.
    """

    target: str
    args: Tuple[Any, ...] = ()
    kwargs: Dict[str, Any] = field(default_factory=dict)
    call: bool = False
    sys_path: Tuple[str, ...] = ()

    def resolve(self) -> type:
        for p in reversed(self.sys_path):
            if p and p not in sys.path:
                sys.path.insert(0, p)
        mod_name, _, attr = self.target.partition(":")
        if not attr:
            raise ValueError(f"factory target must be 'module:attr', got {self.target!r}")
        obj: Any = importlib.import_module(mod_name)
        for part in attr.split("."):
            obj = getattr(obj, part)
        if self.call:
            obj = obj(*self.args, **dict(self.kwargs))
        return obj


_WORKER_REGISTRY: Dict[str, TrainableFactory] = {}


def register_worker_factory(name: str, factory: TrainableFactory) -> None:
    """Register a spawn-safe factory under ``name`` (the process-tier analogue
    of ``register_trainable``)."""
    if not isinstance(factory, TrainableFactory):
        raise TypeError(f"expected a TrainableFactory, got {type(factory)}")
    _WORKER_REGISTRY[name] = factory


def resolve_worker_factory(name: str) -> TrainableFactory:
    try:
        return _WORKER_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no worker factory registered for trainable {name!r}; process "
            "workers rebuild the trainable in a fresh interpreter, so register "
            "a spawn-safe recipe with register_worker_factory(name, "
            "TrainableFactory(...)) (for model trainables use "
            "train.trainable.model_trainable_factory)")


def factory_from_class(cls: type) -> Optional[TrainableFactory]:
    """A factory referencing ``cls`` by import path, or None when the class is
    not importable from a fresh interpreter (local classes, ``wrap_function``
    products — those need an explicit factory)."""
    qualname = getattr(cls, "__qualname__", "")
    module = getattr(cls, "__module__", "")
    if not module or not qualname or "<locals>" in qualname or module == "__main__":
        return None
    return TrainableFactory(target=f"{module}:{qualname}")


# ---------------------------------------------------------------------------------
# child side
# ---------------------------------------------------------------------------------

def _child_store(spec: Dict[str, Any]) -> ObjectStore:
    # Tiny in-memory footprint: the child's store exists only as a window onto
    # the shared spill directory; checkpoint bytes go straight to disk.
    return ObjectStore(capacity_bytes=1 << 20, spill_dir=spec["spill_dir"])


def _decode_state(state: Any) -> Any:
    if isinstance(state, (bytes, bytearray)):
        from .checkpoint import tree_from_bytes
        return tree_from_bytes(bytes(state))
    return state  # a live pytree put there by an in-host executor


def _consume_key(store: ObjectStore, key: str) -> None:
    """Private export-copy payloads (CheckpointManager.export_copy) are
    one-shot: delete after a successful restore so spill files don't pile up.
    Shared keys (a trial's own checkpoints) are left alone."""
    if key.startswith("export/"):
        try:
            store.delete(key)
        except OSError:
            pass


def _child_main(conn, spec: Dict[str, Any]) -> None:
    """Worker process entry: build the trainable, then serve the command loop.

    Every reply is sent before blocking on the next command; the parent's
    resume gate is simply "don't send STEP yet", and lookahead credits are
    simply "queue up to k STEPs" — the child itself never changes behavior,
    it just stops idling between a RESULT and the next command.

    ``conn`` is any Transport: an object with ``send(obj)`` / ``recv()`` /
    ``poll(timeout)`` / ``close()``.  The pipe tier passes a multiprocessing
    Connection; the cluster tier passes a framed SocketTransport whose closed/
    corrupt-peer errors subclass EOFError/OSError, so the exception handling
    below needs no transport-specific branches (repro.cluster.transport).
    """
    trial_id = spec["trial_id"]
    checkpoint_freq = int(spec.get("checkpoint_freq", 0))
    # Child-side tracing (repro.obs): spans are buffered and shipped as ONE
    # MSG_SPANS before the reply they annotate, so the parent's pump adopts
    # them onto the trial's trace row before processing the result.  The
    # child has no injected clock — timestamps are wall time; the process
    # tier never runs under a VirtualClock (DESIGN.md §5/§8).
    trace_on = bool(spec.get("trace"))
    spans: list = []

    def _flush_spans() -> None:
        if spans:
            conn.send((MSG_SPANS, list(spans)))
            spans.clear()

    try:
        nice = int(spec.get("nice", 0))
        if nice > 0 and hasattr(os, "nice"):
            # Data-plane yields to control-plane: trial compute saturates the
            # cores, but the host's pump/runner threads must preempt instantly
            # to turn a RESULT into the next STEP, or every worker idles at
            # the gate for an OS scheduling quantum per step.
            os.nice(nice)
        t_build = _time.time()
        store = _child_store(spec)
        cls = spec["factory"].resolve()
        trainable = cls(dict(spec["config"]))
        restore_key = spec.get("restore_key")
        if restore_key:
            t_res = _time.time()
            trainable.restore(_decode_state(store.get(restore_key)))
            trainable.iteration = int(spec.get("restore_iteration", 0))
            _consume_key(store, restore_key)
            if trace_on:
                spans.append(("ckpt.restore", t_res, _time.time() - t_res,
                              "ckpt", "worker",
                              {"iteration": trainable.iteration}))
        if trace_on:
            spans.append(("build", t_build, _time.time() - t_build,
                          "lifecycle", "worker", {"pid": os.getpid()}))
            _flush_spans()
        conn.send((MSG_READY, os.getpid()))
    except BaseException:  # noqa: BLE001 — report the build failure, then exit
        try:
            conn.send((MSG_ERROR, traceback.format_exc()))
        except (BrokenPipeError, OSError):
            pass
        return

    save_seq = itertools.count()

    content_addressed = bool(spec.get("cas"))

    def _save_bytes() -> str:
        from .checkpoint import tree_to_bytes
        t0 = _time.time()
        data = tree_to_bytes(trainable.save())
        if content_addressed:
            # Cluster tier: the key IS the payload digest (scoped per trial so
            # keep_last rotation of one trial can never delete another trial's
            # identical bytes).  The controller re-derives the digest after
            # fetching across hosts — a torn or tampered spill file fails the
            # fetch instead of restoring garbage — and identical re-saves
            # (PBT rewinds) dedupe to one spill file.
            import hashlib
            key = f"cas/{trial_id}/{hashlib.sha256(data).hexdigest()}"
        else:
            # Key is unique per save, not just per iteration: a PBT rewind
            # makes a worker re-reach the same iteration and save again, and
            # reusing the key would let the host's LRU serve the stale first
            # payload (and let keep_last rotation of the old Checkpoint delete
            # the new one's data).
            key = (f"ckpt/{trial_id}/{trainable.iteration}."
                   f"{os.getpid()}.{next(save_seq)}")
        key = store.put_spilled(data, key=key)
        if trace_on:
            spans.append(("ckpt.save", t0, _time.time() - t0, "ckpt",
                          "worker", {"iteration": trainable.iteration,
                                     "bytes": len(data)}))
        return key

    done_seen = False
    queued_steps = 0
    stashed = None  # one control command held back behind queued STEPs
    try:
        while True:
            # Lookahead credits queue STEPs in the pipe; count them instead
            # of executing on receipt.  A STOP sent behind k-1 credited STEPs
            # preempts them (teardown beats doomed compute), but every OTHER
            # control command keeps FIFO order with the queued STEPs: a SAVE
            # must observe the state *after* the steps queued before it —
            # the parent relies on that drain-barrier during a resize, and
            # jumping the queue would make the later RESTORE rewind results
            # already produced (duplicate iterations).
            msg = None
            while msg is None:
                if stashed is not None and not queued_steps:
                    msg, stashed = stashed, None
                    break
                if queued_steps and not conn.poll(0):
                    queued_steps -= 1
                    if done_seen:
                        # Credits queued behind a final result: stepping a
                        # finished trainable would be an error; drop them.
                        continue
                    try:
                        t_step = _time.time()
                        metrics = dict(trainable.train())
                        if trace_on:
                            spans.append(("step", t_step,
                                          _time.time() - t_step, "train",
                                          "worker",
                                          {"iteration": trainable.iteration}))
                        done = bool(metrics.pop("done", False))
                        if (checkpoint_freq and not done
                                and trainable.iteration % checkpoint_freq == 0):
                            conn.send((MSG_CHECKPOINTED, _save_bytes(),
                                       trainable.iteration))
                    except Exception:  # noqa: BLE001 — trial, not framework, error
                        conn.send((MSG_ERROR, traceback.format_exc()))
                        return
                    done_seen = done
                    _flush_spans()
                    conn.send((MSG_RESULT, trainable.iteration, metrics, done))
                    continue
                nxt = conn.recv()
                if nxt[0] == CMD_STEP:
                    queued_steps += 1
                elif nxt[0] == CMD_STOP or not queued_steps:
                    msg = nxt
                else:
                    stashed = nxt  # at most one: sync exchanges are serial
            # Only control commands reach the dispatch: the receive loop
            # above counts STEPs into queued_steps and never yields one.
            cmd = msg[0]
            if cmd == CMD_RESIZE:
                # Elastic slice resize (DESIGN.md §6): rebuild the trainable
                # over the new mesh window and restore the just-saved state —
                # all inside this warm process, no teardown.  Failure is
                # NON-fatal: the old trainable keeps serving and the parent
                # rolls the pool back (trial falls back to its old slice).
                _, new_config, key, iteration = msg
                resized = None
                try:
                    state = _decode_state(store.get(key))
                    resized = cls(dict(new_config))
                    resized.restore(state)
                    resized.iteration = int(iteration)
                except Exception:  # noqa: BLE001 — keep the old trainable
                    if resized is not None:  # built but failed to restore
                        try:
                            resized.cleanup()
                        except Exception:  # noqa: BLE001
                            pass
                    conn.send((MSG_RESIZED, False, traceback.format_exc()))
                else:
                    old = trainable
                    trainable = resized
                    try:
                        old.cleanup()
                    except Exception:  # noqa: BLE001
                        pass
                    conn.send((MSG_RESIZED, True, None))
            elif cmd == CMD_SAVE:
                try:
                    key = _save_bytes()
                    _flush_spans()
                    conn.send((MSG_SAVED, key, trainable.iteration))
                except Exception:  # noqa: BLE001
                    conn.send((MSG_ERROR, traceback.format_exc()))
                    return
            elif cmd == CMD_RESTORE:
                _, key, iteration = msg
                try:
                    t_res = _time.time()
                    trainable.restore(_decode_state(store.get(key)))
                    trainable.iteration = int(iteration)
                    _consume_key(store, key)
                    if trace_on:
                        spans.append(("ckpt.restore", t_res,
                                      _time.time() - t_res, "ckpt", "worker",
                                      {"iteration": int(iteration)}))
                        _flush_spans()
                    conn.send((MSG_RESTORED, int(iteration)))
                except Exception:  # noqa: BLE001
                    conn.send((MSG_ERROR, traceback.format_exc()))
                    return
            elif cmd == CMD_RESET_CONFIG:
                _, new_config = msg
                try:
                    ok = bool(trainable.reset_config(dict(new_config)))
                    if ok:
                        trainable.config = dict(new_config)
                except Exception:  # noqa: BLE001
                    conn.send((MSG_ERROR, traceback.format_exc()))
                    return
                conn.send((MSG_RESET, ok))
            elif cmd == CMD_STOP:
                try:
                    trainable.cleanup()
                except Exception:  # noqa: BLE001
                    pass
                conn.send((MSG_STOPPED,))
                return
            else:
                conn.send((MSG_ERROR, f"unknown worker command {cmd!r}"))
                return
    except (EOFError, KeyboardInterrupt, BrokenPipeError, OSError):
        # parent vanished or killed us mid-send; nothing left to report to
        return
    finally:
        try:
            conn.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------------
# parent side
# ---------------------------------------------------------------------------------

_DEFAULT_CTX: Optional[Any] = None


def _default_context():
    """The cheapest safe multiprocessing context on this platform.

    Preferred: ``forkserver`` with this module preloaded — the server process
    imports repro.core once, then every worker is a ~tens-of-ms fork of that
    warm, thread-free image (fork is safe there: the server never starts JAX
    or any thread).  Plain ``fork`` from the *host* is NOT safe — the host has
    JAX/XLA and executor threads — and plain ``spawn`` re-imports the host's
    ``__main__`` plus the whole stack in every single worker (~1-2s per
    trial).  Falls back to ``spawn`` where forkserver is unavailable.
    """
    global _DEFAULT_CTX
    if _DEFAULT_CTX is None:
        try:
            ctx = mp.get_context("forkserver")
            ctx.set_forkserver_preload(["repro.core.workers"])
            _DEFAULT_CTX = ctx
        except ValueError:  # platform without forkserver
            _DEFAULT_CTX = mp.get_context("spawn")
    return _DEFAULT_CTX


class ProcessWorker:
    """Parent-side handle on one spawned trial worker.

    ``send`` is thread-safe (the executor's pump thread kicks READY workers
    while the runner thread drives lifecycle commands).  ``kill`` is the
    reclamation path the thread tier cannot offer: SIGKILL, join, done —
    whatever the child was stuck in, its slice is free again.
    """

    def __init__(
        self,
        factory: TrainableFactory,
        trial_id: str,
        config: Dict[str, Any],
        spill_dir: str,
        checkpoint_freq: int = 0,
        restore_key: Optional[str] = None,
        restore_iteration: int = 0,
        mp_context: Optional[str] = None,
        nice: int = 1,
        trace: bool = False,
    ):
        spec = {
            "factory": factory,
            "trial_id": trial_id,
            "config": config,
            "spill_dir": spill_dir,
            "checkpoint_freq": checkpoint_freq,
            "restore_key": restore_key,
            "restore_iteration": restore_iteration,
            "nice": nice,
            "trace": trace,
        }
        ctx = mp.get_context(mp_context) if mp_context else _default_context()
        self.conn, child_conn = ctx.Pipe(duplex=True)
        # A duplex Pipe Connection already satisfies the Transport surface
        # (send/recv/poll/close + itself as the waitable): ``transport`` is
        # what the executor pump multiplexes on, and subclasses (the cluster
        # tier's socket workers) swap in a framed SocketTransport without the
        # pump or ``_child_main`` noticing.
        self.transport: Any = self.conn
        self.process = ctx.Process(
            target=_child_main, args=(child_conn, spec),
            name=f"repro-worker-{trial_id}", daemon=True)
        self._send_lock = threading.Lock()
        self.process.start()
        child_conn.close()  # child end belongs to the child now

    @property
    def pid(self) -> Optional[int]:
        return self.process.pid

    def alive(self) -> bool:
        return self.process.is_alive()

    def send(self, *msg: Any) -> bool:
        """Best-effort command send; False when the transport is already
        dead.  EOFError covers framed transports signalling a closed peer."""
        try:
            with self._send_lock:
                self.transport.send(msg)
            return True
        except (BrokenPipeError, OSError, ValueError, EOFError):
            return False

    def join(self, timeout: Optional[float] = None) -> bool:
        self.process.join(timeout=timeout)
        return not self.process.is_alive()

    def kill(self, join_timeout: float = 5.0) -> None:
        """SIGKILL the worker and reap it.  Unlike an abandoned thread, this
        *reclaims* the straggler: the process is gone, so its sub-mesh can be
        handed to another trial immediately."""
        try:
            self.process.kill()
        except (OSError, AttributeError, ValueError):
            pass
        self.process.join(timeout=join_timeout)
        self.close()

    def close(self) -> None:
        try:
            self.transport.close()
        except OSError:
            pass
