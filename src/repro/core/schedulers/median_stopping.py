"""Median Stopping Rule (Golovin et al. 2017, Google Vizier; paper Table 1).

Stop trial t at step s if t's best objective up to s is strictly worse than the
median of the *running averages* of all completed/ongoing trials' objectives
reported up to step s.  A grace period and a minimum number of reference trials
guard cold starts.
"""
from __future__ import annotations

from typing import Any, Dict, List

import numpy as np

from ..trial import Result, Trial
from .base import SchedulerDecision, TrialScheduler

__all__ = ["MedianStoppingRule"]


class MedianStoppingRule(TrialScheduler):
    def __init__(
        self,
        metric: str = "loss",
        mode: str = "min",
        grace_period: int = 1,
        min_samples_required: int = 3,
        hard_stop: bool = True,
    ):
        super().__init__(metric=metric, mode=mode)
        self.grace_period = grace_period
        self.min_samples_required = min_samples_required
        self.hard_stop = hard_stop
        # trial_id -> list of scores in report order (higher = better)
        self._scores: Dict[str, List[float]] = {}
        self.n_stopped = 0

    def decision_interval(self) -> int:
        # May stop a trial on any post-grace result: exact mode needs
        # lookahead 1.
        return 1

    def _running_avg(self, trial_id: str, upto: int) -> float:
        scores = self._scores[trial_id][:upto]
        return float(np.mean(scores)) if scores else float("-inf")

    def on_result(self, runner, trial: Trial, result: Result) -> SchedulerDecision:
        score = self._score(result.value(self.metric))
        self._scores.setdefault(trial.trial_id, []).append(score)
        step = len(self._scores[trial.trial_id])
        if step <= self.grace_period:
            return SchedulerDecision.CONTINUE

        # Median of other trials' running averages up to the same step.
        others = [
            self._running_avg(tid, step)
            for tid, s in self._scores.items()
            if tid != trial.trial_id and len(s) >= step
        ]
        if len(others) < self.min_samples_required:
            return SchedulerDecision.CONTINUE
        median = float(np.median(others))
        best_so_far = max(self._scores[trial.trial_id])
        if best_so_far < median:
            self.n_stopped += 1
            verdict = SchedulerDecision.STOP if self.hard_stop else SchedulerDecision.PAUSE
        else:
            verdict = SchedulerDecision.CONTINUE
        self._record_decision(trial.trial_id, verdict,
                              iteration=result.training_iteration,
                              reason="median", step=step, score=score,
                              best_so_far=best_so_far, median=median,
                              n_others=len(others),
                              grace_period=self.grace_period,
                              min_samples=self.min_samples_required)
        return verdict

    def state_dict(self) -> Dict[str, Any]:
        return {"scores": {tid: list(s) for tid, s in self._scores.items()},
                "n_stopped": self.n_stopped}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self._scores = {str(tid): [float(v) for v in s]
                        for tid, s in state["scores"].items()}
        self.n_stopped = int(state["n_stopped"])

    def debug_string(self) -> str:
        return f"MedianStoppingRule: {self.n_stopped} trials stopped"
