from .base import SchedulerDecision, TrialScheduler
from .fifo import FIFOScheduler
from .median_stopping import MedianStoppingRule
from .asha import ASHAScheduler, AsyncHyperBandScheduler
from .hyperband import HyperBandScheduler
from .pbt import PopulationBasedTraining
