"""Trial scheduler API — the paper's §4.2 primary interface.

    class TrialScheduler:
        def on_result(self, trial, result): ...
        def choose_trial_to_run(self): ...

Event-based: the runner calls ``choose_trial_to_run`` when resources free up,
and ``on_result`` for every intermediate result; the scheduler returns a flag —
CONTINUE, PAUSE (checkpoint + yield resources), STOP, or RESTART_WITH_CONFIG
(restore from a checkpoint with an updated hyperparameter map — the paper's
"restart a trial with an updated hyperparameter configuration", used by PBT).
"""
from __future__ import annotations

import enum
from typing import List, Optional, TYPE_CHECKING

from ..trial import Result, Trial, TrialStatus

if TYPE_CHECKING:  # pragma: no cover
    from ..runner import TrialRunner

__all__ = ["SchedulerDecision", "TrialScheduler"]


class SchedulerDecision(str, enum.Enum):
    CONTINUE = "CONTINUE"
    PAUSE = "PAUSE"
    STOP = "STOP"
    RESTART_WITH_CONFIG = "RESTART_WITH_CONFIG"  # new config staged on the trial


class TrialScheduler:
    """Base scheduler. Subclasses override on_result / choose_trial_to_run."""

    def __init__(self, metric: str = "loss", mode: str = "min"):
        if mode not in ("min", "max"):
            raise ValueError("mode must be 'min' or 'max'")
        self.metric = metric
        self.mode = mode

    # score such that HIGHER is always better internally
    def _score(self, value: float) -> float:
        return value if self.mode == "max" else -value

    def decision_interval(self) -> int:
        """Decision granularity: how many results may elapse between decisions
        that can stop, pause, or perturb a trial.

        ``0`` means *never* — the scheduler runs every trial to its stopping
        condition (FIFO), so workers may run unbounded result lookahead
        without changing any decision.  ``n >= 1`` means the scheduler may act
        on any result (1) or on every n-th result per trial; the elastic
        tier's ``ResourceBroker`` preserves exactness by clamping lookahead
        credits to 1 whenever the interval is nonzero (DESIGN.md §6).
        Conservative default: 1.
        """
        return 1

    # -- lifecycle events -------------------------------------------------------
    def on_trial_add(self, runner: "TrialRunner", trial: Trial) -> None:
        pass

    def on_trial_error(self, runner: "TrialRunner", trial: Trial) -> None:
        pass

    def on_result(self, runner: "TrialRunner", trial: Trial, result: Result) -> SchedulerDecision:
        """Called for every intermediate result. Default: run to completion."""
        return SchedulerDecision.CONTINUE

    def on_trial_complete(self, runner: "TrialRunner", trial: Trial) -> None:
        pass

    def choose_trial_to_run(self, runner: "TrialRunner") -> Optional[Trial]:
        """Pick the next trial to (re)launch given free resources.

        Default policy: oldest-queued PENDING trial, then oldest-queued PAUSED
        trial, via the runner's status/shape index (one ``has_resources``
        probe per resource shape instead of an O(n) scan — DESIGN.md §9).
        """
        trial = runner.next_ready(TrialStatus.PENDING)
        if trial is not None:
            return trial
        return runner.next_ready(TrialStatus.PAUSED)

    def debug_string(self) -> str:
        return type(self).__name__
