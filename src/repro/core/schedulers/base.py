"""Trial scheduler API — the paper's §4.2 primary interface.

    class TrialScheduler:
        def on_result(self, trial, result): ...
        def choose_trial_to_run(self): ...

Event-based: the runner calls ``choose_trial_to_run`` when resources free up,
and ``on_result`` for every intermediate result; the scheduler returns a flag —
CONTINUE, PAUSE (checkpoint + yield resources), STOP, or RESTART_WITH_CONFIG
(restore from a checkpoint with an updated hyperparameter map — the paper's
"restart a trial with an updated hyperparameter configuration", used by PBT).
"""
from __future__ import annotations

import enum
from collections import deque
from typing import Any, Dict, List, Optional, TYPE_CHECKING

from ..trial import Result, Trial, TrialStatus

if TYPE_CHECKING:  # pragma: no cover
    from ..runner import TrialRunner

__all__ = ["SchedulerDecision", "TrialScheduler"]


class SchedulerDecision(str, enum.Enum):
    CONTINUE = "CONTINUE"
    PAUSE = "PAUSE"
    STOP = "STOP"
    RESTART_WITH_CONFIG = "RESTART_WITH_CONFIG"  # new config staged on the trial


class TrialScheduler:
    """Base scheduler. Subclasses override on_result / choose_trial_to_run."""

    def __init__(self, metric: str = "loss", mode: str = "min"):
        if mode not in ("min", "max"):
            raise ValueError("mode must be 'min' or 'max'")
        self.metric = metric
        self.mode = mode
        # Decision provenance (DESIGN.md §10): every non-trivial verdict is
        # recorded with the inputs that produced it.  The runner drains this
        # after each on_result/on_trial_error call; the maxlen is a backstop
        # so an undrained scheduler (unit tests, direct use) stays bounded.
        self._decision_log: "deque[Dict[str, Any]]" = deque(maxlen=4096)
        self._last_explain: Optional[Dict[str, Any]] = None

    # score such that HIGHER is always better internally
    def _score(self, value: float) -> float:
        return value if self.mode == "max" else -value

    # -- decision provenance (DESIGN.md §10) ------------------------------------
    def _record_decision(self, trial_id: str, verdict: "SchedulerDecision",
                         iteration: Optional[int] = None,
                         **inputs: Any) -> Dict[str, Any]:
        """Record a verdict plus the inputs that produced it.

        Called by subclasses at each decision point; the record lands in
        ``explain_last()`` and in the drain queue the runner journals from.
        """
        rec: Dict[str, Any] = {
            "trial_id": trial_id,
            "verdict": verdict.value if isinstance(verdict, SchedulerDecision) else str(verdict),
            "iteration": iteration,
            "inputs": inputs,
        }
        self._last_explain = rec
        self._decision_log.append(rec)
        return rec

    def explain_last(self) -> Optional[Dict[str, Any]]:
        """The most recent decision record (verdict + inputs), or None."""
        return self._last_explain

    def pop_decisions(self) -> List[Dict[str, Any]]:
        """Drain all recorded-but-unjournaled decision records, in order."""
        if not self._decision_log:
            return []
        out = list(self._decision_log)
        self._decision_log.clear()
        return out

    # -- durable state (DESIGN.md §10) ------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        """JSON-serializable snapshot of decision-relevant mutable state.

        The base scheduler (and FIFO) is stateless beyond construction args,
        so the base snapshot is empty; subclasses extend it.  ``metric`` /
        ``mode`` are constructor config, not state — resume reconstructs the
        scheduler then loads this dict.
        """
        return {}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        """Restore from a ``state_dict()`` snapshot.  Base: nothing to do."""

    def decision_interval(self) -> int:
        """Decision granularity: how many results may elapse between decisions
        that can stop, pause, or perturb a trial.

        ``0`` means *never* — the scheduler runs every trial to its stopping
        condition (FIFO), so workers may run unbounded result lookahead
        without changing any decision.  ``n >= 1`` means the scheduler may act
        on any result (1) or on every n-th result per trial; the elastic
        tier's ``ResourceBroker`` preserves exactness by clamping lookahead
        credits to 1 whenever the interval is nonzero (DESIGN.md §6).
        Conservative default: 1.
        """
        return 1

    def holds_trial(self, trial_id: str) -> bool:
        """True when the scheduler is deliberately holding this PAUSED trial
        (e.g. a HyperBand milestone-waiter awaiting its bracket cut) and the
        runner must not relaunch it on its own.

        Durable resume uses this to keep restored milestone-waiters parked
        until the scheduler's own promote path fires (DESIGN.md §12).  Base:
        nothing is ever held.
        """
        return False

    # -- lifecycle events -------------------------------------------------------
    def on_trial_add(self, runner: "TrialRunner", trial: Trial) -> None:
        pass

    def on_trial_error(self, runner: "TrialRunner", trial: Trial) -> None:
        pass

    def on_result(self, runner: "TrialRunner", trial: Trial, result: Result) -> SchedulerDecision:
        """Called for every intermediate result. Default: run to completion."""
        return SchedulerDecision.CONTINUE

    def on_trial_complete(self, runner: "TrialRunner", trial: Trial) -> None:
        pass

    def choose_trial_to_run(self, runner: "TrialRunner") -> Optional[Trial]:
        """Pick the next trial to (re)launch given free resources.

        Default policy: oldest-queued PENDING trial, then oldest-queued PAUSED
        trial, via the runner's status/shape index (one ``has_resources``
        probe per resource shape instead of an O(n) scan — DESIGN.md §9).
        """
        trial = runner.next_ready(TrialStatus.PENDING)
        if trial is not None:
            return trial
        return runner.next_ready(TrialStatus.PAUSED)

    def debug_string(self) -> str:
        return type(self).__name__
