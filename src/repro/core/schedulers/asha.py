"""Asynchronous HyperBand / ASHA (Li et al. 2018; paper Table 1: 78 LoC).

Successive halving with asynchronous rung promotion: a trial reaching rung r is
promoted iff its result is in the top 1/reduction_factor of all results *seen so
far* at rung r; otherwise it is stopped (or paused).  No bracket barriers — this
is the variant the paper notes is "simpler to implement in the distributed
setting".  Multiple brackets (s values) are supported like the published ASHA.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..trial import Result, Trial
from .base import SchedulerDecision, TrialScheduler

__all__ = ["AsyncHyperBandScheduler", "ASHAScheduler"]


class _Bracket:
    def __init__(self, min_t: int, max_t: int, rf: int, s: int):
        # rung milestones: min_t * rf^k for k >= s, capped at max_t
        self.rf = rf
        self.milestones: List[int] = []
        t = min_t * (rf ** s)
        while t < max_t:
            self.milestones.append(int(t))
            t *= rf
        self.milestones.append(int(max_t))
        # rung -> list of recorded scores (higher better)
        self.rungs: Dict[int, List[float]] = {m: [] for m in self.milestones}

    def on_result(self, iteration: int, score: float
                  ) -> Tuple[SchedulerDecision, Optional[Dict[str, Any]]]:
        """Verdict plus the rung check that produced it (None = no new rung).

        The returned check carries the promotion inputs for the *deciding*
        rung: the last rung this result arrived at (a STOP at any rung wins).
        """
        decision = SchedulerDecision.CONTINUE
        check: Optional[Dict[str, Any]] = None
        for milestone in self.milestones:
            if iteration >= milestone and milestone != self.milestones[-1]:
                recorded = self.rungs[milestone]
                if not any(np.isclose(score, r) for r in recorded):
                    # promotion check against results seen so far at this rung
                    cutoff = (
                        float(np.percentile(recorded, (1 - 1 / self.rf) * 100))
                        if recorded
                        else float("-inf")
                    )
                    rung_decision = (SchedulerDecision.STOP if score < cutoff
                                     else SchedulerDecision.CONTINUE)
                    if check is None or rung_decision == SchedulerDecision.STOP:
                        check = {"milestone": milestone, "cutoff": cutoff,
                                 "score": score, "n_rung": len(recorded),
                                 "rf": self.rf}
                    recorded.append(score)
                    if score < cutoff:
                        decision = SchedulerDecision.STOP
        return decision, check

    def state_dict(self) -> Dict[str, Any]:
        # rungs keyed by int milestones -> list-of-pairs for JSON round-trips
        return {"rungs": [[m, list(v)] for m, v in self.rungs.items()]}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        for m, scores in state["rungs"]:
            self.rungs[int(m)] = [float(s) for s in scores]

    def debug_string(self) -> str:
        return " | ".join(f"r={m}:n={len(v)}" for m, v in self.rungs.items())


class AsyncHyperBandScheduler(TrialScheduler):
    def __init__(
        self,
        metric: str = "loss",
        mode: str = "min",
        max_t: int = 100,
        grace_period: int = 1,
        reduction_factor: int = 4,
        brackets: int = 1,
    ):
        super().__init__(metric=metric, mode=mode)
        if grace_period < 1 or max_t < grace_period:
            raise ValueError("need 1 <= grace_period <= max_t")
        self.max_t = max_t
        self.grace_period = grace_period  # rung-survival signal (elastic GreedyFill)
        self._brackets = [
            _Bracket(grace_period, max_t, reduction_factor, s) for s in range(brackets)
        ]
        self._trial_bracket: Dict[str, int] = {}
        self._rng = np.random.default_rng(0)
        self.n_stopped = 0

    def decision_interval(self) -> int:
        # Any result can be a rung arrival (milestones are per-bracket), so a
        # stop may be issued on every report: exact mode needs lookahead 1.
        return 1

    def on_trial_add(self, runner, trial: Trial) -> None:
        # Softmax-free sizing: weight brackets by number of rungs (as in ASHA).
        sizes = np.array([len(b.milestones) for b in self._brackets], dtype=float)
        probs = sizes / sizes.sum()
        self._trial_bracket[trial.trial_id] = int(self._rng.choice(len(self._brackets), p=probs))

    def on_result(self, runner, trial: Trial, result: Result) -> SchedulerDecision:
        if result.training_iteration >= self.max_t:
            self._record_decision(trial.trial_id, SchedulerDecision.STOP,
                                  iteration=result.training_iteration,
                                  reason="max_t", max_t=self.max_t)
            return SchedulerDecision.STOP
        b_idx = self._trial_bracket.get(trial.trial_id, 0)
        bracket = self._brackets[b_idx]
        score = self._score(result.value(self.metric))
        decision, check = bracket.on_result(result.training_iteration, score)
        if check is not None:
            self._record_decision(trial.trial_id, decision,
                                  iteration=result.training_iteration,
                                  reason="rung", bracket=b_idx, **check)
        if decision == SchedulerDecision.STOP:
            self.n_stopped += 1
        return decision

    def state_dict(self) -> Dict[str, Any]:
        return {
            "brackets": [b.state_dict() for b in self._brackets],
            "trial_bracket": dict(self._trial_bracket),
            "rng": self._rng.bit_generator.state,
            "n_stopped": self.n_stopped,
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        for b, bs in zip(self._brackets, state["brackets"]):
            b.load_state_dict(bs)
        self._trial_bracket = {str(k): int(v)
                               for k, v in state["trial_bracket"].items()}
        self._rng = np.random.default_rng()
        self._rng.bit_generator.state = state["rng"]
        self.n_stopped = int(state["n_stopped"])

    def debug_string(self) -> str:
        lines = [f"AsyncHyperBand: {self.n_stopped} stopped"]
        lines += [f"  bracket {i}: {b.debug_string()}" for i, b in enumerate(self._brackets)]
        return "\n".join(lines)


ASHAScheduler = AsyncHyperBandScheduler
