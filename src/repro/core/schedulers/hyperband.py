"""HyperBand — original synchronous formulation (Li et al. 2016; Table 1: 215 LoC).

Brackets s = s_max..0 with n(s) = ceil((s_max+1)/(s+1) * eta^s) trials starting
at r(s) = R * eta^-s resource.  Within a bracket, successive-halving rounds are
*synchronous*: every live trial must reach the round's milestone (we PAUSE those
that arrive early — this exercises checkpoint/pause/resume through the narrow
interface), then the top 1/eta continue and the rest are stopped.

This is exactly the pause-capable behaviour the paper argues systems treating a
trial as an atomic unit (Spearmint/HyperOpt/TuPAQ) cannot express (§2).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import numpy as np

from ..trial import Result, Trial, TrialStatus
from .base import SchedulerDecision, TrialScheduler

__all__ = ["HyperBandScheduler"]


class _SyncBracket:
    def __init__(self, s: int, s_max: int, R: int, eta: int):
        self.eta = eta
        self.capacity = int(math.ceil((s_max + 1) / (s + 1) * eta**s))
        self.r0 = max(1, int(R * eta**-s))
        self.R = R
        self.round = 0
        self.trials: List[Trial] = []          # live (not yet cut) members
        self.arrived: Dict[str, float] = {}    # trial_id -> score at current milestone
        self.finished = False

    @property
    def milestone(self) -> int:
        return min(self.R, self.r0 * self.eta**self.round)

    @property
    def full(self) -> bool:
        return len(self.trials) >= self.capacity

    def add(self, trial: Trial) -> None:
        self.trials.append(trial)

    def record(self, trial: Trial, score: float) -> None:
        self.arrived[trial.trial_id] = score

    def ready_to_cut(self) -> bool:
        # Cut when every live member (incl. not-yet-started PENDING members,
        # which haven't arrived) has recorded at the milestone.  Capacity need
        # not be reached: an underfull bracket (fewer trials than n(s)) would
        # otherwise wait forever for members that will never be added.
        live = [t for t in self.trials if not t.status.is_finished()]
        return bool(live) and all(t.trial_id in self.arrived for t in live)

    def cut(self) -> Dict[str, bool]:
        """Perform one halving round. Returns trial_id -> keep?"""
        live = [t for t in self.trials if not t.status.is_finished()]
        n_keep = max(1, int(len(live) / self.eta))
        ranked = sorted(live, key=lambda t: self.arrived[t.trial_id], reverse=True)
        keep = {t.trial_id: (i < n_keep) for i, t in enumerate(ranked)}
        self.trials = [t for t in ranked if keep[t.trial_id]]
        self.arrived.clear()
        self.round += 1
        if self.milestone >= self.R and self.round > 0 and len(self.trials) <= 1:
            pass  # final round: survivors run to R then terminate via max_t
        return keep

    def state_dict(self) -> Dict[str, Any]:
        # Trials are serialized by id: load_state_dict takes an id->Trial
        # resolver because live Trial objects don't survive a JSON round-trip.
        return {"eta": self.eta, "capacity": self.capacity, "r0": self.r0,
                "R": self.R, "round": self.round,
                "trial_ids": [t.trial_id for t in self.trials],
                "arrived": dict(self.arrived), "finished": self.finished}

    def load_state_dict(self, state: Dict[str, Any],
                        trials: Optional[Dict[str, Trial]] = None) -> None:
        self.round = int(state["round"])
        self.arrived = {str(k): float(v) for k, v in state["arrived"].items()}
        self.finished = bool(state["finished"])
        if trials is not None:
            self.trials = [trials[tid] for tid in state["trial_ids"]
                           if tid in trials]


class HyperBandScheduler(TrialScheduler):
    def __init__(
        self,
        metric: str = "loss",
        mode: str = "min",
        max_t: int = 81,
        eta: int = 3,
    ):
        super().__init__(metric=metric, mode=mode)
        self.max_t = max_t
        self.eta = eta
        self.s_max = int(math.log(max_t) / math.log(eta))
        self._brackets: List[_SyncBracket] = []
        self._trial_bracket: Dict[str, _SyncBracket] = {}
        self._next_s = self.s_max
        self._promote: List[str] = []  # trial_ids cleared to resume after a cut
        self.n_stopped = 0

    def decision_interval(self) -> int:
        # Synchronous halving pauses trials at bracket milestones; any result
        # may be the milestone arrival, so exact mode needs lookahead 1.
        return 1

    # -- bracket assignment -----------------------------------------------------
    def _open_bracket(self) -> _SyncBracket:
        b = _SyncBracket(self._next_s, self.s_max, self.max_t, self.eta)
        self._brackets.append(b)
        self._next_s = self._next_s - 1 if self._next_s > 0 else self.s_max
        return b

    def on_trial_add(self, runner, trial: Trial) -> None:
        bracket = next((b for b in self._brackets if not b.full), None) or self._open_bracket()
        bracket.add(trial)
        self._trial_bracket[trial.trial_id] = bracket

    def holds_trial(self, trial_id: str) -> bool:
        # A milestone-waiter (recorded in its bracket's ``arrived``) must stay
        # PAUSED until the synchronous cut fires — relaunching it early (e.g.
        # from the durable-resume queue) would let it run past the milestone
        # before the bracket decides who survives.
        bracket = self._trial_bracket.get(trial_id)
        return bracket is not None and trial_id in bracket.arrived

    # -- result handling ----------------------------------------------------------
    def _cut_records(self, bracket: _SyncBracket, keep: Dict[str, bool],
                     arrived: Dict[str, float], milestone: int,
                     rnd: int) -> Dict[str, Dict[str, Any]]:
        """Per-trial provenance for one halving round (DESIGN.md §10).

        Returns trial_id -> inputs dict: rank within the round's ranking,
        score, and the score of the last kept trial (the effective cut line).
        """
        ranked = sorted(keep, key=lambda tid: arrived.get(tid, float("-inf")),
                        reverse=True)
        n_keep = sum(1 for v in keep.values() if v)
        cut_score = (arrived.get(ranked[n_keep - 1], float("-inf"))
                     if n_keep else float("-inf"))
        b_idx = self._brackets.index(bracket)
        return {tid: {"milestone": milestone, "round": rnd, "bracket": b_idx,
                      "rank": i, "n_keep": n_keep, "n_live": len(ranked),
                      "score": arrived.get(tid), "cut_score": cut_score}
                for i, tid in enumerate(ranked)}

    def on_result(self, runner, trial: Trial, result: Result) -> SchedulerDecision:
        if result.training_iteration >= self.max_t:
            self._record_decision(trial.trial_id, SchedulerDecision.STOP,
                                  iteration=result.training_iteration,
                                  reason="max_t", max_t=self.max_t)
            return SchedulerDecision.STOP
        bracket = self._trial_bracket[trial.trial_id]
        if result.training_iteration < bracket.milestone:
            return SchedulerDecision.CONTINUE

        bracket.record(trial, self._score(result.value(self.metric)))
        if not bracket.ready_to_cut():
            # Wait (paused, checkpointed) for bracket peers to reach the milestone.
            live = [t for t in bracket.trials if not t.status.is_finished()]
            self._record_decision(
                trial.trial_id, SchedulerDecision.PAUSE,
                iteration=result.training_iteration, reason="milestone_wait",
                milestone=bracket.milestone, round=bracket.round,
                bracket=self._brackets.index(bracket),
                n_arrived=len(bracket.arrived), n_live=len(live))
            return SchedulerDecision.PAUSE

        arrived = dict(bracket.arrived)
        milestone, rnd = bracket.milestone, bracket.round
        keep = bracket.cut()
        records = self._cut_records(bracket, keep, arrived, milestone, rnd)
        my_decision = SchedulerDecision.PAUSE
        for t in runner.trials:
            verdict = keep.get(t.trial_id)
            if verdict is None:
                continue
            if t.trial_id == trial.trial_id:
                my_decision = (
                    SchedulerDecision.CONTINUE if verdict else SchedulerDecision.STOP
                )
                self._record_decision(t.trial_id, my_decision,
                                      iteration=result.training_iteration,
                                      reason="cut", **records[t.trial_id])
                if not verdict:
                    self.n_stopped += 1
            elif verdict:
                self._record_decision(t.trial_id, "PROMOTE", reason="cut",
                                      **records[t.trial_id])
                self._promote.append(t.trial_id)
            else:
                if t.status == TrialStatus.PAUSED:
                    self._record_decision(t.trial_id, SchedulerDecision.STOP,
                                          reason="cut", **records[t.trial_id])
                    runner.stop_trial(t)
                    self.n_stopped += 1
        return my_decision

    def on_trial_error(self, runner, trial: Trial) -> None:
        bracket = self._trial_bracket.get(trial.trial_id)
        if not bracket:
            return
        bracket.arrived.pop(trial.trial_id, None)
        bracket.trials = [t for t in bracket.trials if t.trial_id != trial.trial_id]
        # The error may have been the peer everyone was waiting on — re-check.
        if bracket.ready_to_cut():
            arrived = dict(bracket.arrived)
            milestone, rnd = bracket.milestone, bracket.round
            keep = bracket.cut()
            records = self._cut_records(bracket, keep, arrived, milestone, rnd)
            for t in runner.trials:
                verdict = keep.get(t.trial_id)
                if verdict is None:
                    continue
                if verdict:
                    self._record_decision(t.trial_id, "PROMOTE",
                                          reason="cut_after_error",
                                          **records[t.trial_id])
                    self._promote.append(t.trial_id)
                elif t.status == TrialStatus.PAUSED:
                    self._record_decision(t.trial_id, SchedulerDecision.STOP,
                                          reason="cut_after_error",
                                          **records[t.trial_id])
                    runner.stop_trial(t)
                    self.n_stopped += 1

    def state_dict(self) -> Dict[str, Any]:
        return {
            "brackets": [b.state_dict() for b in self._brackets],
            "trial_bracket": {tid: self._brackets.index(b)
                              for tid, b in self._trial_bracket.items()},
            "next_s": self._next_s,
            "promote": list(self._promote),
            "n_stopped": self.n_stopped,
        }

    def load_state_dict(self, state: Dict[str, Any],
                        trials: Optional[Dict[str, Trial]] = None) -> None:
        # Rebuild bracket shells in recorded order, then restore their state.
        self._brackets = []
        self._next_s = self.s_max
        for bs in state["brackets"]:
            b = self._open_bracket()
            b.load_state_dict(bs, trials=trials)
        self._trial_bracket = {str(tid): self._brackets[int(i)]
                               for tid, i in state["trial_bracket"].items()}
        self._next_s = int(state["next_s"])
        self._promote = [str(t) for t in state["promote"]]
        self.n_stopped = int(state["n_stopped"])

    # -- trial selection ----------------------------------------------------------
    def choose_trial_to_run(self, runner) -> Optional[Trial]:
        # 1. resume survivors of a cut
        while self._promote:
            tid = self._promote[0]
            t = runner.get_trial(tid)
            if t is None or t.status != TrialStatus.PAUSED:
                self._promote.pop(0)  # already resumed or finished
                continue
            if runner.has_resources(t):
                return t
            break  # keep queued until resources free up
        # 2. new pending trials
        t = runner.next_ready(TrialStatus.PENDING)
        if t is not None:
            return t
        # 3. crash-requeued members (max_failures retry): PAUSED *without* a
        # recorded milestone arrival is not waiting on a cut — it died and was
        # re-queued by the runner, and nothing else will ever relaunch it.
        # (Milestone-paused members ARE in bracket.arrived; cut survivors ride
        # the _promote queue above.)
        def _crash_requeued(t: Trial) -> bool:
            bracket = self._trial_bracket.get(t.trial_id)
            return bracket is not None and t.trial_id not in bracket.arrived
        # NOT generic paused trials — paused bracket members wait for the cut.
        return runner.next_ready(TrialStatus.PAUSED, fit=_crash_requeued)

    def debug_string(self) -> str:
        lines = [f"HyperBand: eta={self.eta} R={self.max_t} ({self.n_stopped} stopped)"]
        for i, b in enumerate(self._brackets):
            lines.append(
                f"  bracket {i}: cap={b.capacity} round={b.round} "
                f"milestone={b.milestone} live={len(b.trials)}"
            )
        return "\n".join(lines)
