"""Population-Based Training (Jaderberg et al. 2017; paper Table 1: 169 LoC).

Every ``perturbation_interval`` iterations a trial is *ready*; if it sits in the
bottom ``quantile_fraction`` of the population it EXPLOITS: clone the model
parameters of a top-quantile donor (via the donor's latest checkpoint) and
EXPLORE: perturb the donor's hyperparameters (x0.8 / x1.2, or resample from the
original distribution with prob ``resample_probability``).

This exercises the paper's requirement of "clone or mutate model parameters in
the middle of training" (§3) through the narrow interface alone: the scheduler
returns RESTART_WITH_CONFIG and the runner restores the staged donor checkpoint
with the mutated hyperparameter map — no scheduler-side distributed code.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import numpy as np

from ..trial import Result, Trial
from .base import SchedulerDecision, TrialScheduler

__all__ = ["PopulationBasedTraining"]


class PopulationBasedTraining(TrialScheduler):
    def __init__(
        self,
        metric: str = "loss",
        mode: str = "min",
        perturbation_interval: int = 5,
        hyperparam_mutations: Optional[Dict[str, Any]] = None,
        quantile_fraction: float = 0.25,
        resample_probability: float = 0.25,
        perturbation_factors: tuple = (0.8, 1.2),
        seed: int = 0,
    ):
        super().__init__(metric=metric, mode=mode)
        if not 0 < quantile_fraction <= 0.5:
            raise ValueError("quantile_fraction must be in (0, 0.5]")
        self.perturbation_interval = perturbation_interval
        self.mutations = dict(hyperparam_mutations or {})
        self.quantile_fraction = quantile_fraction
        self.resample_probability = resample_probability
        self.perturbation_factors = perturbation_factors
        self._rng = np.random.default_rng(seed)
        self._last_perturb: Dict[str, int] = {}
        self.n_exploits = 0

    def decision_interval(self) -> int:
        # Exploit/explore fires only once a trial has advanced
        # perturbation_interval iterations past its last perturbation — the
        # declared granularity.  The broker still clamps lookahead to 1 for
        # exactness (a nonzero interval means decisions exist); the value is
        # surfaced so observability (CREDITS events) records how much slack a
        # future bounded-staleness mode could exploit.
        return max(1, int(self.perturbation_interval))

    # -- explore ------------------------------------------------------------------
    def _explore(self, config: Dict[str, Any]) -> Dict[str, Any]:
        from ..search.space import Domain, Categorical

        new = dict(config)
        for key, spec in self.mutations.items():
            if key not in new:
                continue
            if self._rng.random() < self.resample_probability:
                if isinstance(spec, Domain):
                    new[key] = spec.sample(self._rng)
                elif isinstance(spec, (list, tuple)):
                    new[key] = spec[int(self._rng.integers(len(spec)))]
                elif callable(spec):
                    new[key] = spec()
            else:
                if isinstance(spec, (list, tuple)) or isinstance(spec, Categorical):
                    values = list(spec.values) if isinstance(spec, Categorical) else list(spec)
                    # shift to a neighbouring value
                    try:
                        i = values.index(new[key])
                        j = int(np.clip(i + self._rng.choice([-1, 1]), 0, len(values) - 1))
                        new[key] = values[j]
                    except ValueError:
                        new[key] = values[int(self._rng.integers(len(values)))]
                elif isinstance(new[key], (int, float)) and not isinstance(new[key], bool):
                    factor = float(self._rng.choice(self.perturbation_factors))
                    mutated = new[key] * factor
                    new[key] = int(round(mutated)) if isinstance(new[key], int) else mutated
        return new

    # -- quantiles ------------------------------------------------------------------
    def _population_scores(self, runner) -> List[tuple]:
        scored = []
        for t in runner.trials:
            if t.last_result is not None and self.metric in t.last_result.metrics:
                scored.append((self._score(t.last_result.value(self.metric)), t))
        return sorted(scored, key=lambda x: x[0])  # ascending: worst first

    def on_result(self, runner, trial: Trial, result: Result) -> SchedulerDecision:
        last = self._last_perturb.get(trial.trial_id, 0)
        if result.training_iteration - last < self.perturbation_interval:
            return SchedulerDecision.CONTINUE
        self._last_perturb[trial.trial_id] = result.training_iteration

        scored = self._population_scores(runner)
        if len(scored) < 2:
            return SchedulerDecision.CONTINUE
        n_q = max(1, int(len(scored) * self.quantile_fraction))
        bottom = {t.trial_id for _, t in scored[:n_q]}
        top = [t for _, t in scored[-n_q:]]
        if trial.trial_id not in bottom:
            return SchedulerDecision.CONTINUE

        donor = top[int(self._rng.integers(len(top)))]
        if donor.trial_id == trial.trial_id or donor.checkpoint is None:
            # Journaled so resume replay can reproduce this branch: whether
            # the drawn donor had a live checkpoint is executor state the
            # journal otherwise would not capture (DESIGN.md §12).
            self._record_decision(
                trial.trial_id, "EXPLOIT_SKIPPED",
                iteration=result.training_iteration, reason="exploit_skipped",
                donor=donor.trial_id,
                donor_is_self=donor.trial_id == trial.trial_id,
                donor_has_checkpoint=donor.checkpoint is not None)
            return SchedulerDecision.CONTINUE

        # Stage the exploit: the runner restores donor's checkpoint with the
        # explored config (paper: "restart a trial with an updated
        # hyperparameter configuration").
        donor.checkpoint.pinned = True  # survive keep_last rotation until applied
        new_config = self._explore(donor.config)
        trial.scheduler_state["restore_from"] = donor.checkpoint
        trial.scheduler_state["new_config"] = new_config
        trial.scheduler_state["cloned_from"] = donor.trial_id
        self.n_exploits += 1
        my_score = next((s for s, t in scored if t.trial_id == trial.trial_id),
                        None)
        donor_score = next((s for s, t in scored
                            if t.trial_id == donor.trial_id), None)
        self._record_decision(
            trial.trial_id, SchedulerDecision.RESTART_WITH_CONFIG,
            iteration=result.training_iteration, reason="exploit",
            donor=donor.trial_id,
            donor_iteration=donor.checkpoint.training_iteration,
            donor_score=donor_score, my_score=my_score,
            quantile_fraction=self.quantile_fraction, n_bottom=n_q,
            population=len(scored), new_config=new_config)
        return SchedulerDecision.RESTART_WITH_CONFIG

    def state_dict(self) -> Dict[str, Any]:
        return {"last_perturb": dict(self._last_perturb),
                "n_exploits": self.n_exploits,
                "rng": self._rng.bit_generator.state}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self._last_perturb = {str(k): int(v)
                              for k, v in state["last_perturb"].items()}
        self.n_exploits = int(state["n_exploits"])
        self._rng = np.random.default_rng()
        self._rng.bit_generator.state = state["rng"]

    def debug_string(self) -> str:
        return f"PBT: {self.n_exploits} exploit/explore events"
