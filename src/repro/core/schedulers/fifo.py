"""FIFO — the trivial scheduler (paper Table 1: 10 LoC).

Runs each trial to its stopping condition; launches trials in parallel when
resources allow (that part is the runner's job).  All logic is the base class.
"""
from __future__ import annotations

from .base import TrialScheduler

__all__ = ["FIFOScheduler"]


class FIFOScheduler(TrialScheduler):
    def decision_interval(self) -> int:
        # Never stops/pauses/perturbs: every decision is CONTINUE, so workers
        # may run unbounded result lookahead without changing semantics.
        return 0
