"""In-memory object store — the ``ray.put``/``ray.get`` analogue (paper §4.3.2).

Trials broadcast weights/datasets by putting them in the store and passing keys;
PBT clones a trial by ``get``-ing the donor checkpoint.  Content lives in host
memory with optional spill-to-disk for large or evicted entries.  Values are
arbitrary pytrees; we deep-copy nothing — JAX arrays are immutable, so sharing
references is safe and clone-by-reference is O(1) (a functional-state advantage
over actor snapshots, noted in DESIGN.md §2).
"""
from __future__ import annotations

import itertools
import os
import pickle
from collections import OrderedDict
from typing import Any, Optional

__all__ = ["ObjectStore"]


class ObjectStore:
    def __init__(self, capacity_bytes: int = 2 << 30, spill_dir: Optional[str] = None):
        self._mem: "OrderedDict[str, Any]" = OrderedDict()
        self._sizes: dict = {}
        self._capacity = capacity_bytes
        self._used = 0
        self._spill_dir = spill_dir
        self._counter = itertools.count()
        self.n_spilled = 0
        self.n_evicted = 0

    def _estimate_size(self, value: Any) -> int:
        import jax
        import numpy as np

        total = 0
        for leaf in jax.tree_util.tree_leaves(value):
            if hasattr(leaf, "nbytes"):
                total += int(leaf.nbytes)
            else:
                total += 64
        return max(total, 64)

    def put(self, value: Any, key: Optional[str] = None) -> str:
        key = key or f"obj_{next(self._counter):08d}"
        size = self._estimate_size(value)
        if key in self._mem:
            # replacing: credit the old entry back BEFORE capacity accounting,
            # else a same-key update can spuriously evict (or refuse)
            self._used -= self._sizes.pop(key, 0)
            del self._mem[key]
        self._evict_for(size)
        self._mem[key] = value
        self._sizes[key] = size
        self._used += size
        self._mem.move_to_end(key)
        return key

    def get(self, key: str) -> Any:
        if key in self._mem:
            self._mem.move_to_end(key)  # LRU touch
            return self._mem[key]
        path = self._spill_path(key)
        if path and os.path.exists(path):
            with open(path, "rb") as f:
                return pickle.load(f)
        raise KeyError(f"object {key!r} not in store")

    def contains(self, key: str) -> bool:
        path = self._spill_path(key)
        return key in self._mem or bool(path and os.path.exists(path))

    def delete(self, key: str) -> None:
        if key in self._mem:
            self._used -= self._sizes.pop(key, 0)
            del self._mem[key]
        path = self._spill_path(key)
        if path and os.path.exists(path):
            os.remove(path)

    @property
    def used_bytes(self) -> int:
        return self._used

    # -- eviction / spill ------------------------------------------------------
    def _spill_path(self, key: str) -> Optional[str]:
        if not self._spill_dir:
            return None
        return os.path.join(self._spill_dir, f"{key}.pkl")

    def _evict_for(self, incoming: int) -> None:
        if self._used + incoming > self._capacity and self._mem and not self._spill_dir:
            # Without a spill_dir, LRU eviction would DESTROY objects and turn
            # later get() calls into KeyErrors.  Refuse: a loud capacity error
            # beats silently losing a trial checkpoint.
            raise RuntimeError(
                f"ObjectStore over capacity ({self._used + incoming} > "
                f"{self._capacity} bytes) and no spill_dir is configured; "
                "evicting would destroy stored objects. Configure spill_dir= "
                "or raise capacity_bytes.")
        while self._mem and self._used + incoming > self._capacity:
            key, value = self._mem.popitem(last=False)  # LRU -> disk
            self._used -= self._sizes.pop(key, 0)
            path = self._spill_path(key)
            os.makedirs(self._spill_dir, exist_ok=True)
            with open(path, "wb") as f:
                pickle.dump(value, f)
            self.n_spilled += 1
            self.n_evicted += 1
