"""In-memory object store — the ``ray.put``/``ray.get`` analogue (paper §4.3.2).

Trials broadcast weights/datasets by putting them in the store and passing keys;
PBT clones a trial by ``get``-ing the donor checkpoint.  Content lives in host
memory with optional spill-to-disk for large or evicted entries.  Values are
arbitrary pytrees; we deep-copy nothing — JAX arrays are immutable, so sharing
references is safe and clone-by-reference is O(1) (a functional-state advantage
over actor snapshots, noted in DESIGN.md §2).

Two properties matter for the execution tiers (DESIGN.md §4–§5):

- **Thread/process-host safety** — the store is shared mutable state across the
  runner thread, the concurrent executor's worker threads, and the process
  executor's pump thread, so every public operation holds one ``RLock``.
- **Spill files as an IPC surface** — ``put_spilled`` writes an entry straight
  to the spill directory and ``export`` forces a resident entry out to it, so a
  *separate process* pointed at the same ``spill_dir`` can exchange values by
  key alone (the process-worker checkpoint path, DESIGN.md §5).  ``get`` of a
  spilled entry re-admits it into the in-memory LRU so hot entries stop paying
  a disk read per access.
"""
from __future__ import annotations

import itertools
import os
import pickle
import sys
import tempfile
import threading
from collections import OrderedDict
from typing import Any, Optional

__all__ = ["ObjectStore"]


class ObjectStore:
    def __init__(self, capacity_bytes: int = 2 << 30, spill_dir: Optional[str] = None):
        self._mem: "OrderedDict[str, Any]" = OrderedDict()
        self._sizes: dict = {}
        self._capacity = capacity_bytes
        self._used = 0
        self._spill_dir = spill_dir
        self._counter = itertools.count()
        self._lock = threading.RLock()
        self.n_spilled = 0
        self.n_evicted = 0

    @property
    def spill_dir(self) -> Optional[str]:
        return self._spill_dir

    def ensure_spill_dir(self) -> str:
        """The spill directory, creating a private temp one if unconfigured.

        Process workers *require* a spill surface (checkpoint bytes cross the
        process boundary as spill files), so the process executor calls this at
        construction instead of failing on the first checkpoint.
        """
        with self._lock:
            if not self._spill_dir:
                self._spill_dir = tempfile.mkdtemp(prefix="repro-store-")
            os.makedirs(self._spill_dir, exist_ok=True)
            return self._spill_dir

    def _estimate_size(self, value: Any) -> int:
        if isinstance(value, (bytes, bytearray)):
            return max(len(value), 64)
        if "jax" in sys.modules:  # don't *cause* a jax import just to size a value
            leaves = sys.modules["jax"].tree_util.tree_leaves(value)
        else:
            leaves = []
            stack = [value]
            while stack:
                node = stack.pop()
                if isinstance(node, dict):
                    stack.extend(node.values())
                elif isinstance(node, (list, tuple)):
                    stack.extend(node)
                else:
                    leaves.append(node)
        total = 0
        for leaf in leaves:
            if isinstance(leaf, (bytes, bytearray)):
                total += len(leaf)
            elif hasattr(leaf, "nbytes"):
                total += int(leaf.nbytes)
            else:
                total += 64
        return max(total, 64)

    def put(self, value: Any, key: Optional[str] = None) -> str:
        with self._lock:
            key = key or f"obj_{next(self._counter):08d}"
            size = self._estimate_size(value)
            if key in self._mem:
                # replacing: credit the old entry back BEFORE capacity accounting,
                # else a same-key update can spuriously evict (or refuse)
                self._used -= self._sizes.pop(key, 0)
                del self._mem[key]
            self._evict_for(size)
            self._mem[key] = value
            self._sizes[key] = size
            self._used += size
            self._mem.move_to_end(key)
            return key

    def put_spilled(self, value: Any, key: Optional[str] = None) -> str:
        """Write ``value`` directly to the spill surface, bypassing memory.

        This is the cross-process handoff path: a worker process stores
        checkpoint bytes here and sends only the key over the pipe; the host's
        store (same ``spill_dir``) resolves the key via ``get``/``contains``.
        """
        with self._lock:
            if not self._spill_dir:
                raise RuntimeError("put_spilled requires a spill_dir")
            key = key or f"obj_{next(self._counter):08d}"
            self._write_spill(key, value)
            # a stale in-memory copy under the same key would shadow the new file
            if key in self._mem:
                self._used -= self._sizes.pop(key, 0)
                del self._mem[key]
            self.n_spilled += 1
            return key

    def export(self, key: str) -> str:
        """Force ``key`` onto the spill surface (if not already there) and
        return the file path, so another process can read it."""
        with self._lock:
            path = self._spill_path(key)
            if not path:
                raise RuntimeError("export requires a spill_dir")
            if not os.path.exists(path):
                if key not in self._mem:
                    raise KeyError(f"object {key!r} not in store")
                self._write_spill(key, self._mem[key])
                self.n_spilled += 1
            return path

    def get(self, key: str) -> Any:
        with self._lock:
            if key in self._mem:
                self._mem.move_to_end(key)  # LRU touch
                return self._mem[key]
            path = self._spill_path(key)
            if path and os.path.exists(path):
                with open(path, "rb") as f:
                    value = pickle.load(f)
                # Re-admit into the LRU: repeated gets of a hot spilled entry
                # must not pay a disk read each time.  The file stays behind as
                # the durable copy (delete() removes both).
                self.put(value, key=key)
                return value
            raise KeyError(f"object {key!r} not in store")

    def peek(self, key: str) -> Any:
        """``get`` without the LRU touch or spill re-admission: for one-shot
        readers (e.g. mirroring a worker-written checkpoint to disk) that must
        not cache a copy another process may rewrite, nor evict hot entries."""
        with self._lock:
            if key in self._mem:
                return self._mem[key]
            path = self._spill_path(key)
            if path and os.path.exists(path):
                with open(path, "rb") as f:
                    return pickle.load(f)
            raise KeyError(f"object {key!r} not in store")

    def contains(self, key: str) -> bool:
        with self._lock:
            path = self._spill_path(key)
            return key in self._mem or bool(path and os.path.exists(path))

    def delete(self, key: str) -> None:
        with self._lock:
            if key in self._mem:
                self._used -= self._sizes.pop(key, 0)
                del self._mem[key]
            path = self._spill_path(key)
            if path and os.path.exists(path):
                os.remove(path)

    @property
    def used_bytes(self) -> int:
        with self._lock:
            return self._used

    # -- eviction / spill ------------------------------------------------------
    def _spill_path(self, key: str) -> Optional[str]:
        if not self._spill_dir:
            return None
        return os.path.join(self._spill_dir, f"{key.replace('/', '__')}.pkl")

    def _write_spill(self, key: str, value: Any) -> None:
        path = self._spill_path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            pickle.dump(value, f)
        os.replace(tmp, path)  # atomic: a concurrent reader never sees a torn file

    def _evict_for(self, incoming: int) -> None:
        # caller holds self._lock (RLock re-entry from put)
        if self._used + incoming > self._capacity and self._mem and not self._spill_dir:
            # Without a spill_dir, LRU eviction would DESTROY objects and turn
            # later get() calls into KeyErrors.  Refuse: a loud capacity error
            # beats silently losing a trial checkpoint.
            raise RuntimeError(
                f"ObjectStore over capacity ({self._used + incoming} > "
                f"{self._capacity} bytes) and no spill_dir is configured; "
                "evicting would destroy stored objects. Configure spill_dir= "
                "or raise capacity_bytes.")
        while self._mem and self._used + incoming > self._capacity:
            key, value = self._mem.popitem(last=False)  # LRU -> disk
            self._used -= self._sizes.pop(key, 0)
            self._write_spill(key, value)
            self.n_spilled += 1
            self.n_evicted += 1
