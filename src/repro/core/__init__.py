"""repro.core — Tune: distributed model selection over a narrow-waist interface.

Public API mirrors the paper: a user API (Trainable / function trainables +
search-space DSL + run_experiments) and a scheduler API (TrialScheduler and the
six built-in algorithms of Table 1).
"""
from .api import FunctionHandle, FunctionTrainable, Trainable, wrap_function
from .checkpoint import CheckpointManager, load_pytree, save_pytree, tree_from_bytes, tree_to_bytes
from .clock import (Clock, VirtualClock, WallClock, get_default_clock,
                    set_default_clock, use_clock)
from .experiment import (ExperimentAnalysis, load_experiment_state,
                         register_trainable, run_experiments)
from .loggers import CompositeLogger, ConsoleLogger, CSVLogger, JSONLLogger, Logger
from .object_store import ObjectStore
from .resources import ResourceAccountant, Resources
from .runner import TrialRunner
from .events import EventBus, EventType, TrialEvent
from .executor import BusDrivenExecutor, SerialMeshExecutor, TrialExecutor
from .concurrent_executor import ConcurrentMeshExecutor
from .process_executor import ProcessMeshExecutor
from .elastic import FairShare, GreedyFill, ResizePolicy, ResourceBroker
from .workers import (ProcessWorker, TrainableFactory, factory_from_class,
                      register_worker_factory, resolve_worker_factory)
from .trial import Checkpoint, Result, Trial, TrialStatus
from .schedulers.base import SchedulerDecision, TrialScheduler
from .schedulers.fifo import FIFOScheduler
from .schedulers.median_stopping import MedianStoppingRule
from .schedulers.asha import ASHAScheduler, AsyncHyperBandScheduler
from .schedulers.hyperband import HyperBandScheduler
from .schedulers.pbt import PopulationBasedTraining
from .search.space import (
    choice, grid_search, loguniform, normal, qrandint, randint, sample_from, uniform,
)
from .search.basic import GridSearcher, RandomSearcher, Searcher
from .search.tpe import TPESearcher
from .search.gp import GPSearcher
from ..obs import (NULL_OBS, MetricsRegistry, Observability,  # DESIGN.md §8
                   Tracer)

__all__ = [
    "Trainable", "FunctionTrainable", "FunctionHandle", "wrap_function",
    "run_experiments", "register_trainable", "ExperimentAnalysis",
    "load_experiment_state",
    "Clock", "WallClock", "VirtualClock",
    "get_default_clock", "set_default_clock", "use_clock",
    "Trial", "TrialStatus", "Result", "Checkpoint",
    "TrialRunner", "TrialExecutor", "SerialMeshExecutor", "BusDrivenExecutor",
    "ConcurrentMeshExecutor", "ProcessMeshExecutor",
    "ResourceBroker", "ResizePolicy", "GreedyFill", "FairShare",
    "TrainableFactory", "ProcessWorker", "register_worker_factory",
    "resolve_worker_factory", "factory_from_class",
    "EventBus", "EventType", "TrialEvent",
    "TrialScheduler", "SchedulerDecision",
    "FIFOScheduler", "MedianStoppingRule", "ASHAScheduler",
    "AsyncHyperBandScheduler", "HyperBandScheduler", "PopulationBasedTraining",
    "Searcher", "RandomSearcher", "GridSearcher", "TPESearcher", "GPSearcher",
    "grid_search", "choice", "uniform", "loguniform", "randint", "qrandint",
    "normal", "sample_from",
    "Resources", "ResourceAccountant", "ObjectStore", "CheckpointManager",
    "save_pytree", "load_pytree", "tree_to_bytes", "tree_from_bytes",
    "Logger", "ConsoleLogger", "CSVLogger", "JSONLLogger", "CompositeLogger",
    "Observability", "NULL_OBS", "MetricsRegistry", "Tracer",
]
