"""Durable resume (DESIGN.md §12): rebuild a killed sweep from its artifacts.

A run that journals to ``events.jsonl`` leaves three durable sources behind
when its controller dies:

1. the **journal** — every result / decision / lifecycle event, flushed per
   record (the torn final line of a kill -9 is repaired here);
2. the **search-state snapshot** (``search_state.json``) — scheduler +
   searcher ``state_dict()`` stamped with a *watermark*: the exact count of
   journal records whose effects the snapshot already contains;
3. the per-trial **checkpoint mirrors** (``ckpt/<trial_id>/iter_N.ckpt``).

``prepare_resume`` reconciles the three into a :class:`ResumePlan`:

- journal records ``[0..W)`` (below the watermark) are *bookkept only* —
  trial result histories, statuses, configs, iteration frontiers — because
  the snapshot already reflects them;
- the tail ``[W..end)`` is *replayed through* the scheduler/searcher
  (``on_result`` / ``on_trial_add`` / ``on_trial_complete`` / ``suggest``)
  against a shim runner, so rung counts, bracket membership, populations
  and RNG streams advance exactly as they did in the original process;
- finally each non-terminal trial is matched to its newest *valid* disk
  mirror at-or-below its journal frontier: mirror found → PAUSED with a
  checkpoint (plus a **result fence** so re-executed, already-journaled
  iterations are not journaled twice), no mirror → PENDING from scratch.

Virtual-time phase: each restored trial carries ``resume_phase_t`` — the
journal timestamp of its restore point — so its worker re-enters the
virtual timeline exactly where the original left it and post-resume
results arrive in the same cross-trial order as an uninterrupted run
(the bit-identical-continuation contract; limits documented in §12).

With no usable snapshot the plan falls back to a **cold replay**: a fresh
scheduler is fed ``on_trial_add`` for the initial trials in generation
order and the *entire* journal becomes the tail.
"""
from __future__ import annotations

import inspect
import os
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

from ..obs.analysis import parse_journal_lines
from ..obs.flightrec import load_search_state
from .checkpoint import load_pytree
from .resources import Resources
from .schedulers.base import SchedulerDecision, TrialScheduler
from .search.basic import Searcher
from .trial import Checkpoint, Result, Trial, TrialStatus

__all__ = ["ResumePlan", "prepare_resume", "repair_journal"]

_TERMINAL = (TrialStatus.TERMINATED, TrialStatus.ERROR)


def repair_journal(path: str) -> int:
    """Truncate the torn tail a kill -9 may leave mid-write.

    JSONLLogger flushes one complete line per record, so the only possible
    damage is a final line without a newline terminator.  Returns the number
    of bytes dropped (0 for a clean journal)."""
    with open(path, "rb+") as f:
        data = f.read()
        if not data or data.endswith(b"\n"):
            return 0
        cut = data.rfind(b"\n") + 1
        f.truncate(cut)
        return len(data) - cut


@dataclass
class ResumePlan:
    """Everything ``TrialRunner.apply_resume_plan`` needs to continue a run."""

    trials: List[Trial] = field(default_factory=list)
    # trial_id -> last already-journaled result iteration of the current
    # lineage: the resumed worker's re-executed results at-or-below this are
    # dropped (runner result fence).
    result_fences: Dict[str, int] = field(default_factory=dict)
    # trial_id -> {event kind -> iteration bound} for non-result events
    # (CHECKPOINTED) the original run already journaled.
    event_fences: Dict[str, Dict[str, int]] = field(default_factory=dict)
    # Restored-trial relaunch order (phase-ascending): drained ahead of the
    # scheduler's own choose loop.
    resume_order: List[str] = field(default_factory=list)
    next_suggest_index: int = 0
    # Count of surviving journal records: the resumed JSONLLogger continues
    # its watermark from here.
    n_journal_records: int = 0
    used_snapshot: bool = False
    warnings: List[str] = field(default_factory=list)

    def summary(self) -> str:
        n_term = sum(1 for t in self.trials if t.status in _TERMINAL)
        n_paused = sum(1 for t in self.trials if t.status == TrialStatus.PAUSED)
        n_pending = len(self.trials) - n_term - n_paused
        return (f"resume: {len(self.trials)} trials "
                f"({n_term} finished, {n_paused} from checkpoint, "
                f"{n_pending} from scratch), "
                f"{self.n_journal_records} journal records, "
                f"{'snapshot' if self.used_snapshot else 'cold'} replay")


def _safe_id(trial_id: str) -> str:
    return trial_id.replace("/", "_")


def _mirror_path(ckpt_dir: Optional[str], trial_id: str, iteration: int
                 ) -> Optional[str]:
    if not ckpt_dir:
        return None
    return os.path.join(ckpt_dir, _safe_id(trial_id), f"iter_{iteration}.ckpt")


def _valid_mirror(path: Optional[str]) -> bool:
    """A mirror counts only if it loads: CRC + msgpack decode, so a file torn
    by the crash (or half-rotated) falls through to an older one."""
    if not path or not os.path.exists(path):
        return False
    try:
        load_pytree(path)
    except Exception:
        return False
    return True


def _latest_valid_mirror(ckpt_dir: Optional[str], trial_id: str,
                         frontier: int) -> Tuple[int, Optional[str]]:
    """Newest loadable mirror at-or-below the journal frontier, else (0, None).

    Mirrors above the frontier are skipped even when valid: after a PBT
    rewind they can belong to an abandoned lineage, and a checkpoint saved
    just before the kill whose *result* never reached the journal must be
    re-earned — the journal is the source of truth, so that iteration re-runs
    (its duplicate CHECKPOINTED event is fenced, its result is fresh)."""
    if not ckpt_dir or frontier <= 0:
        return 0, None
    d = os.path.join(ckpt_dir, _safe_id(trial_id))
    if not os.path.isdir(d):
        return 0, None
    iters: List[int] = []
    for fn in os.listdir(d):
        m = re.fullmatch(r"iter_(\d+)\.ckpt", fn)
        if m:
            iters.append(int(m.group(1)))
    for k in sorted(iters, reverse=True):
        if k <= frontier:
            path = os.path.join(d, f"iter_{k}.ckpt")
            if _valid_mirror(path):
                return k, path
    return 0, None


class _ReplayRunner:
    """The narrow slice of TrialRunner the scheduler hooks touch during
    replay: ``trials`` / ``get_trial`` for population scans, ``stop_trial``
    for peer stops (HyperBand cuts).  ``has_resources`` answers False so a
    scheduler probing capacity mid-replay stays passive."""

    def __init__(self, replay: "_Replay"):
        self._replay = replay

    @property
    def trials(self) -> List[Trial]:
        return self._replay.trial_list

    def get_trial(self, trial_id: str) -> Optional[Trial]:
        return self._replay.trial_map.get(trial_id)

    def stop_trial(self, trial: Trial) -> None:
        self._replay.shim_stop(trial)

    def has_resources(self, trial: Trial) -> bool:
        return False

    def next_ready(self, status: TrialStatus, fit: Any = None) -> Optional[Trial]:
        return None


class _Replay:
    """Two-phase journal replay + three-source reconciliation."""

    def __init__(self, scheduler: TrialScheduler, searcher: Optional[Searcher],
                 trainable_name: str, default_resources: Optional[Resources],
                 stopping_criteria: Optional[Dict[str, float]],
                 checkpoint_dir: Optional[str]):
        self.scheduler = scheduler
        self.searcher = searcher
        self.trainable_name = trainable_name
        self.default_resources = default_resources or Resources()
        self.stopping_criteria = dict(stopping_criteria or {})
        self.checkpoint_dir = checkpoint_dir
        self.shim = _ReplayRunner(self)
        self.trial_map: Dict[str, Trial] = {}
        self.trial_list: List[Trial] = []
        # -- journal-derived bookkeeping, all keyed by trial_id ---------------
        self.frontier: Dict[str, int] = {}       # current-lineage result frontier
        # iteration -> journal t of the result that (last) produced it; rewinds
        # (RESTARTED / exploit) stamp their own t at the rewind iteration, so
        # result_t[restore_k] is always the virtual time the current lineage
        # occupied state k — exactly the phase a restored worker must re-enter.
        self.result_t: Dict[str, Dict[int, float]] = {}
        self.ckpt_seen: Dict[str, int] = {}      # last journaled CHECKPOINTED iter
        self.pending_exploit: Dict[str, Dict[str, Any]] = {}
        self.completed_fed: Set[str] = set()
        self.active: Set[str] = set()            # produced at least one record
        self.max_sugg = -1
        self.warnings: List[str] = []
        self._sugg_pat = re.compile(
            rf"^{re.escape(trainable_name)}_sugg_(\d+)$")

    # -- trial identity -----------------------------------------------------------
    def seed_base_trials(self, base_trials: List[Trial]) -> None:
        """Fresh shells from the identity source (regenerated configs or the
        legacy pkl): id + config + resources survive, everything transient
        (results, status, checkpoints) is rebuilt from the journal."""
        for bt in base_trials:
            if bt.trial_id in self.trial_map:
                continue
            t = Trial(config=dict(bt.config),
                      trainable_name=self.trainable_name,
                      resources=bt.resources,
                      stopping_criteria=bt.stopping_criteria or self.stopping_criteria,
                      tag=bt.tag, trial_id=bt.trial_id)
            self.trial_map[t.trial_id] = t
            self.trial_list.append(t)

    def ensure(self, trial_id: str,
               config: Optional[Dict[str, Any]] = None) -> Trial:
        t = self.trial_map.get(trial_id)
        if t is None:
            t = Trial(config=dict(config or {}),
                      trainable_name=self.trainable_name,
                      resources=self.default_resources,
                      stopping_criteria=self.stopping_criteria,
                      trial_id=trial_id)
            self.trial_map[trial_id] = t
            self.trial_list.append(t)
        elif config and not t.config:
            t.config = dict(config)
        return t

    # -- searcher plumbing --------------------------------------------------------
    def observe(self, trial: Trial, final: bool) -> None:
        if self.searcher is None or trial.last_result is None:
            return
        metric = self.searcher.metric
        if metric in trial.last_result.metrics:
            self.searcher.observe(trial.trial_id, trial.config,
                                  trial.last_result.value(metric), final)

    def shim_stop(self, trial: Trial) -> None:
        """Replay analogue of TrialRunner.stop_trial."""
        if trial.trial_id in self.completed_fed:
            return
        if trial.status not in _TERMINAL:
            trial.status = TrialStatus.TERMINATED
        self.completed_fed.add(trial.trial_id)
        self.scheduler.on_trial_complete(self.shim, trial)
        self.observe(trial, final=True)

    def _drain(self) -> None:
        # Replay-regenerated decision records were journaled by the original
        # run already — discard them so the deque stays bounded and nothing
        # downstream re-journals them.
        self.scheduler.pop_decisions()

    # -- record handlers ----------------------------------------------------------
    def _rewind(self, tid: str, iteration: int, t: float) -> None:
        """A RESTARTED retry or an exploit rewound the trial to ``iteration``
        at journal time ``t``: the current lineage restarts there."""
        self.frontier[tid] = iteration
        self.result_t.setdefault(tid, {})[iteration] = float(t)
        self.ckpt_seen[tid] = min(self.ckpt_seen.get(tid, iteration), iteration)

    def _on_result(self, rec: Dict[str, Any], feed: bool,
                   records: List[Dict[str, Any]], i: int) -> None:
        tid = rec["trial_id"]
        cfg = rec.get("config")
        trial = self.ensure(tid, cfg if isinstance(cfg, dict) else None)
        if isinstance(cfg, dict) and cfg:
            # result records carry the *effective* config (post-exploit
            # mutations included) — the overlay keeps restored configs exact
            trial.config = dict(cfg)
        it = int(rec.get("iteration", 0))
        t = float(rec.get("t", 0.0))
        res = Result(tid, it, dict(rec.get("metrics") or {}), timestamp=t)
        trial.record_result(res)
        if trial.status not in _TERMINAL:
            trial.status = TrialStatus.RUNNING
        self.frontier[tid] = it
        self.result_t.setdefault(tid, {})[it] = t
        self.active.add(tid)
        self.pending_exploit.pop(tid, None)
        if not feed:
            return

        # Peek the contiguous decision records this result produced (the
        # journal writes them immediately after it): they tell us executor
        # state the replay cannot otherwise know.
        runner_stop = False
        exploit_t = t
        j = i + 1
        while j < len(records) and records[j].get("event") == "decision" \
                and (records[j].get("info") or {}).get("source") != "searcher":
            info = records[j].get("info") or {}
            if records[j].get("trial_id") == tid:
                v, src = info.get("verdict"), info.get("source")
                inp = info.get("inputs") or {}
                if src == "runner" and v == "STOP":
                    # The runner stopped it (stopping criterion / done) before
                    # the scheduler ever saw this result: don't feed it.
                    runner_stop = True
                elif src == "scheduler" and v == "RESTART_WITH_CONFIG":
                    # Force the donor's checkpoint so PBT's draw re-takes the
                    # exploit branch with the journaled donor iteration.
                    donor = self.ensure(str(inp.get("donor")))
                    d_it = int(inp.get("donor_iteration", 0))
                    donor.checkpoint = Checkpoint(
                        trial_id=donor.trial_id, training_iteration=d_it,
                        path=_mirror_path(self.checkpoint_dir,
                                          donor.trial_id, d_it))
                    exploit_t = float(records[j].get("t", t))
                elif v == "EXPLOIT_SKIPPED":
                    if not inp.get("donor_is_self") \
                            and not inp.get("donor_has_checkpoint"):
                        d = self.trial_map.get(str(inp.get("donor")))
                        if d is not None:
                            d.checkpoint = None
            j += 1

        if runner_stop:
            self.shim_stop(trial)
            self._drain()
            return
        verdict = self.scheduler.on_result(self.shim, trial, res)
        self._drain()
        self.observe(trial, final=False)
        self._apply_verdict(trial, verdict, exploit_t)

    def _apply_verdict(self, trial: Trial, verdict: SchedulerDecision,
                       exploit_t: float) -> None:
        tid = trial.trial_id
        if verdict == SchedulerDecision.PAUSE:
            if trial.status not in _TERMINAL:
                trial.status = TrialStatus.PAUSED
        elif verdict == SchedulerDecision.STOP:
            self.shim_stop(trial)
            self._drain()
        elif verdict == SchedulerDecision.RESTART_WITH_CONFIG:
            ckpt = trial.scheduler_state.pop("restore_from", None)
            new_config = trial.scheduler_state.pop("new_config", None)
            trial.scheduler_state.pop("cloned_from", None)
            if ckpt is None:
                return
            ckpt.pinned = False
            if isinstance(new_config, dict):
                trial.config = dict(new_config)
            self.pending_exploit[tid] = {
                "donor": ckpt.trial_id,
                "donor_iteration": int(ckpt.training_iteration),
                "new_config": dict(new_config or {})}
            self._rewind(tid, int(ckpt.training_iteration), exploit_t)
            if trial.status not in _TERMINAL:
                trial.status = TrialStatus.RUNNING

    def _on_decision(self, rec: Dict[str, Any], feed: bool) -> None:
        tid = rec.get("trial_id") or ""
        info = rec.get("info") or {}
        src, v = info.get("source"), info.get("verdict")
        inp = info.get("inputs") or {}
        t = float(rec.get("t", 0.0))
        if src == "searcher":
            m = self._sugg_pat.match(tid)
            if m:
                self.max_sugg = max(self.max_sugg, int(m.group(1)))
            trial = self.ensure(tid)
            if feed and self.searcher is not None:
                # Re-invoking suggest replays the searcher's RNG/grid advance
                # and regenerates the identical config.
                cfg = self.searcher.suggest(tid)
                if cfg is not None:
                    trial.config = dict(cfg)
                elif not trial.config:
                    self.warnings.append(
                        f"searcher exhausted re-suggesting {tid}; its config "
                        f"falls back to journal result records")
                self.scheduler.on_trial_add(self.shim, trial)
                self._drain()
            return
        if v == "PROMOTE":
            # A synchronous-cut survivor relaunches at the *cut* time, not at
            # its own milestone arrival: shift the restore phase forward.
            # (Both replay modes: the feed re-fills the scheduler's promote
            # queue, but the phase stamp is pure resume bookkeeping.)
            k = self.frontier.get(tid)
            if k is not None:
                self.result_t.setdefault(tid, {})[k] = t
            if feed:
                return
        if feed:
            # Tail decisions' state effects were produced by the feeds
            # themselves; applying the record too would double them.
            return
        trial = self.ensure(tid)
        if v == "PAUSE":
            if trial.status not in _TERMINAL:
                trial.status = TrialStatus.PAUSED
        elif v == "STOP":
            if trial.status not in _TERMINAL:
                trial.status = TrialStatus.TERMINATED
        elif v == "RESTART_WITH_CONFIG":
            new_config = inp.get("new_config")
            if isinstance(new_config, dict):
                trial.config = dict(new_config)
            d_it = int(inp.get("donor_iteration", 0))
            self.pending_exploit[tid] = {
                "donor": str(inp.get("donor")), "donor_iteration": d_it,
                "new_config": dict(new_config or {})}
            self._rewind(tid, d_it, t)
            if trial.status not in _TERMINAL:
                trial.status = TrialStatus.RUNNING

    def _on_complete(self, rec: Dict[str, Any], feed: bool) -> None:
        tid = rec["trial_id"]
        trial = self.ensure(tid)
        try:
            status = TrialStatus(rec.get("status"))
        except ValueError:
            status = TrialStatus.TERMINATED
        self.active.add(tid)
        if feed and tid not in self.completed_fed:
            trial.status = status
            if status == TrialStatus.ERROR:
                # The runner's error path feeds on_trial_error (never
                # on_trial_complete — _finalize_error skips it).
                self.scheduler.on_trial_error(self.shim, trial)
            else:
                self.scheduler.on_trial_complete(self.shim, trial)
            self._drain()
            self.observe(trial, final=True)
            self.completed_fed.add(tid)
        else:
            trial.status = status

    def _on_restarted(self, rec: Dict[str, Any]) -> None:
        tid = rec["trial_id"]
        trial = self.ensure(tid)
        info = rec.get("info") or {}
        self.active.add(tid)
        if info.get("num_failures") is not None:
            trial.num_failures = int(info["num_failures"])
        c = info.get("checkpoint_iteration")
        if c is None:
            return  # pre-§12 journal: frontier keeps its last result value
        c = int(c)
        self._rewind(tid, c, float(rec.get("t", 0.0)))
        if trial.status not in _TERMINAL:
            trial.status = (TrialStatus.PAUSED if c > 0 else TrialStatus.PENDING)

    # -- main loop ---------------------------------------------------------------
    def replay(self, records: List[Dict[str, Any]], watermark: int) -> None:
        for i, rec in enumerate(records):
            kind = rec.get("event")
            tid = rec.get("trial_id")
            if not isinstance(tid, str):
                continue
            feed = i >= watermark
            if kind == "result":
                self._on_result(rec, feed, records, i)
            elif kind == "decision":
                self._on_decision(rec, feed)
            elif kind == "complete":
                self._on_complete(rec, feed)
            elif kind == "restarted":
                self._on_restarted(rec)
            elif kind == "checkpointed":
                self.active.add(tid)
                it = (rec.get("info") or {}).get("iteration")
                if it is not None:
                    self.ckpt_seen[tid] = int(it)
            elif kind == "profile":
                self.ensure(tid).profile = rec.get("info") or {}
        self._drain()

    # -- reconciliation -----------------------------------------------------------
    def reconcile(self) -> Tuple[Dict[str, int], Dict[str, Dict[str, int]],
                                 List[str]]:
        """Match every non-terminal trial to its best recovery source.

        Returns (result_fences, event_fences, resume_order)."""
        result_fences: Dict[str, int] = {}
        event_fences: Dict[str, Dict[str, int]] = {}
        entries: List[Tuple[float, int, str]] = []
        for idx, trial in enumerate(self.trial_list):
            tid = trial.trial_id
            if trial.status in _TERMINAL:
                # A finished trial keeps its last checkpoint in the live run
                # — a later PBT exploit may pick it as donor.  Rebuild that
                # reference from its newest surviving mirror.
                bound = max(self.frontier.get(tid, 0),
                            self.ckpt_seen.get(tid, 0))
                k, path = _latest_valid_mirror(self.checkpoint_dir, tid, bound)
                if path is not None:
                    trial.checkpoint = Checkpoint(
                        trial_id=tid, training_iteration=k, path=path)
                continue
            trial.scheduler_state.pop("restore_from", None)
            trial.scheduler_state.pop("new_config", None)
            trial.scheduler_state.pop("cloned_from", None)
            f = self.frontier.get(tid, 0)
            pe = self.pending_exploit.get(tid)
            if pe is not None:
                # Exploit staged but no post-exploit result journaled: restore
                # the donor's mirror under the mutated config — equivalent to
                # the restart_trial_with_config the crash pre-empted.
                donor, d_it = pe["donor"], pe["donor_iteration"]
                path = _mirror_path(self.checkpoint_dir, donor, d_it)
                if _valid_mirror(path):
                    trial.checkpoint = Checkpoint(
                        trial_id=donor, training_iteration=d_it, path=path)
                    trial.status = TrialStatus.PAUSED
                else:
                    self.warnings.append(
                        f"{tid}: exploit donor mirror {donor}@{d_it} missing "
                        f"or invalid; restarting from scratch (value-exact "
                        f"for iteration-determined trainables, timing is not)")
                    trial.checkpoint = None
                    trial.status = TrialStatus.PENDING
                if d_it > 0:
                    result_fences[tid] = d_it
                phase = self.result_t.get(tid, {}).get(d_it)
                trial.resume_phase_t = phase
                entries.append((phase if phase is not None else float("inf"),
                                idx, tid))
                continue
            if tid not in self.active and not trial.results:
                # Never started: a plain PENDING trial the scheduler launches
                # through its own choose loop, after restored ones re-fill.
                trial.status = TrialStatus.PENDING
                continue
            k, path = _latest_valid_mirror(self.checkpoint_dir, tid, f)
            if path is not None:
                trial.checkpoint = Checkpoint(
                    trial_id=tid, training_iteration=k, path=path)
                trial.status = TrialStatus.PAUSED
            else:
                if f > 0:
                    self.warnings.append(
                        f"{tid}: no valid checkpoint mirror at or below "
                        f"iteration {f}; restarting from scratch")
                trial.checkpoint = None
                trial.status = TrialStatus.PENDING
                k = 0
            if f > 0:
                result_fences[tid] = f
            cs = self.ckpt_seen.get(tid, 0)
            if cs > k:
                event_fences[tid] = {"checkpointed": cs}
            phase = self.result_t.get(tid, {}).get(k)
            trial.resume_phase_t = phase
            entries.append((phase if phase is not None else float("inf"),
                            idx, tid))
        entries.sort()
        return result_fences, event_fences, [tid for _, _, tid in entries]


def prepare_resume(
    journal_path: str,
    search_state_path: Optional[str],
    scheduler: TrialScheduler,
    searcher: Optional[Searcher] = None,
    base_trials: Optional[List[Trial]] = None,
    checkpoint_dir: Optional[str] = None,
    trainable_name: str = "trainable",
    default_resources: Optional[Resources] = None,
    stopping_criteria: Optional[Dict[str, float]] = None,
) -> ResumePlan:
    """Rebuild a killed run's full state into a :class:`ResumePlan`.

    ``scheduler`` (and ``searcher``, when given) must be **freshly
    constructed** with the original run's arguments: their mutable state is
    installed here — from the watermarked snapshot when one is usable, else
    by cold-replaying the whole journal through them.

    ``base_trials`` is the identity source for the run's *initial* trial
    set — same ids, same configs, same generation order as the original
    process (regenerated from the space, or loaded from the legacy pkl).
    Trials the searcher suggested mid-run are reconstructed from the journal
    itself.  Only identity fields are read; transient state is rebuilt.
    """
    repair_journal(journal_path)
    with open(journal_path, "r") as f:
        header, records, skipped = parse_journal_lines(f)

    replay = _Replay(scheduler, searcher, trainable_name, default_resources,
                     stopping_criteria, checkpoint_dir)
    replay.seed_base_trials(list(base_trials or []))

    # -- snapshot: how much of the journal is already folded in? -----------------
    state = load_search_state(search_state_path) if search_state_path else None
    watermark = 0
    used_snapshot = False
    searcher_state: Optional[Dict[str, Any]] = None
    if state is not None:
        w = state.get("journal_records")
        sch = state.get("scheduler") or {}
        if (isinstance(w, int) and 0 <= w <= len(records)
                and sch.get("type") == type(scheduler).__name__):
            watermark, used_snapshot = w, True
            se = state.get("searcher") or {}
            if searcher is not None and se.get("type") == type(searcher).__name__:
                searcher_state = se.get("state")
        else:
            replay.warnings.append(
                "search_state.json unusable (missing watermark or "
                "scheduler type mismatch); cold-replaying the full journal")

    if used_snapshot:
        # Shells for every trial the snapshot may reference (HyperBand
        # serializes bracket members by id and resolves them on load).
        for rec in records[:watermark]:
            tid = rec.get("trial_id")
            if isinstance(tid, str):
                cfg = rec.get("config") if rec.get("event") == "result" else None
                replay.ensure(tid, cfg if isinstance(cfg, dict) else None)
        try:
            sched_state = (state.get("scheduler") or {}).get("state") or {}
            if "trials" in inspect.signature(scheduler.load_state_dict).parameters:
                scheduler.load_state_dict(sched_state, trials=replay.trial_map)
            else:
                scheduler.load_state_dict(sched_state)
        except Exception as e:
            replay.warnings.append(
                f"scheduler snapshot failed to load ({e!r}); "
                f"cold-replaying the full journal")
            watermark, used_snapshot = 0, False
    if used_snapshot and searcher_state is not None:
        try:
            searcher.load_state_dict(searcher_state)
            # Suggested-but-resultless trials have no config in the journal
            # yet; TPE/GP snapshots carry it in their pending map.
            for tid, cfg in (searcher_state.get("pending") or {}).items():
                if isinstance(cfg, dict):
                    replay.ensure(str(tid), cfg)
        except Exception as e:
            replay.warnings.append(f"searcher snapshot failed to load ({e!r}); "
                                   f"searcher continues from its fresh state")

    if not used_snapshot:
        # Cold replay: re-register the initial trials in generation order so
        # per-add scheduler state (ASHA's bracket draws, HyperBand membership)
        # rebuilds exactly; suggested trials re-add at their journal records.
        for trial in replay.trial_list:
            if not replay._sugg_pat.match(trial.trial_id):
                scheduler.on_trial_add(replay.shim, trial)
        replay._drain()

    replay.replay(records, watermark)
    result_fences, event_fences, resume_order = replay.reconcile()

    return ResumePlan(
        trials=replay.trial_list,
        result_fences=result_fences,
        event_fences=event_fences,
        resume_order=resume_order,
        next_suggest_index=replay.max_sugg + 1,
        n_journal_records=len(records),
        used_snapshot=used_snapshot,
        warnings=replay.warnings,
    )
