"""Elastic resource control plane — checkpoint-boundary slice resize and
bounded result lookahead (DESIGN.md §6).

The SlicePool decouples trials from devices, but through PR 3 a trial's slice
was fixed for its whole life: capacity freed by early-stopped trials sat idle
while big survivors stayed small — exactly the utilization gap ASHA-style
aggressive early stopping creates.  This module closes it with a small
control plane layered *on top of* the executors, never inside them:

- ``ResourceBroker`` rides the runner's event loop.  At every checkpoint
  boundary — the moment a trial's worker is parked waiting for the
  scheduler's CONTINUE — it asks a ``ResizePolicy`` whether the trial's
  ``MeshSlice`` should grow or shrink, and drives the executor's resize
  protocol (SAVE → swap slice in the pool → rebuild mesh + re-shard →
  RESTORE onto the new sub-mesh).  A failed rebuild rolls back to the exact
  old device range; the trial never observes a torn state.
- The same broker issues **lookahead credits**: how many un-consumed results
  a worker may run ahead of the scheduler.  ``k > 1`` removes a control-plane
  round-trip (a pipe RTT, for process workers) from every step of a
  throughput-bound sweep.  Exactness is preserved automatically: the broker
  consults ``Scheduler.decision_interval()`` and clamps credits to 1 whenever
  the scheduler can stop/pause/perturb trials (ASHA, HyperBand, PBT,
  MedianStopping); only pure run-to-completion schedulers (FIFO, interval 0)
  get the full requested lookahead.

Policies are deliberately dumb and pluggable — they see the runner, the pool
stats (``utilization``/``largest_free_block``/``fragments``) and the trial's
current slice, and return a target size or None.  All actual mutation stays
on the runner thread inside the executor, so the threading contracts of
DESIGN.md §4/§5 are untouched.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, TYPE_CHECKING

from .clock import Clock, get_default_clock
from .events import EventType, TrialEvent
from .trial import Trial, TrialStatus

if TYPE_CHECKING:  # pragma: no cover
    from .runner import TrialRunner

__all__ = ["ResizePolicy", "GreedyFill", "FairShare", "ResourceBroker",
           "resolve_policy"]


class ResizePolicy:
    """Decides a trial's target slice size at a checkpoint boundary.

    ``propose`` is called on the runner thread for a RUNNING trial whose
    worker is parked (idle at the resume gate), with the live pool and the
    trial's currently held slice.  Return the desired device count, or None
    to leave the trial alone.  Feasibility should be checked with
    ``pool.can_resize`` — proposing the impossible just burns a
    RESIZE_FAILED event.
    """

    name = "policy"

    def propose(self, runner: "TrialRunner", trial: Trial,
                pool: Any, sl: Any) -> Optional[int]:
        raise NotImplementedError


class GreedyFill(ResizePolicy):
    """Survivors absorb freed devices: double a RUNNING trial's slice while
    the pool can host the growth.

    Growth is gated on a scheduler survival signal: a trial must have
    advanced past the scheduler's grace period (ASHA/median ``grace_period``;
    1 otherwise) before it is considered a survivor worth feeding — capacity
    freed at the first rung cut should flow to trials that outlived the cut,
    not to whichever straggler reported first.  One doubling per checkpoint
    boundary keeps the absorb gradual and the rebuild cost amortized.
    """

    name = "greedy"

    def __init__(self, factor: int = 2, max_devices: Optional[int] = None):
        if factor < 2:
            raise ValueError("growth factor must be >= 2")
        self.factor = factor
        self.max_devices = max_devices

    def propose(self, runner, trial, pool, sl):
        survived_t = int(getattr(runner.scheduler, "grace_period", 1) or 1)
        if trial.training_iteration < survived_t:
            return None
        cap = min(self.max_devices or pool.n_total, pool.n_total)
        target = sl.size * self.factor
        if target > cap or not pool.can_resize(sl, target):
            return None
        return target


class FairShare(ResizePolicy):
    """Rebalance the pool equally across RUNNING trials.

    Target = ``n_total // n_running`` rounded down to a power of two (mesh
    shapes and sharding divisibility like powers of two), floored at
    ``min_devices``.  Shrinks oversized trials as eagerly as it grows
    undersized ones, so a late-arriving PENDING trial can be placed at the
    next boundary instead of waiting for a survivor to finish.
    """

    name = "fair"

    def __init__(self, min_devices: int = 1, round_pow2: bool = True):
        self.min_devices = max(1, int(min_devices))
        self.round_pow2 = round_pow2

    def propose(self, runner, trial, pool, sl):
        running = sum(1 for t in runner.trials if t.status == TrialStatus.RUNNING)
        # Trials waiting for capacity count toward the denominator: the fair
        # share must leave room for them to actually launch.
        waiting = sum(1 for t in runner.trials
                      if t.status in (TrialStatus.PENDING, TrialStatus.PAUSED))
        share = pool.n_total // max(1, running + waiting)
        if self.round_pow2 and share >= 1:
            p = 1
            while p * 2 <= share:
                p *= 2
            share = p
        share = max(self.min_devices, share)
        if share == sl.size:
            return None
        if share > sl.size and not pool.can_resize(sl, share):
            return None
        return share


_POLICIES: Dict[str, type] = {"greedy": GreedyFill, "fair": FairShare}


def resolve_policy(spec: Any) -> Optional[ResizePolicy]:
    """``None``/``"off"`` -> None; a ResizePolicy instance passes through; a
    name ("greedy"/"fair") builds the default-configured policy."""
    if spec is None or spec == "off":
        return None
    if isinstance(spec, ResizePolicy):
        return spec
    try:
        return _POLICIES[spec]()
    except KeyError:
        raise ValueError(
            f"unknown elastic policy {spec!r}; pass 'off', 'greedy', 'fair', "
            f"or a ResizePolicy instance") from None


class ResourceBroker:
    """The elastic control plane: one per TrialRunner, driven on its thread.

    ``bind`` installs the effective lookahead on the executor (computed from
    the scheduler's declared decision granularity), ``observe`` watches the
    event stream for bookkeeping, and ``before_resume`` is the checkpoint
    boundary hook — the runner calls it right before re-opening a trial's
    resume gate, which is the only moment a RUNNING trial's worker is
    guaranteed parked and resizable.
    """

    def __init__(self, policy: Optional[ResizePolicy] = None,
                 lookahead: int = 1, clock: Optional[Clock] = None):
        if lookahead < 1:
            raise ValueError(f"lookahead must be >= 1, got {lookahead}")
        self.policy = policy
        self.lookahead = int(lookahead)
        self.clock = clock  # None = adopt the executor's clock at bind()
        self.effective_lookahead = 1
        self.decision_interval = 1
        self.n_resized = 0
        self.n_resize_failed = 0
        self.n_events = 0
        self._runner: Optional["TrialRunner"] = None
        self._announced: set = set()  # trial_ids whose credit grant was logged

    # -- wiring ---------------------------------------------------------------------
    def bind(self, runner: "TrialRunner") -> None:
        self._runner = runner
        if self.clock is None:
            # The broker's CREDITS/RESIZED events go straight to the loggers
            # (never through a bus that would stamp them), so they must share
            # the executor's time axis to sort against bus events.
            self.clock = getattr(runner.executor, "clock", None) or get_default_clock()
        self.decision_interval = int(runner.scheduler.decision_interval())
        # Exactness rule: any scheduler that can stop/pause/perturb (nonzero
        # interval) gets k=1, so every decision is made on a parked worker and
        # elastic runs reproduce the serial tier's decisions exactly.  Pure
        # run-to-completion schedulers get the full requested lookahead.
        self.effective_lookahead = (self.lookahead
                                    if self.decision_interval == 0 else 1)
        runner.executor.set_lookahead(self.effective_lookahead)

    # -- event-loop hooks -------------------------------------------------------------
    def observe(self, runner: "TrialRunner", event: TrialEvent) -> None:
        """Extension point: every bus event flows through here before the
        runner acts on it, so a stateful broker/policy subclass can track
        e.g. stop rates or per-trial progress.  The base broker only counts
        events for ``debug_string``."""
        self.n_events += 1

    def before_resume(self, runner: "TrialRunner", trial: Trial) -> None:
        """Checkpoint-boundary hook: the scheduler said CONTINUE and the
        trial's worker is parked.  Announce the credit grant once, then let
        the policy propose a resize."""
        if (trial.trial_id not in self._announced
                and (self.lookahead != 1 or self.effective_lookahead != 1)):
            self._announced.add(trial.trial_id)
            runner.logger.on_event(trial, TrialEvent(
                EventType.CREDITS, trial.trial_id,
                info={"requested": self.lookahead,
                      "granted": self.effective_lookahead,
                      "decision_interval": self.decision_interval},
                timestamp=self.clock.time()))
        if self.policy is None:
            return
        ex = runner.executor
        # Per-trial pool when the executor places across hosts (cluster tier);
        # the shared pool otherwise.  Rebalancing stays within one failure
        # domain — slices never span hosts.
        pool_fn = getattr(ex, "_pool_for", None)
        pool = (pool_fn(trial) if callable(pool_fn)
                else getattr(ex, "slice_pool", None))
        if pool is None or not ex.trial_idle(trial):
            return
        sl = ex.held_slice(trial.trial_id)
        if sl is None:
            return
        target = self.policy.propose(runner, trial, pool, sl)
        if target is None or target == sl.size:
            return
        from_devices = sl.size
        tracer = runner.obs.tracer
        if tracer.enabled:
            with tracer.span("resize", trial.trial_id, cat="elastic",
                             from_devices=from_devices, to_devices=target,
                             policy=self.policy.name) as sp:
                ok = ex.resize_trial(trial, target)
                sp.arg("ok", ok)
        else:
            ok = ex.resize_trial(trial, target)
        m = runner.obs.metrics
        info = {"from_devices": from_devices, "to_devices": target,
                "policy": self.policy.name,
                "utilization": round(pool.utilization(), 3),
                "holes": pool.fragments(),
                "largest_free_block": pool.largest_free_block()}
        if ok:
            self.n_resized += 1
            if m is not None:
                m.counter("trials.resized").inc()
            runner.logger.on_event(trial, TrialEvent(
                EventType.RESIZED, trial.trial_id, info=info,
                timestamp=self.clock.time()))
        else:
            self.n_resize_failed += 1
            if m is not None:
                m.counter("trials.resize_failed").inc()
            runner.logger.on_event(trial, TrialEvent(
                EventType.RESIZE_FAILED, trial.trial_id, info=info,
                timestamp=self.clock.time()))

    def debug_string(self) -> str:
        return (f"ResourceBroker(policy={self.policy.name if self.policy else 'off'}, "
                f"lookahead={self.effective_lookahead}/{self.lookahead}, "
                f"resized={self.n_resized}, failed={self.n_resize_failed}, "
                f"events={self.n_events})")
