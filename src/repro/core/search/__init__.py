from .space import (
    Categorical, Domain, Function, GridSearch, LogUniform, Normal, QRandInt,
    RandInt, Uniform, choice, grid_search, loguniform, normal, qrandint,
    randint, sample_from, sample_space, space_signature,
)
from .variants import count_grid_variants, format_variant_tag, generate_variants
from .basic import GridSearcher, RandomSearcher, Searcher
from .tpe import TPESearcher
from .gp import GPSearcher
