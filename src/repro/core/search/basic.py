"""Searcher interface + random/grid searchers.

The paper folds search algorithms into schedulers ("they can add to the list of
trials to execute (e.g., based on suggestions from HyperOpt)" §4.2).  We keep a
small ``Searcher`` interface (suggest/observe) and an adapter scheduler
(``SearchAlgorithmScheduler``) that feeds suggestions into the runner as
capacity frees up — so any Searcher composes with any TrialScheduler's
early-stopping behaviour.
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, List, Optional

import numpy as np

from .space import sample_space
from .variants import generate_variants

__all__ = ["Searcher", "RandomSearcher", "GridSearcher"]


class Searcher:
    def __init__(self, space: Dict[str, Any], metric: str = "loss", mode: str = "min"):
        self.space = space
        self.metric = metric
        self.mode = mode

    def suggest(self, trial_id: str) -> Optional[Dict[str, Any]]:
        """Return the next config to try, or None when exhausted."""
        raise NotImplementedError

    def observe(self, trial_id: str, config: Dict[str, Any], value: float, final: bool) -> None:
        """Feed back an observed metric value for a suggested config."""

    def _score(self, value: float) -> float:
        return value if self.mode == "max" else -value


class RandomSearcher(Searcher):
    def __init__(self, space, metric="loss", mode="min", max_trials: int = 0, seed: int = 0):
        super().__init__(space, metric, mode)
        self.max_trials = max_trials
        self._rng = np.random.default_rng(seed)
        self._count = 0

    def suggest(self, trial_id: str) -> Optional[Dict[str, Any]]:
        if self.max_trials and self._count >= self.max_trials:
            return None
        self._count += 1
        return sample_space(self.space, self._rng)


class GridSearcher(Searcher):
    """Exhausts the grid cross-product (stochastic domains sampled once each)."""

    def __init__(self, space, metric="loss", mode="min", num_samples: int = 1, seed: int = 0):
        super().__init__(space, metric, mode)
        self._it = generate_variants(space, num_samples=num_samples, seed=seed)

    def suggest(self, trial_id: str) -> Optional[Dict[str, Any]]:
        try:
            return next(self._it)
        except StopIteration:
            return None
