"""Searcher interface + random/grid searchers.

The paper folds search algorithms into schedulers ("they can add to the list of
trials to execute (e.g., based on suggestions from HyperOpt)" §4.2).  We keep a
small ``Searcher`` interface (suggest/observe) and an adapter scheduler
(``SearchAlgorithmScheduler``) that feeds suggestions into the runner as
capacity frees up — so any Searcher composes with any TrialScheduler's
early-stopping behaviour.
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, List, Optional

import numpy as np

from .space import sample_space
from .variants import generate_variants

__all__ = ["Searcher", "RandomSearcher", "GridSearcher"]


class Searcher:
    def __init__(self, space: Dict[str, Any], metric: str = "loss", mode: str = "min"):
        self.space = space
        self.metric = metric
        self.mode = mode
        self._last_explain: Optional[Dict[str, Any]] = None

    def suggest(self, trial_id: str) -> Optional[Dict[str, Any]]:
        """Return the next config to try, or None when exhausted."""
        raise NotImplementedError

    def observe(self, trial_id: str, config: Dict[str, Any], value: float, final: bool) -> None:
        """Feed back an observed metric value for a suggested config."""

    def _score(self, value: float) -> float:
        return value if self.mode == "max" else -value

    # -- decision provenance (DESIGN.md §10) ------------------------------------
    def _record_suggest(self, trial_id: str, **inputs: Any) -> Dict[str, Any]:
        """Record the inputs behind the last suggest() for explain_last()."""
        rec = {"trial_id": trial_id, "verdict": "SUGGEST", "iteration": None,
               "inputs": inputs}
        self._last_explain = rec
        return rec

    def explain_last(self) -> Optional[Dict[str, Any]]:
        """The most recent suggestion record (inputs behind it), or None."""
        return self._last_explain

    # -- durable state (DESIGN.md §10) ------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        """JSON-serializable snapshot of the searcher's mutable state."""
        return {}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        """Restore from a ``state_dict()`` snapshot.  Base: nothing to do."""


class RandomSearcher(Searcher):
    def __init__(self, space, metric="loss", mode="min", max_trials: int = 0, seed: int = 0):
        super().__init__(space, metric, mode)
        self.max_trials = max_trials
        self._rng = np.random.default_rng(seed)
        self._count = 0

    def suggest(self, trial_id: str) -> Optional[Dict[str, Any]]:
        if self.max_trials and self._count >= self.max_trials:
            return None
        self._count += 1
        self._record_suggest(trial_id, strategy="random",
                             n_suggested=self._count,
                             max_trials=self.max_trials)
        return sample_space(self.space, self._rng)

    def state_dict(self) -> Dict[str, Any]:
        return {"rng": self._rng.bit_generator.state, "count": self._count}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self._rng = np.random.default_rng()
        self._rng.bit_generator.state = state["rng"]
        self._count = int(state["count"])


class GridSearcher(Searcher):
    """Exhausts the grid cross-product (stochastic domains sampled once each)."""

    def __init__(self, space, metric="loss", mode="min", num_samples: int = 1, seed: int = 0):
        super().__init__(space, metric, mode)
        self.num_samples = num_samples
        self.seed = seed
        self._it = generate_variants(space, num_samples=num_samples, seed=seed)
        self._n_emitted = 0

    def suggest(self, trial_id: str) -> Optional[Dict[str, Any]]:
        try:
            cfg = next(self._it)
        except StopIteration:
            return None
        self._n_emitted += 1
        self._record_suggest(trial_id, strategy="grid",
                             index=self._n_emitted - 1)
        return cfg

    def state_dict(self) -> Dict[str, Any]:
        # The live generator can't serialize; snapshot how far it advanced
        # and fast-forward a rebuilt one on load (deterministic: same seed).
        return {"n_emitted": self._n_emitted}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self._it = generate_variants(self.space, num_samples=self.num_samples,
                                     seed=self.seed)
        self._n_emitted = 0
        for _ in range(int(state["n_emitted"])):
            try:
                next(self._it)
            except StopIteration:
                break
            self._n_emitted += 1
