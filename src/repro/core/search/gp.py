"""Gaussian-process Bayesian optimization searcher (expected improvement).

Beyond the paper's integrations (it lists HyperOpt/TPE): a numpy-only GP with
an RBF kernel over normalized continuous dims, EI maximized over random
candidates.  Complements TPE: better sample-efficiency on smooth, low-dim
spaces; same ``Searcher`` interface, so it composes with every scheduler.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .basic import Searcher
from .space import Categorical, Domain, LogUniform, RandInt, Uniform, sample_space

__all__ = ["GPSearcher"]


class _GP:
    """RBF-kernel GP regression with Cholesky solves (no scipy)."""

    def __init__(self, X: np.ndarray, y: np.ndarray,
                 length_scale: float = 0.2, noise: float = 1e-4):
        self.X = X
        self.mu = y.mean()
        self.sigma_y = max(y.std(), 1e-8)
        self.y = (y - self.mu) / self.sigma_y
        self.ls = length_scale
        K = self._kernel(X, X) + noise * np.eye(len(X))
        self.L = np.linalg.cholesky(K)
        self.alpha = np.linalg.solve(
            self.L.T, np.linalg.solve(self.L, self.y))

    def _kernel(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        d2 = ((A[:, None, :] - B[None, :, :]) ** 2).sum(-1)
        return np.exp(-0.5 * d2 / self.ls**2)

    def predict(self, Xs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        Ks = self._kernel(Xs, self.X)
        mean = Ks @ self.alpha
        v = np.linalg.solve(self.L, Ks.T)
        var = np.maximum(1.0 - (v**2).sum(0), 1e-12)
        return mean * self.sigma_y + self.mu, np.sqrt(var) * self.sigma_y


def _norm_cdf(z):
    return 0.5 * (1.0 + np.vectorize(math.erf)(z / math.sqrt(2.0)))


def _norm_pdf(z):
    return np.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)


class GPSearcher(Searcher):
    def __init__(self, space: Dict[str, Any], metric: str = "loss",
                 mode: str = "min", n_startup_trials: int = 8,
                 n_candidates: int = 256, length_scale: float = 0.2,
                 xi: float = 0.01, max_trials: int = 0, seed: int = 0):
        super().__init__(space, metric, mode)
        self.n_startup = n_startup_trials
        self.n_candidates = n_candidates
        self.ls = length_scale
        self.xi = xi
        self.max_trials = max_trials
        self._rng = np.random.default_rng(seed)
        self._history: List[Tuple[Dict[str, Any], float]] = []  # (cfg, score↑)
        self._count = 0
        self._cont_dims = [(k, v) for k, v in space.items()
                           if isinstance(v, (Uniform, LogUniform, RandInt))]
        if not self._cont_dims:
            raise ValueError("GPSearcher needs >=1 continuous/int dimension")

    # -- unit-cube encoding ------------------------------------------------------
    def _encode(self, cfg: Dict[str, Any]) -> np.ndarray:
        out = []
        for k, d in self._cont_dims:
            v = float(cfg[k])
            if isinstance(d, LogUniform):
                out.append((math.log(v) - math.log(d.low))
                           / (math.log(d.high) - math.log(d.low)))
            else:
                out.append((v - d.low) / (d.high - d.low))
        return np.asarray(out)

    def _decode_into(self, u: np.ndarray, cfg: Dict[str, Any]) -> Dict[str, Any]:
        for (k, d), ui in zip(self._cont_dims, u):
            ui = float(np.clip(ui, 0.0, 1.0))
            if isinstance(d, LogUniform):
                cfg[k] = math.exp(math.log(d.low)
                                  + ui * (math.log(d.high) - math.log(d.low)))
            elif isinstance(d, RandInt):
                cfg[k] = int(round(d.low + ui * (d.high - 1 - d.low)))
            else:
                cfg[k] = d.low + ui * (d.high - d.low)
        return cfg

    # -- Searcher interface ---------------------------------------------------------
    def observe(self, trial_id, config, value, final) -> None:
        if final:
            self._history.append((config, self._score(value)))

    def suggest(self, trial_id: str) -> Optional[Dict[str, Any]]:
        if self.max_trials and self._count >= self.max_trials:
            return None
        self._count += 1
        base = sample_space(self.space, self._rng)
        if len(self._history) < self.n_startup:
            self._record_suggest(trial_id, strategy="random_startup",
                                 n_obs=len(self._history),
                                 n_startup=self.n_startup)
            return base
        X = np.stack([self._encode(c) for c, _ in self._history])
        y = np.asarray([s for _, s in self._history])  # higher better
        try:
            gp = _GP(X, y, length_scale=self.ls)
        except np.linalg.LinAlgError:
            self._record_suggest(trial_id, strategy="random_fallback",
                                 n_obs=len(self._history),
                                 reason="gp_cholesky_failed")
            return base
        cands = self._rng.uniform(0, 1, size=(self.n_candidates, X.shape[1]))
        mean, std = gp.predict(cands)
        best = y.max()
        z = (mean - best - self.xi) / std
        ei = (mean - best - self.xi) * _norm_cdf(z) + std * _norm_pdf(z)
        i = int(np.argmax(ei))
        self._record_suggest(trial_id, strategy="gp_ei",
                             n_obs=len(self._history), best_score=float(best),
                             ei=float(ei[i]), posterior_mean=float(mean[i]),
                             posterior_std=float(std[i]))
        return self._decode_into(cands[i], base)

    def state_dict(self) -> Dict[str, Any]:
        return {"rng": self._rng.bit_generator.state,
                "history": [[dict(c), float(s)] for c, s in self._history],
                "count": self._count}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self._rng = np.random.default_rng()
        self._rng.bit_generator.state = state["rng"]
        self._history = [(dict(c), float(s)) for c, s in state["history"]]
        self._count = int(state["count"])
