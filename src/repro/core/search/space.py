"""Search-space DSL.

The paper (§4.3) provides "a small DSL to specify hyperparameter variations",
offering "features similar to those provided by HyperOpt".  We implement the
same surface: ``grid_search`` for exhaustive axes and a family of stochastic
domains (``choice``, ``uniform``, ``loguniform``, ``randint``, ``qrandint``,
``normal``, ``sample_from``) for random/suggested sampling.

A *space* is a (possibly nested) dict mapping hyperparameter names to either
constants, ``Domain`` instances, or ``grid_search([...])`` markers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Sequence

import numpy as np

__all__ = [
    "Domain",
    "Categorical",
    "Uniform",
    "LogUniform",
    "RandInt",
    "QRandInt",
    "Normal",
    "Function",
    "GridSearch",
    "grid_search",
    "choice",
    "uniform",
    "loguniform",
    "randint",
    "qrandint",
    "normal",
    "sample_from",
    "sample_space",
    "space_signature",
]


class Domain:
    """Base class for stochastic hyperparameter domains."""

    def sample(self, rng: np.random.Generator) -> Any:
        raise NotImplementedError

    # -- Introspection used by searchers (TPE) -------------------------------
    def is_continuous(self) -> bool:
        return False


@dataclass(frozen=True)
class Categorical(Domain):
    values: tuple

    def sample(self, rng: np.random.Generator) -> Any:
        return self.values[int(rng.integers(0, len(self.values)))]


@dataclass(frozen=True)
class Uniform(Domain):
    low: float
    high: float

    def __post_init__(self):
        if not self.low < self.high:
            raise ValueError(f"uniform requires low < high, got [{self.low}, {self.high})")

    def sample(self, rng: np.random.Generator) -> float:
        return float(rng.uniform(self.low, self.high))

    def is_continuous(self) -> bool:
        return True


@dataclass(frozen=True)
class LogUniform(Domain):
    low: float
    high: float

    def __post_init__(self):
        if self.low <= 0:
            raise ValueError("loguniform requires low > 0")
        if not self.low < self.high:
            raise ValueError(f"loguniform requires low < high, got [{self.low}, {self.high})")

    def sample(self, rng: np.random.Generator) -> float:
        return float(math.exp(rng.uniform(math.log(self.low), math.log(self.high))))

    def is_continuous(self) -> bool:
        return True


@dataclass(frozen=True)
class RandInt(Domain):
    low: int
    high: int  # exclusive

    def __post_init__(self):
        if not self.low < self.high:
            raise ValueError(f"randint requires low < high, got [{self.low}, {self.high})")

    def sample(self, rng: np.random.Generator) -> int:
        return int(rng.integers(self.low, self.high))


@dataclass(frozen=True)
class QRandInt(Domain):
    low: int
    high: int
    q: int = 1

    def sample(self, rng: np.random.Generator) -> int:
        v = int(rng.integers(self.low, self.high))
        return int(round(v / self.q) * self.q)


@dataclass(frozen=True)
class Normal(Domain):
    mean: float
    std: float

    def sample(self, rng: np.random.Generator) -> float:
        return float(rng.normal(self.mean, self.std))

    def is_continuous(self) -> bool:
        return True


@dataclass(frozen=True)
class Function(Domain):
    """``sample_from`` — arbitrary user callable (optionally config-dependent)."""

    fn: Callable

    def sample(self, rng: np.random.Generator, config: Dict[str, Any] | None = None) -> Any:
        try:
            return self.fn(config or {})
        except TypeError:
            return self.fn()


@dataclass(frozen=True)
class GridSearch:
    """Exhaustive axis marker; the cross product of all grid axes is taken."""

    values: tuple


# -- public constructors ------------------------------------------------------

def grid_search(values: Sequence[Any]) -> GridSearch:
    return GridSearch(tuple(values))


def choice(values: Sequence[Any]) -> Categorical:
    return Categorical(tuple(values))


def uniform(low: float, high: float) -> Uniform:
    return Uniform(low, high)


def loguniform(low: float, high: float) -> LogUniform:
    return LogUniform(low, high)


def randint(low: int, high: int) -> RandInt:
    return RandInt(low, high)


def qrandint(low: int, high: int, q: int = 1) -> QRandInt:
    return QRandInt(low, high, q)


def normal(mean: float, std: float) -> Normal:
    return Normal(mean, std)


def sample_from(fn: Callable) -> Function:
    return Function(fn)


# -- sampling -----------------------------------------------------------------

def sample_space(space: Dict[str, Any], rng: np.random.Generator) -> Dict[str, Any]:
    """Resolve one concrete config from ``space``.

    ``grid_search`` markers are NOT resolved here (use variants.generate_variants
    for the grid cross-product); passing one raises.
    ``sample_from`` functions are resolved last so they may read sampled values.
    """
    out: Dict[str, Any] = {}
    deferred: List[tuple] = []
    for key, spec in space.items():
        if isinstance(spec, GridSearch):
            raise ValueError(
                f"grid_search axis {key!r} must be resolved via generate_variants()"
            )
        if isinstance(spec, Function):
            deferred.append((key, spec))
        elif isinstance(spec, Domain):
            out[key] = spec.sample(rng)
        elif isinstance(spec, dict):
            out[key] = sample_space(spec, rng)
        else:
            out[key] = spec
    for key, spec in deferred:
        out[key] = spec.sample(rng, out)
    return out


def space_signature(space: Dict[str, Any]) -> List[str]:
    """Flat, sorted list of parameter paths — used by searchers to key models."""
    sig: List[str] = []

    def walk(prefix: str, node: Dict[str, Any]):
        for k, v in node.items():
            path = f"{prefix}/{k}" if prefix else k
            if isinstance(v, dict):
                walk(path, v)
            else:
                sig.append(path)

    walk("", space)
    return sorted(sig)
