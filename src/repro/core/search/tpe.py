"""Tree-structured Parzen Estimator — HyperOpt's algorithm (Bergstra et al. 2013).

The paper integrates HyperOpt as a suggestion source (Table 1: 137 LoC).  We
implement TPE from scratch (numpy only — no scipy/hyperopt available offline):

  - observations are split at quantile gamma into "good" (l) and "bad" (g);
  - continuous dims: Parzen KDE (Gaussian mixture centred on observations,
    bandwidth per Scott's rule, truncated to the domain);
  - categorical/int dims: smoothed categorical counts;
  - EI is maximized by sampling n_ei_candidates from l(x) and picking
    argmax l(x)/g(x).

Supports Uniform, LogUniform, RandInt, Categorical domains; other domain types
fall back to prior sampling.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .basic import Searcher
from .space import Categorical, Domain, LogUniform, RandInt, Uniform, sample_space

__all__ = ["TPESearcher"]


def _norm_pdf(x: np.ndarray, mu: np.ndarray, sigma: np.ndarray) -> np.ndarray:
    z = (x[:, None] - mu[None, :]) / sigma[None, :]
    return np.exp(-0.5 * z * z) / (sigma[None, :] * math.sqrt(2 * math.pi))


class _ParzenEstimator:
    """1-D Parzen estimator over a (possibly log-) bounded continuous domain."""

    def __init__(self, obs: np.ndarray, low: float, high: float, log: bool):
        self.log = log
        self.low, self.high = (math.log(low), math.log(high)) if log else (low, high)
        pts = np.log(obs) if log else np.asarray(obs, dtype=float)
        # prior component: uniform-ish wide Gaussian at the domain centre
        centre = 0.5 * (self.low + self.high)
        width = self.high - self.low
        self.mu = np.concatenate([[centre], pts])
        n = len(self.mu)
        # HyperOpt-style adaptive bandwidths: each point's sigma is its max
        # gap to the neighbouring points (sorted), clipped to sane bounds —
        # dense clusters get narrow kernels so the estimator concentrates.
        order = np.argsort(self.mu)
        sorted_mu = self.mu[order]
        gaps = np.empty(n)
        if n > 1:
            left = np.diff(sorted_mu, prepend=sorted_mu[0] - width)
            right = np.diff(sorted_mu, append=sorted_mu[-1] + width)
            gaps[order] = np.maximum(left, right)
        else:
            gaps[:] = width
        lo_bw = width / max(100.0, 10.0 * n)
        self.sigma = np.clip(gaps, lo_bw, width)
        self.sigma[0] = width  # broad prior

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        idx = rng.integers(0, len(self.mu), size=n)
        raw = rng.normal(self.mu[idx], self.sigma[idx])
        raw = np.clip(raw, self.low, self.high)
        return np.exp(raw) if self.log else raw

    def log_pdf(self, x: np.ndarray) -> np.ndarray:
        pts = np.log(x) if self.log else np.asarray(x, dtype=float)
        dens = _norm_pdf(pts, self.mu, self.sigma).mean(axis=1)
        return np.log(np.maximum(dens, 1e-300))


class _CategoricalEstimator:
    def __init__(self, obs_idx: List[int], n_choices: int, prior_weight: float = 1.0):
        counts = np.full(n_choices, prior_weight)
        for i in obs_idx:
            counts[i] += 1.0
        self.probs = counts / counts.sum()

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        return rng.choice(len(self.probs), size=n, p=self.probs)

    def log_pdf(self, idx: np.ndarray) -> np.ndarray:
        return np.log(self.probs[idx.astype(int)])


class TPESearcher(Searcher):
    def __init__(
        self,
        space: Dict[str, Any],
        metric: str = "loss",
        mode: str = "min",
        n_startup_trials: int = 10,
        gamma: float = 0.25,
        n_ei_candidates: int = 24,
        max_trials: int = 0,
        seed: int = 0,
    ):
        super().__init__(space, metric, mode)
        self.n_startup = n_startup_trials
        self.gamma = gamma
        self.n_ei = n_ei_candidates
        self.max_trials = max_trials
        self._rng = np.random.default_rng(seed)
        self._history: List[Tuple[Dict[str, Any], float]] = []  # (config, score↑)
        self._pending: Dict[str, Dict[str, Any]] = {}
        self._count = 0

    # -- observation ---------------------------------------------------------------
    def observe(self, trial_id, config, value, final) -> None:
        if final:
            self._history.append((config, self._score(value)))
            self._pending.pop(trial_id, None)

    # -- suggestion ----------------------------------------------------------------
    def suggest(self, trial_id: str) -> Optional[Dict[str, Any]]:
        if self.max_trials and self._count >= self.max_trials:
            return None
        self._count += 1
        if len(self._history) < self.n_startup:
            cfg = sample_space(self.space, self._rng)
            self._record_suggest(trial_id, strategy="random_startup",
                                 n_obs=len(self._history),
                                 n_startup=self.n_startup)
        else:
            cfg = self._suggest_tpe()
            n_good = max(1, int(np.ceil(self.gamma * len(self._history))))
            self._record_suggest(trial_id, strategy="tpe",
                                 n_obs=len(self._history), n_good=n_good,
                                 n_bad=len(self._history) - n_good,
                                 gamma=self.gamma)
        self._pending[trial_id] = cfg
        return cfg

    def state_dict(self) -> Dict[str, Any]:
        return {"rng": self._rng.bit_generator.state,
                "history": [[dict(c), float(s)] for c, s in self._history],
                "pending": {tid: dict(c) for tid, c in self._pending.items()},
                "count": self._count}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self._rng = np.random.default_rng()
        self._rng.bit_generator.state = state["rng"]
        self._history = [(dict(c), float(s)) for c, s in state["history"]]
        self._pending = {str(tid): dict(c)
                         for tid, c in state["pending"].items()}
        self._count = int(state["count"])

    def _split(self) -> Tuple[List[Dict], List[Dict]]:
        ranked = sorted(self._history, key=lambda cv: cv[1], reverse=True)
        n_good = max(1, int(np.ceil(self.gamma * len(ranked))))
        good = [c for c, _ in ranked[:n_good]]
        bad = [c for c, _ in ranked[n_good:]] or [c for c, _ in ranked[n_good - 1:]]
        return good, bad

    def _suggest_tpe(self) -> Dict[str, Any]:
        good, bad = self._split()
        out: Dict[str, Any] = {}
        for key, spec in self.space.items():
            if isinstance(spec, dict):
                raise ValueError("TPESearcher supports flat spaces; nest-free keys only")
            if not isinstance(spec, Domain):
                out[key] = spec
                continue
            g_obs = [c[key] for c in good if key in c]
            b_obs = [c[key] for c in bad if key in c]
            out[key] = self._suggest_dim(spec, g_obs, b_obs)
        return out

    def _suggest_dim(self, spec: Domain, g_obs: List, b_obs: List):
        rng = self._rng
        if isinstance(spec, (Uniform, LogUniform)) and g_obs and b_obs:
            log = isinstance(spec, LogUniform)
            l_est = _ParzenEstimator(np.asarray(g_obs, float), spec.low, spec.high, log)
            g_est = _ParzenEstimator(np.asarray(b_obs, float), spec.low, spec.high, log)
            cands = l_est.sample(rng, self.n_ei)
            score = l_est.log_pdf(cands) - g_est.log_pdf(cands)
            return float(cands[int(np.argmax(score))])
        if isinstance(spec, RandInt) and g_obs and b_obs:
            lo, hi = spec.low, spec.high
            l_est = _ParzenEstimator(np.asarray(g_obs, float) + 0.5, lo, hi, False)
            g_est = _ParzenEstimator(np.asarray(b_obs, float) + 0.5, lo, hi, False)
            cands = l_est.sample(rng, self.n_ei)
            score = l_est.log_pdf(cands) - g_est.log_pdf(cands)
            return int(np.clip(round(cands[int(np.argmax(score))] - 0.5), lo, hi - 1))
        if isinstance(spec, Categorical) and g_obs:
            values = list(spec.values)
            gi = [values.index(v) for v in g_obs if v in values]
            bi = [values.index(v) for v in b_obs if v in values]
            l_est = _CategoricalEstimator(gi, len(values))
            g_est = _CategoricalEstimator(bi, len(values))
            cands = l_est.sample(rng, self.n_ei)
            score = l_est.log_pdf(cands) - g_est.log_pdf(cands)
            return values[int(cands[int(np.argmax(score))])]
        return spec.sample(rng)
