"""Grid-search resolution: expand ``grid_search`` axes into concrete variants.

Mirrors the paper's §4.3 example: a space with two 3- and 2-valued grid axes
produces the 3x2 cross product as the initial set of trials; all stochastic
domains within each variant are sampled ``num_samples`` times.
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, Iterator, List, Tuple

import numpy as np

from .space import Domain, Function, GridSearch, sample_space

__all__ = ["generate_variants", "count_grid_variants", "format_variant_tag"]


def _find_grid_axes(space: Dict[str, Any], prefix: Tuple[str, ...] = ()) -> List[Tuple[Tuple[str, ...], GridSearch]]:
    axes = []
    for k, v in space.items():
        path = prefix + (k,)
        if isinstance(v, GridSearch):
            axes.append((path, v))
        elif isinstance(v, dict):
            axes.extend(_find_grid_axes(v, path))
    return axes


def _set_path(d: Dict[str, Any], path: Tuple[str, ...], value: Any) -> None:
    node = d
    for k in path[:-1]:
        node = node[k]
    node[path[-1]] = value


def _copy_space(space: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in space.items():
        out[k] = _copy_space(v) if isinstance(v, dict) else v
    return out


def count_grid_variants(space: Dict[str, Any]) -> int:
    n = 1
    for _, axis in _find_grid_axes(space):
        n *= len(axis.values)
    return n


def generate_variants(
    space: Dict[str, Any],
    num_samples: int = 1,
    seed: int | None = None,
) -> Iterator[Dict[str, Any]]:
    """Yield ``num_samples x prod(grid axes)`` concrete configs.

    Grid axes are expanded exhaustively; stochastic domains are re-sampled per
    variant so that ``num_samples > 1`` gives distinct random draws.
    """
    rng = np.random.default_rng(seed)
    axes = _find_grid_axes(space)
    axis_paths = [p for p, _ in axes]
    axis_values = [a.values for _, a in axes]
    for _ in range(num_samples):
        for combo in itertools.product(*axis_values) if axes else [()]:
            variant = _copy_space(space)
            for path, value in zip(axis_paths, combo):
                _set_path(variant, path, value)
            yield sample_space(variant, rng)


def format_variant_tag(config: Dict[str, Any], max_items: int = 4) -> str:
    """Short human-readable tag for a trial, e.g. ``lr=0.01,momentum=0.9``."""
    items = []
    for k, v in config.items():
        if isinstance(v, dict):
            continue
        if isinstance(v, float):
            items.append(f"{k}={v:.4g}")
        else:
            items.append(f"{k}={v}")
        if len(items) >= max_items:
            break
    return ",".join(items)
