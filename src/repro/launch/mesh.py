"""Production mesh builders.

Functions (not module constants) so importing never touches jax device state.
Target: TPU v5e, 256 chips/pod; single-pod (16, 16) = (data, model), multi-pod
(2, 16, 16) = (pod, data, model).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "HW"]


class HW:
    """TPU v5e hardware constants used by the roofline analysis."""
    PEAK_FLOPS_BF16 = 197e12       # per chip
    HBM_BW = 819e9                 # bytes/s per chip
    ICI_BW = 50e9                  # bytes/s per link
    HBM_BYTES = 16 * 2**30         # 16 GiB per chip
    CHIPS_PER_POD = 256


def _make(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    # Auto axis types are the default on old jax and an explicit kwarg on new;
    # pass them only where supported so both jax 0.4.x and 0.5+ work.
    try:
        axis_type = jax.sharding.AxisType.Auto  # jax >= 0.5
    except AttributeError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh with Auto axis types (e.g. trial sub-meshes)."""
    return _make(shape, axes)
