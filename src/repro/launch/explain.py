"""Explain scheduler/searcher verdicts from the decision journal (DESIGN.md §10).

    PYTHONPATH=src python -m repro.launch.explain runs/demo --trial my_trial_00003
    PYTHONPATH=src python -m repro.launch.explain --journal runs/demo/events.jsonl
    PYTHONPATH=src python -m repro.launch.explain --bundle flightrec/run-x-00-sigterm.json

Answers "why did trial X stop / pause / get perturbed?" from DECISION records
alone — either from the JSONL journal (schema v3) or from a flight-recorder
forensic bundle dumped at crash time.  Output is deterministic (virtual
timestamps, %.6g floats, sorted trials), so two identical-token VirtualClock
runs explain byte-identically — the same comparability contract as traces,
summaries, and bundles.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple

from ..obs.analysis import ExperimentAnalysis, format_decision


def _fmt_t(t: Any) -> str:
    if isinstance(t, float):
        return f"{t:.6g}"
    return str(t)


def _lines_for_trial(trial_id: str, status: Optional[str],
                     iterations: Optional[Any],
                     decisions: List[Dict[str, Any]]) -> List[str]:
    head = f"trial {trial_id}"
    meta = []
    if status is not None:
        meta.append(str(status))
    if iterations is not None:
        meta.append(f"{iterations} iterations")
    if meta:
        head += ": " + ", ".join(meta)
    out = [head]
    if not decisions:
        out.append("  no decision records (pre-v3 journal, or decisions=False)")
        return out
    for d in decisions:
        out.append(f"  [t={_fmt_t(d.get('t'))}] "
                   f"{format_decision(d.get('info') or {})}")
    fate = next((d for d in reversed(decisions)
                 if (d.get("info") or {}).get("verdict") != "SUGGEST"), None)
    if fate is not None:
        out.append(f"  fate: {format_decision(fate.get('info') or {})}")
    return out


def _from_journal(path: str, trial_id: Optional[str]) -> List[str]:
    an = ExperimentAnalysis.from_journal(path)
    if trial_id is not None:
        r = an.get(trial_id)
        if r is None:
            return [f"trial {trial_id}: not in journal"]
        return _lines_for_trial(trial_id, r.status, r.iterations,
                                r.decisions())
    out: List[str] = []
    for tid in an.trial_ids():
        r = an.get(tid)
        decs = r.decisions()
        if decs:
            out += _lines_for_trial(tid, r.status, r.iterations, decs)
    return out or ["no decision records in journal"]


def _from_bundle(path: str, trial_id: Optional[str]) -> List[str]:
    with open(path) as f:
        bundle = json.load(f)
    by_trial: Dict[str, List[Dict[str, Any]]] = {}
    for row in bundle.get("decisions") or []:
        tid = row.get("trial_id")
        if isinstance(tid, str):
            by_trial.setdefault(tid, []).append(row)
    table = {r.get("trial_id"): r for r in bundle.get("trials") or []}
    out = [f"bundle {bundle.get('run_id')}: reason={bundle.get('reason')} "
           f"t={_fmt_t(bundle.get('t_virtual'))}"]
    tids = [trial_id] if trial_id is not None else sorted(by_trial)
    for tid in tids:
        decs = by_trial.get(tid)
        tr = table.get(tid) or {}
        if decs is None and trial_id is not None:
            out.append(f"trial {tid}: no decision records in bundle "
                       f"(ring holds the last "
                       f"{len(bundle.get('decisions') or [])})")
            continue
        out += _lines_for_trial(tid, tr.get("status"), tr.get("iteration"),
                                decs or [])
    if trial_id is None and not by_trial:
        out.append("no decision records in bundle")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("log_dir", nargs="?", default=None,
                    help="run directory: uses events.jsonl found inside")
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="JSONL event journal (overrides log_dir discovery)")
    ap.add_argument("--bundle", default=None, metavar="PATH",
                    help="flight-recorder forensic bundle JSON (answers from "
                         "the crash dump instead of the journal)")
    ap.add_argument("--trial", default=None, metavar="ID",
                    help="explain one trial (default: all trials that have "
                         "decision records)")
    args = ap.parse_args(argv)

    journal = args.journal
    if args.log_dir and journal is None and args.bundle is None:
        p = os.path.join(args.log_dir, "events.jsonl")
        journal = p if os.path.exists(p) else None
    if args.bundle is not None:
        lines = _from_bundle(args.bundle, args.trial)
    elif journal is not None:
        lines = _from_journal(journal, args.trial)
    else:
        ap.error("no source: pass --journal PATH, --bundle PATH, or a "
                 "log_dir containing events.jsonl")
        return 2  # unreachable; ap.error raises SystemExit
    print("\n".join(lines))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
