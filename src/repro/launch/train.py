"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq-len 128 --reduced

``--reduced`` trains the smoke-scale variant (CPU-feasible); without it the
full config is used (TPU-scale — on this container use the dry-run instead).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs
from ..data.pipeline import DataConfig, SyntheticLMDataset, synthetic_batch
from ..models import param_count
from ..train import adamw, linear_warmup_cosine, make_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_archs() + ["all"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced smoke-scale variant")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--out", default=None, help="JSONL metrics path")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt = adamw(linear_warmup_cosine(args.lr, args.warmup, args.steps))
    state = make_train_state(jax.random.key(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    print(f"[train] {cfg.arch_id} ({'reduced' if args.reduced else 'full'}): "
          f"{param_count(state.params):,} params")

    if cfg.frontend is None:
        data = SyntheticLMDataset(DataConfig(
            global_batch=args.batch, seq_len=args.seq_len,
            vocab_size=cfg.vocab_size))
        batch_at = lambda i: data.batch_at(i)
    else:
        batch_at = lambda i: synthetic_batch(cfg, args.batch, args.seq_len, seed=i)

    out_f = open(args.out, "w") if args.out else None
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in batch_at(i).items()}
        state, metrics = step(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            row = {"step": i, "loss": float(metrics["loss"]),
                   "accuracy": float(metrics["accuracy"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "elapsed_s": round(time.time() - t0, 2)}
            print(f"[train] {json.dumps(row)}")
            if out_f:
                out_f.write(json.dumps(row) + "\n")
    if out_f:
        out_f.close()
    final = float(metrics["loss"])
    print(f"[train] done: final loss {final:.4f} in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
