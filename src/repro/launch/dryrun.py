"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) combo.

Proves the distribution config is coherent without hardware: for each combo we
``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` on the production mesh
(single-pod 16x16 and multi-pod 2x16x16), print ``memory_analysis()`` /
``cost_analysis()``, and derive roofline terms (launch/roofline.py).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out benchmarks/results
"""
# The host platform must present 512 placeholder devices BEFORE jax initializes;
# these two lines must precede every other import (including repro.*).
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

import argparse
import dataclasses
import json
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs
from ..dist.sharding import (batch_specs, cache_specs, make_shardings,
                             param_specs, train_state_specs)
from ..models import ModelConfig, decode_step, forward_encode, init_params, prefill
from ..train import adamw, linear_warmup_cosine, make_train_state, make_train_step
from .mesh import HW, make_production_mesh
from .roofline import analyze
from .shapes import SHAPES, ShapeSpec, dryrun_config, input_specs, skip_reason


def _tree_bytes(tree: Any) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def active_param_count(cfg: ModelConfig) -> int:
    """Total params, counting only top_k/n_experts of routed expert weights."""
    shapes = jax.eval_shape(partial(init_params, jax.random.key(0), cfg))
    total = sum(int(x.size) for x in jax.tree_util.tree_leaves(shapes))
    if cfg.moe is None:
        return total
    expert = 0
    def count_experts(path, leaf):
        nonlocal expert
        keys = [getattr(k, "key", None) for k in path]
        if "experts" in keys:
            expert += int(leaf.size)
        return leaf
    jax.tree_util.tree_map_with_path(count_experts, shapes)
    frac = cfg.moe.top_k / cfg.moe.n_experts
    return int(total - expert * (1.0 - frac))


def lower_one(
    arch: str, shape: ShapeSpec, mesh, mesh_name: str,
    verbose: bool = True, compile_: bool = True,
    strategy: str = "fsdp_tp", seq_parallel: bool = False,
    cfg_overrides: Optional[Dict[str, Any]] = None,
    variant: str = "",
) -> Optional[Dict[str, Any]]:
    """Lower+compile one combo.  ``strategy``/``seq_parallel``/``cfg_overrides``
    parameterize §Perf variants; ``variant`` labels the record."""
    cfg = dryrun_config(get_config(arch))
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    reason = skip_reason(cfg, shape)
    if reason is not None:
        if verbose:
            print(f"[dryrun] SKIP {arch} x {shape.name}: {reason}")
        return {"arch": arch, "shape": shape.name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}

    chips = mesh.devices.size
    specs = input_specs(cfg, shape)
    t0 = time.time()
    from ..dist.sharding import activation_policy, sharding_strategy
    strat_ctx = sharding_strategy(strategy)
    strat_ctx.__enter__()
    policy_ctx = activation_policy(mesh, seq_parallel=seq_parallel)
    policy_ctx.__enter__()

    if shape.kind == "train":
        opt = adamw(linear_warmup_cosine(3e-4, 100, 10_000),
                    moment_dtype=cfg.opt_moment_dtype)
        state_shapes = jax.eval_shape(
            partial(make_train_state, jax.random.key(0), cfg, opt))
        state_sh = make_shardings(train_state_specs(state_shapes, mesh, cfg), mesh)
        batch_sh = make_shardings(batch_specs(specs["batch"], mesh), mesh)
        step = make_train_step(cfg, opt, microbatch=cfg.train_microbatch)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None), donate_argnums=(0,))
        lowered = jitted.lower(state_shapes, specs["batch"])
        n_tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        params_shapes = jax.eval_shape(partial(init_params, jax.random.key(0), cfg))
        param_sh = make_shardings(param_specs(params_shapes, mesh, cfg), mesh)
        batch_sh = make_shardings(batch_specs(specs["batch"], mesh), mesh)
        if cfg.encoder_only:
            fn = lambda p, b: forward_encode(p, b, cfg)
        else:
            fn = lambda p, b: prefill(p, b, cfg, shape.seq_len)
        jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh))
        lowered = jitted.lower(params_shapes, specs["batch"])
        n_tokens = shape.global_batch * shape.seq_len
    else:  # decode
        params_shapes = jax.eval_shape(partial(init_params, jax.random.key(0), cfg))
        param_sh = make_shardings(param_specs(params_shapes, mesh, cfg), mesh)
        cache_sh = make_shardings(
            cache_specs(specs["caches"], mesh, shape.global_batch), mesh)
        tok_sh = make_shardings(batch_specs(specs["tokens"], mesh), mesh)
        fn = lambda p, c, t, pos: decode_step(p, c, t, pos, cfg)
        jitted = jax.jit(fn, in_shardings=(param_sh, cache_sh, tok_sh, None),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_shapes, specs["caches"],
                               specs["tokens"], specs["pos"])
        n_tokens = shape.global_batch  # one new token per sequence

    policy_ctx.__exit__(None, None, None)
    strat_ctx.__exit__(None, None, None)
    t_lower = time.time() - t0
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape.name, "mesh": mesh_name, "chips": int(chips),
        "status": "lowered", "t_lower_s": round(t_lower, 2),
        "variant": variant or "baseline",
    }
    if not compile_:
        if verbose:
            print(f"[dryrun] {arch} x {shape.name} x {mesh_name}: lowered "
                  f"in {t_lower:.1f}s (compile skipped)")
        return record

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    report = analyze(
        arch, shape.name, mesh_name, int(chips), compiled,
        n_params_active=active_param_count(cfg), n_tokens=n_tokens,
        kind=shape.kind)
    record.update(status="compiled", t_compile_s=round(t_compile, 2),
                  **report.to_dict())

    if verbose:
        ma = compiled.memory_analysis()
        print(f"[dryrun] {arch} x {shape.name} x {mesh_name} "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        print(f"  memory_analysis: {ma}")
        from .roofline import normalize_cost_analysis
        ca = normalize_cost_analysis(compiled.cost_analysis())
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"  roofline: compute={report.compute_s*1e3:.2f}ms "
              f"memory={report.memory_s*1e3:.2f}ms "
              f"collective={report.collective_s*1e3:.2f}ms "
              f"-> {report.dominant}-bound; "
              f"useful-flops={report.useful_flops_ratio:.2f} "
              f"hbm/dev={report.hbm_per_device_gib:.2f}GiB")
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=["all"] + list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="all archs x shapes")
    ap.add_argument("--out", default=None, help="directory for JSON records")
    ap.add_argument("--no-compile", action="store_true", help="lower only")
    args = ap.parse_args()

    archs = list_archs() if (args.arch == "all" or args.all) else [args.arch]
    shapes = list(SHAPES.values()) if (args.shape == "all" or args.all) \
        else [SHAPES[args.shape]]
    mesh_names = {"single": ["pod16x16"], "multi": ["pods2x16x16"],
                  "both": ["pod16x16", "pods2x16x16"]}[args.mesh]

    records = []
    for mesh_name in mesh_names:
        mesh = make_production_mesh(multi_pod=(mesh_name == "pods2x16x16"))
        for arch in archs:
            for shape in shapes:
                try:
                    rec = lower_one(arch, shape, mesh, mesh_name,
                                    compile_=not args.no_compile)
                except Exception as e:  # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                if rec is not None:
                    records.append(rec)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    path = os.path.join(args.out, f"dryrun_{args.mesh}.json")
                    with open(path, "w") as f:
                        json.dump(records, f, indent=1, default=str)

    n_ok = sum(1 for r in records if r["status"] == "compiled")
    n_skip = sum(1 for r in records if r["status"] == "skipped")
    n_err = sum(1 for r in records if r["status"] == "error")
    print(f"\n[dryrun] {n_ok} compiled, {n_skip} skipped (documented), {n_err} errors")
    if n_err:
        for r in records:
            if r["status"] == "error":
                print(f"  ERROR {r['arch']} x {r['shape']} x {r['mesh']}: {r['error']}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
