"""Distributed hyperparameter search launcher — the paper's workload.

    PYTHONPATH=src python -m repro.launch.tune --arch smollm-135m --reduced \
        --scheduler asha --num-samples 16 --max-iters 20 --executor concurrent \
        --elastic greedy

Runs a Tune experiment over a model's optimizer hyperparameters with any of
the six built-in schedulers, optionally driven by a searcher (TPE/random),
with trials placed on mesh slices via the SlicePool.  ``--executor`` picks the
execution tier: ``serial`` (host time-slicing), ``concurrent`` (one worker
thread per trial, overlapped JAX dispatch across disjoint slices, heartbeat
straggler detection), ``process`` (one spawned worker *process* per trial —
GIL-free host stepping, checkpoint bytes over the ObjectStore spill surface,
and kill-on-straggle reclamation after ``--straggler-deadline`` seconds),
``cluster`` (worker processes scheduled across a roster of hosts over the
length-prefixed socket transport — per-host SlicePools, host heartbeats,
content-addressed checkpoint fetch, host eviction; DESIGN.md §11), or
``vmap`` (homogeneous sweeps as one SPMD program).  ``--max-failures``
restarts a crashed trial from its last checkpoint.

Cluster quickstart (3 simulated hosts on loopback sockets)::

    PYTHONPATH=src python -m repro.launch.tune --arch smollm-135m --reduced \
        --scheduler asha --num-samples 8 --executor cluster --hosts 3x8 \
        --devices-per-trial 4 --max-failures 2

``--hosts`` shapes the roster (``3x8`` = three hosts of eight devices;
``a:8,b:16`` names heterogeneous ones) and ``--placement roofline``
right-sizes each trial's slice per host from its roofline profile, falling
back to ``--devices-per-trial``.  A host that stops heartbeating is evicted;
its trials restart from their last fetched checkpoint under the same
``--max-failures`` budget.

``--elastic greedy`` turns on the elastic control plane (DESIGN.md §6):
slices of early-stopped trials are absorbed by survivors at their next
checkpoint boundary (``fair`` rebalances instead); ``--lookahead K`` lets
workers run K results ahead of the scheduler on throughput-bound FIFO
sweeps (auto-clamped to 1 for schedulers that stop/perturb trials).

Observability (DESIGN.md §8-§9) quickstart::

    PYTHONPATH=src python -m repro.launch.tune --arch smollm-135m --reduced \
        --scheduler asha --num-samples 8 --executor concurrent \
        --trace trace.json --metrics-interval 5 --log-dir runs/demo \
        --live-table --report

``--trace PATH`` records a span for every lifecycle phase (schedule decision,
slice acquire, build, step, checkpoint save/restore, resize, restart) and
exports Chrome trace-event JSON at PATH — open it in Perfetto
(https://ui.perfetto.dev) or chrome://tracing.  ``--metrics-interval S``
snapshots the control-plane metrics registry (bus depth/fan-in latency,
scheduler decision latency, pool utilization, checkpoint bytes+latency,
restart/kill/resize counters) every S seconds to ``<log-dir>/metrics.jsonl``
and prints a status table at experiment end.

``--live-table`` renders the paper's live trial table (status / iteration /
metric / slice devices / restarts) as results stream in; ``--report`` writes
the self-contained HTML run report (metric curves, lifecycle gantt, fault
timeline, best-config table) to ``<log-dir>/report.html`` when the run ends —
even when it aborts.  Re-render any past run's artifacts offline with
``python -m repro.launch.report <log-dir>``.

Durable resume (DESIGN.md §12) quickstart — kill a sweep, continue it::

    PYTHONPATH=src python -m repro.launch.tune --arch smollm-135m --reduced \
        --scheduler asha --num-samples 16 --executor concurrent \
        --log-dir runs/sweep
    # ... ^C / OOM-kill / kill -9 the controller mid-sweep, then:
    PYTHONPATH=src python -m repro.launch.tune --arch smollm-135m --reduced \
        --scheduler asha --num-samples 16 --executor concurrent \
        --log-dir runs/sweep --resume

``--resume`` rebuilds the experiment from the run's durable artifacts:
trial statuses, iteration counts and metric histories replay from
``<log-dir>/events.jsonl`` (torn tail from the kill repaired), scheduler and
searcher state load from the watermarked ``<log-dir>/search_state.json``
snapshot, and weights restore from the per-trial checkpoint mirrors under
``<log-dir>/ckpt``.  Finished trials keep their results; interrupted trials
continue from their last valid checkpoint; trials with none restart from
scratch.  Pass the SAME sweep arguments as the original run — the space is
only used to regenerate trial identities, and a conflicting --num-samples
is rejected.  The journal is appended, never truncated.
"""
from __future__ import annotations

import argparse
import json

from ..configs import get_config, list_archs
from ..core import (ASHAScheduler, FIFOScheduler, GPSearcher,
                    HyperBandScheduler, MedianStoppingRule,
                    PopulationBasedTraining, Resources, TPESearcher,
                    RandomSearcher, loguniform, run_experiments, uniform)
from ..dist.submesh import SlicePool
from ..train.trainable import make_model_trainable, model_trainable_factory


def build_vmap_executor(cfg, args):
    """Model selection as one SPMD program: N lanes of the same tiny LM,
    vmapped over (lr, weight_decay) with momentum SGD (see bench_vmap.py)."""
    import jax
    import jax.numpy as jnp

    from ..core import CheckpointManager, ObjectStore
    from ..core.vmap_executor import VectorTrainableSpec, VmapExecutor
    from ..data import DataConfig, SyntheticLMDataset
    from ..models import forward_train, init_params

    data = SyntheticLMDataset(DataConfig(global_batch=args.batch,
                                         seq_len=args.seq_len,
                                         vocab_size=cfg.vocab_size))
    n_banked = 8
    batches = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[jax.tree_util.tree_map(jnp.asarray, data.batch_at(i))
          for i in range(n_banked)])

    def init_fn(seed, hypers):
        params = init_params(jax.random.key(seed), cfg)
        mom = jax.tree_util.tree_map(jnp.zeros_like, params)
        return {"p": params, "m": mom, "i": jnp.zeros((), jnp.int32)}

    def step_fn(state, hypers):
        batch = jax.tree_util.tree_map(lambda x: x[state["i"] % n_banked], batches)
        (_, metrics), grads = jax.value_and_grad(
            lambda p: forward_train(p, batch, cfg), has_aux=True)(state["p"])
        m = jax.tree_util.tree_map(lambda mo, g: 0.9 * mo + g, state["m"], grads)
        p = jax.tree_util.tree_map(
            lambda w, mo: w - hypers["lr"] * (mo + hypers["weight_decay"] * w),
            state["p"], m)
        return {"p": p, "m": m, "i": state["i"] + 1}, {"loss": metrics["loss"]}

    spec = VectorTrainableSpec(init_fn, step_fn, ("lr", "weight_decay"),
                               steps_per_iter=args.steps_per_iter)
    return VmapExecutor(spec, CheckpointManager(ObjectStore()),
                        n_lanes=min(args.num_samples, 8),
                        total_devices=args.total_devices)


def build_scheduler(name: str, max_iters: int):
    if name == "fifo":
        return FIFOScheduler(metric="loss", mode="min")
    if name == "asha":
        return ASHAScheduler(metric="loss", mode="min", max_t=max_iters,
                             grace_period=max(1, max_iters // 8),
                             reduction_factor=3)
    if name == "hyperband":
        return HyperBandScheduler(metric="loss", mode="min", max_t=max_iters)
    if name == "median":
        return MedianStoppingRule(metric="loss", mode="min", grace_period=2)
    if name == "pbt":
        return PopulationBasedTraining(
            metric="loss", mode="min",
            perturbation_interval=max(2, max_iters // 5),
            hyperparam_mutations={"lr": loguniform(1e-4, 1e-1)})
    raise ValueError(name)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    ap.add_argument("--scheduler", default="asha",
                    choices=["fifo", "asha", "hyperband", "median", "pbt"])
    ap.add_argument("--searcher", default=None, choices=[None, "tpe", "gp", "random"])
    ap.add_argument("--num-samples", type=int, default=8)
    ap.add_argument("--max-iters", type=int, default=10)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--steps-per-iter", type=int, default=3)
    ap.add_argument("--devices-per-trial", type=int, default=8)
    ap.add_argument("--total-devices", type=int, default=256)
    ap.add_argument("--executor", default="serial",
                    choices=["serial", "concurrent", "process", "cluster",
                             "vmap"])
    ap.add_argument("--hosts", default="2x8",
                    help="cluster executor roster: N (hosts x 8 devices), "
                         "'3x8', or 'name:devs,...' per host (see "
                         "repro.cluster.parse_hosts)")
    ap.add_argument("--placement", default="roofline",
                    choices=["roofline", "fixed"],
                    help="cluster executor: right-size slices from roofline "
                         "cost profiles, or place the requested width as-is")
    ap.add_argument("--max-failures", type=int, default=0,
                    help="restart a crashed trial from its last checkpoint up "
                         "to N times before marking it ERROR")
    ap.add_argument("--max-experiment-failures", type=int, default=0,
                    help="abort the experiment once more than N trials errored "
                         "(0 = never)")
    ap.add_argument("--heartbeat-timeout", type=float, default=60.0,
                    help="concurrent/process executors: seconds before a "
                         "stalled step emits HEARTBEAT_MISSED")
    ap.add_argument("--straggler-deadline", type=float, default=300.0,
                    help="process executor: hard per-step deadline after which "
                         "a straggling worker is SIGKILLed, its slice returned "
                         "to the pool, and the trial requeued from its last "
                         "checkpoint under --max-failures (0 disables)")
    ap.add_argument("--elastic", default="off",
                    choices=["off", "greedy", "fair"],
                    help="elastic slice resize at checkpoint boundaries: "
                         "'greedy' grows survivors into capacity freed by "
                         "early-stopped trials, 'fair' rebalances the pool "
                         "across running trials (needs a slice pool; no-op "
                         "with --executor vmap)")
    ap.add_argument("--lookahead", type=int, default=1,
                    help="max un-consumed results a worker may run ahead of "
                         "the scheduler (saves a control-plane round-trip per "
                         "step for process workers); automatically clamped to "
                         "1 unless the scheduler never stops/perturbs trials "
                         "(fifo)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export a Chrome trace-event JSON of every control-"
                         "plane span (schedule decision, slice acquire, "
                         "build, step, ckpt save/restore, resize, restart) "
                         "to PATH; view in Perfetto or chrome://tracing")
    ap.add_argument("--metrics-interval", type=float, default=0.0,
                    help="snapshot the control-plane metrics registry every "
                         "S seconds to <log-dir>/metrics.jsonl and print a "
                         "status table at experiment end (0 disables)")
    ap.add_argument("--live-table", action="store_true",
                    help="render the live trial status table (status / iter / "
                         "metric / devices / restarts) as results stream in")
    ap.add_argument("--report", action="store_true",
                    help="write the self-contained HTML run report to "
                         "<log-dir>/report.html at experiment end (requires "
                         "--log-dir; survives an aborting sweep)")
    ap.add_argument("--decisions", default="on",
                    choices=["on", "full", "off"],
                    help="journal scheduler/searcher verdicts as typed "
                         "DECISION records with their inputs (DESIGN.md §10); "
                         "'full' includes CONTINUE verdicts, 'off' disables "
                         "(query them post-hoc with repro.launch.explain)")
    ap.add_argument("--flightrec", default=None, metavar="DIR",
                    help="dump a crash-forensics bundle (last-N events + "
                         "decisions, scheduler/searcher state, trial table) "
                         "to DIR on SIGTERM/abort; defaults to "
                         "<log-dir>/flightrec when --log-dir is set")
    ap.add_argument("--resume", action="store_true",
                    help="continue an interrupted (even kill -9'd) sweep from "
                         "<log-dir>'s durable artifacts: journal replay + "
                         "search-state snapshot + checkpoint mirrors "
                         "(DESIGN.md §12); pass the same sweep arguments as "
                         "the original run")
    ap.add_argument("--log-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.report and not args.log_dir:
        ap.error("--report requires --log-dir (the JSONL journal feeds it)")
    if args.resume and not args.log_dir:
        ap.error("--resume requires --log-dir (the run's artifacts live there)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    workload = dict(batch=args.batch, seq_len=args.seq_len,
                    steps_per_iter=args.steps_per_iter,
                    total_steps=args.max_iters * args.steps_per_iter)
    if args.executor in ("process", "cluster"):
        # Spawn-safe recipe: worker processes rebuild the bound trainable by
        # re-importing make_model_trainable in the child.
        trainable = model_trainable_factory(cfg, **workload)
    else:
        trainable = make_model_trainable(cfg, **workload)

    space = {"lr": loguniform(1e-4, 1e-1), "warmup": 5,
             "weight_decay": uniform(0.0, 0.2)}
    searcher = None
    if args.searcher == "tpe":
        searcher = TPESearcher(space, metric="loss", mode="min",
                               max_trials=args.num_samples, seed=args.seed)
    elif args.searcher == "gp":
        searcher = GPSearcher(space, metric="loss", mode="min",
                              max_trials=args.num_samples, seed=args.seed)
    elif args.searcher == "random":
        searcher = RandomSearcher(space, metric="loss", mode="min",
                                  max_trials=args.num_samples, seed=args.seed)

    if args.executor == "vmap":
        executor = build_vmap_executor(cfg, args)
        pool = None  # lanes replace slices; placement is the stacked program's
    elif args.executor == "cluster":
        executor = args.executor
        pool = None  # per-host pools: the roster is the capacity
    else:
        executor = args.executor
        pool = SlicePool(n_virtual=args.total_devices)
    analysis = run_experiments(
        trainable,
        None if searcher else space,
        scheduler=build_scheduler(args.scheduler, args.max_iters),
        searcher=searcher,
        num_samples=args.num_samples if not searcher else 1,
        stop={"training_iteration": args.max_iters},
        resources_per_trial=Resources(cpu=1, devices=args.devices_per_trial),
        total_devices=args.total_devices,
        slice_pool=pool,
        executor=executor,
        hosts=args.hosts if args.executor == "cluster" else None,
        placement=args.placement,
        max_failures=args.max_failures,
        max_experiment_failures=args.max_experiment_failures,
        heartbeat_timeout=args.heartbeat_timeout,
        straggler_deadline=args.straggler_deadline,
        elastic=args.elastic,
        lookahead=args.lookahead,
        trace=args.trace,
        metrics_interval=args.metrics_interval,
        log_dir=args.log_dir,
        report=args.report,
        decisions={"on": True, "full": "full", "off": False}[args.decisions],
        flight_recorder=args.flightrec,
        live_table=args.live_table,
        resume=args.resume,
        verbose=True,
        seed=args.seed,
    )

    print("\n[tune] results:")
    for row in analysis.results_table():
        cfg_str = {k: (round(v, 5) if isinstance(v, float) else v)
                   for k, v in row["config"].items()
                   if isinstance(v, (int, float, str))}
        best = "   n/a" if row["best"] is None else f"{row['best']:.4f}"
        print(f"  {row['trial_id']}: {row['status']:10s} iters={row['iterations']:3d} "
              f"best={best} {cfg_str}")
    if analysis.best_value() is None:
        print("[tune] no trial produced a result (check that "
              "--devices-per-trial fits --total-devices)")
        return
    print(f"[tune] best config: {json.dumps({k: v for k, v in analysis.best_config().items() if isinstance(v, (int, float, str))})}")
    print(f"[tune] best loss:   {analysis.best_value():.4f}")
    print(f"[tune] total training iterations across trials: {analysis.total_iterations()}")


if __name__ == "__main__":
    main()
