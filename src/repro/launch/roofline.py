"""Roofline terms from a compiled dry-run artifact (no real hardware).

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

Methodology.  ``compiled.cost_analysis()`` reports per-device numbers but
counts ``while`` bodies ONCE (verified empirically: a scanned L-layer stack
reports 1/L of the flops), so we parse the compiled HLO text ourselves and
walk the computation graph with loop trip counts (parsed from each loop
condition's bound constant):

  - FLOPs: every ``dot`` op contributes 2 * prod(result dims) * prod(lhs
    contracting dims) — matmul flops dominate these workloads; elementwise
    flops are not counted (noted under-count, typically <5%).
  - HBM bytes: per top-level op (fusion boundaries), result + operand buffer
    bytes — the standard post-fusion traffic proxy.
  - collective bytes: result buffers of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (``-done`` halves of
    async pairs skipped).

All three are per-device, trip-weighted.  MODEL_FLOPS = 6·N·D (train) or
2·N·D (inference) uses active params for MoE.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import numpy as np

from .mesh import HW

__all__ = ["RooflineReport", "analyze", "hlo_costs", "model_flops",
           "normalize_cost_analysis"]


def normalize_cost_analysis(ca) -> dict:
    """``compiled.cost_analysis()`` returns a dict on jax>=0.5, a [dict] on
    0.4.x, and None on some backends; normalize all three to a dict."""
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    return ca or {}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
# first dot operand; commas inside shape brackets / layout braces (older HLO
# dumps print full operand types, e.g. "dot(f32[64,128]{1,0} %a, ...)") don't
# terminate the match.
_DOT_ARGS_RE = re.compile(r"dot\(((?:\[[^\]]*\]|\{[^}]*\}|[^,)\[{])+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)(?:\.clone)? \([^)]*\)", re.M)
_WHILE_RE = re.compile(r"while\(.*?condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CALLEE_RE = re.compile(r"(?:to_apply|body|condition|branch_computations)=\{?%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")

_CONTROL_OPS = {"while", "conditional", "call", "tuple", "get-tuple-element",
                "parameter", "constant", "after-all", "custom-call"}


def _shape_elems_bytes(dt: str, dims: str) -> Tuple[int, int]:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dt, 0)


def _buffer_bytes(type_str: str) -> int:
    return sum(_shape_elems_bytes(dt, dims)[1]
               for dt, dims in _SHAPE_RE.findall(type_str))


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """name -> body text, by tracking top-level brace blocks."""
    comps: Dict[str, str] = {}
    name, depth, buf = None, 0, []
    for line in hlo_text.splitlines():
        if depth == 0:
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                name, buf, depth = m.group(1), [line], 1
                if line.strip().startswith("ENTRY"):
                    name = "__entry__"
                continue
        else:
            depth += line.count("{") - line.count("}")
            buf.append(line)
            if depth <= 0:
                comps[name] = "\n".join(buf)
                name, depth, buf = None, 0, []
    return comps


def _trip_count(cond_text: str) -> int:
    """Scan loops lower to ``iv < N``; take the max s32 constant as N."""
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


def _line_costs(line: str, in_fusion: bool,
                symtab: Dict[str, str]) -> Dict[str, float]:
    """Costs contributed by a single HLO instruction line.

    ``symtab`` maps instruction names to their result type strings (operands
    are printed by name only in modern HLO dumps)."""
    out: Dict[str, float] = {}
    cm = _COLL_RE.search(line)
    if cm and cm.group(3) != "-done":
        kind = cm.group(2)
        b = _buffer_bytes(cm.group(1))
        out["collective_bytes"] = b
        out[f"coll:{kind}"] = b
        return out

    m = _OP_RE.match(line)
    if not m:
        return out
    types, op = m.group(2), m.group(3)

    if op == "dot":
        contract = _CONTRACT_RE.search(line)
        result_elems = sum(_shape_elems_bytes(dt, dims)[0]
                           for dt, dims in _SHAPE_RE.findall(types))
        k = 1
        am = _DOT_ARGS_RE.search(line)
        lhs_type = None
        if am:
            tok = am.group(1).strip()
            if "[" in tok:
                lhs_type = tok
            else:
                lhs_type = symtab.get(tok.lstrip("%"))
        if lhs_type and contract:
            shapes = _SHAPE_RE.findall(lhs_type)
            if shapes:
                dimlist = [int(d) for d in shapes[0][1].split(",") if d]
                for ci in contract.group(1).split(","):
                    if ci and int(ci) < len(dimlist):
                        k *= dimlist[int(ci)]
        out["dot_flops"] = 2.0 * result_elems * k

    if not in_fusion and op not in _CONTROL_OPS:
        # post-fusion traffic proxy: result buffers of top-level ops (operand
        # traffic is the producing op's result; counting both would double).
        out["traffic_bytes"] = _buffer_bytes(types)
    return out


def hlo_costs(hlo_text: str) -> Dict[str, float]:
    """Trip-weighted per-device costs from compiled HLO text."""
    comps = _split_computations(hlo_text)
    fusion_comps = {n for n in comps if "fused" in n}

    def direct(name: str) -> Dict[str, float]:
        acc: Dict[str, float] = {}
        in_fusion = name in fusion_comps
        lines = comps[name].splitlines()
        symtab: Dict[str, str] = {}
        for line in lines:
            m = _OP_RE.match(line)
            if m:
                symtab[m.group(1)] = m.group(2)
        for line in lines:
            for k, v in _line_costs(line, in_fusion, symtab).items():
                acc[k] = acc.get(k, 0.0) + v
            # entry parameters = real HBM reads (weights/caches/batch), once
            if name == "__entry__":
                m = _OP_RE.match(line)
                if m and m.group(3) == "parameter":
                    acc["traffic_bytes"] = acc.get("traffic_bytes", 0.0) \
                        + _buffer_bytes(m.group(2))
        return acc

    cache: Dict[str, Dict[str, float]] = {}

    def total(name: str, seen=()) -> Dict[str, float]:
        if name in cache:
            return cache[name]
        if name not in comps or name in seen:
            return {}
        text = comps[name]
        acc = direct(name)
        handled = set()
        for m in _WHILE_RE.finditer(text):
            cond, body = m.group(1), m.group(2)
            trips = _trip_count(comps.get(cond, ""))
            for k, v in total(body, seen + (name,)).items():
                acc[k] = acc.get(k, 0.0) + v * trips
            handled.update({cond, body})
        for m in _CALLEE_RE.finditer(text):
            callee = m.group(1)
            if callee in handled or callee not in comps:
                continue
            for k, v in total(callee, seen + (name,)).items():
                acc[k] = acc.get(k, 0.0) + v
            handled.add(callee)
        cache[name] = acc
        return acc

    entry = "__entry__" if "__entry__" in comps else (next(iter(comps)) if comps else "")
    return total(entry) if entry else {}


def model_flops(n_params_active: int, n_tokens: int, kind: str) -> float:
    """6·N·D for a train step (fwd+bwd); 2·N·D for inference-only steps."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * n_tokens


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device, trip-weighted, from the HLO walk
    device_flops: float          # dot flops
    device_bytes: float          # traffic proxy
    collective_bytes: float
    collectives_by_kind: Dict[str, int]
    # raw cost_analysis (loop bodies counted once — for reference only)
    ca_flops_raw: float
    ca_bytes_raw: float
    # memory_analysis (per device)
    arg_bytes: int
    temp_bytes: int
    output_bytes: int
    # model-level
    model_flops_total: float
    n_tokens: int

    @property
    def compute_s(self) -> float:
        return self.device_flops / HW.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.device_bytes / HW.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / HW.ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO flops — catches remat/redundancy waste."""
        total_hlo = self.device_flops * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def hbm_per_device_gib(self) -> float:
        return (self.arg_bytes + self.temp_bytes) / 2**30

    @property
    def step_time_s(self) -> float:
        """No-overlap roofline estimate: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "device_flops": self.device_flops,
            "device_bytes": self.device_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives_by_kind": self.collectives_by_kind,
            "ca_flops_raw": self.ca_flops_raw, "ca_bytes_raw": self.ca_bytes_raw,
            "arg_bytes": self.arg_bytes, "temp_bytes": self.temp_bytes,
            "output_bytes": self.output_bytes,
            "model_flops_total": self.model_flops_total,
            "n_tokens": self.n_tokens,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "hbm_per_device_gib": self.hbm_per_device_gib,
            "step_time_s": self.step_time_s,
        }


def analyze(
    arch: str, shape_name: str, mesh_name: str, chips: int,
    compiled, n_params_active: int, n_tokens: int, kind: str,
    hlo_text: Optional[str] = None,
) -> RooflineReport:
    ca = normalize_cost_analysis(compiled.cost_analysis())
    ma = compiled.memory_analysis()
    text = hlo_text if hlo_text is not None else compiled.as_text()
    costs = hlo_costs(text)
    by_kind = {k.split(":", 1)[1]: int(v) for k, v in costs.items()
               if k.startswith("coll:")}
    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        device_flops=float(costs.get("dot_flops", 0.0)),
        device_bytes=float(costs.get("traffic_bytes", 0.0)),
        collective_bytes=float(costs.get("collective_bytes", 0.0)),
        collectives_by_kind=by_kind,
        ca_flops_raw=float(ca.get("flops", 0.0)),
        ca_bytes_raw=float(ca.get("bytes accessed", 0.0)),
        arg_bytes=int(getattr(ma, "argument_size_in_bytes", 0)),
        temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0)),
        output_bytes=int(getattr(ma, "output_size_in_bytes", 0)),
        model_flops_total=model_flops(n_params_active, n_tokens, kind),
        n_tokens=n_tokens,
    )
