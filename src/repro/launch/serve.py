"""Batched serving driver: prefill a batch of prompts, decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, list_archs
from ..models import decode_step, init_params, param_count, prefill
from ..train.serve_step import sample_tokens


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.arch_id} is encoder-only: no decode")

    params = init_params(jax.random.key(0), cfg)
    print(f"[serve] {cfg.arch_id}: {param_count(params):,} params")

    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    max_len = S + args.new_tokens

    t0 = time.time()
    prefill_jit = jax.jit(lambda p, b: prefill(p, b, cfg, max_len))
    logits, caches = prefill_jit(params, {"tokens": prompts})
    t_prefill = time.time() - t0
    print(f"[serve] prefill {B}x{S}: {t_prefill:.2f}s "
          f"({B*S/t_prefill:.0f} tok/s)")

    decode_jit = jax.jit(lambda c, t, pos: decode_step(params, c, t, pos, cfg))
    key = jax.random.key(2)
    tok = sample_tokens(logits, key, args.temperature)
    out = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        key = jax.random.fold_in(key, i)
        logits, caches = decode_jit(caches, tok, jnp.asarray(S + i, jnp.int32))
        tok = sample_tokens(logits, key, args.temperature)
        out.append(np.asarray(tok))
    t_dec = time.time() - t0
    gen = np.stack(out, axis=1)
    print(f"[serve] decode {args.new_tokens} steps: {t_dec:.2f}s "
          f"({B*(args.new_tokens-1)/max(t_dec,1e-9):.0f} tok/s)")
    print(f"[serve] sample output tokens (row 0): {gen[0][:16].tolist()}")


if __name__ == "__main__":
    main()
