"""Static HTML run report from a run's observability artifacts (DESIGN.md §9).

    PYTHONPATH=src python -m repro.launch.report runs/demo
    PYTHONPATH=src python -m repro.launch.report --journal runs/demo/events.jsonl \
        --trace trace.json --metrics runs/demo/metrics.jsonl \
        --metric loss --mode min --out report.html

Positional form: point it at a ``--log-dir`` from a previous run and it picks
up ``events.jsonl`` / ``metrics.jsonl`` / ``trace.json`` if present, writing
``report.html`` next to them.  The report is one self-contained HTML file —
inline CSS + inline SVG, no scripts, no external fetches — rendered by
``repro.obs.report.build_report`` from the JSONL journal (v2 with run_header
or header-less v1, truncated tails tolerated), the Chrome trace (lifecycle
gantt + restart markers), and the metrics snapshot stream.
"""
from __future__ import annotations

import argparse
import os
import sys

from ..obs.analysis import ExperimentAnalysis
from ..obs.report import build_report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("log_dir", nargs="?", default=None,
                    help="run directory: uses events.jsonl / metrics.jsonl / "
                         "trace.json found inside, writes report.html there")
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="JSONL event journal (overrides log_dir discovery)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="Chrome trace-event JSON for the lifecycle gantt")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="metrics snapshot JSONL stream")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="output HTML path (default: <log_dir>/report.html "
                         "or report.html beside the journal)")
    ap.add_argument("--metric", default=None,
                    help="metric for curves/best-config ranking (default: "
                         "first metric in the journal)")
    ap.add_argument("--mode", default="min", choices=["min", "max"])
    ap.add_argument("--title", default="repro run report")
    args = ap.parse_args(argv)

    journal, trace, metrics, out = (args.journal, args.trace, args.metrics,
                                    args.out)
    if args.log_dir:
        def find(name):
            p = os.path.join(args.log_dir, name)
            return p if os.path.exists(p) else None
        journal = journal or find("events.jsonl")
        trace = trace or find("trace.json")
        metrics = metrics or find("metrics.jsonl")
        out = out or os.path.join(args.log_dir, "report.html")
    if journal is None:
        ap.error("no journal: pass --journal PATH or a log_dir containing "
                 "events.jsonl")
    out = out or os.path.join(os.path.dirname(journal) or ".", "report.html")

    analysis = ExperimentAnalysis.from_journal(journal)
    html = build_report(analysis=analysis, trace_path=trace,
                        metrics_path=metrics, metric=args.metric,
                        mode=args.mode, title=args.title)
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        f.write(html)
    summary = analysis.summary(metric=args.metric, mode=args.mode)
    print(f"[report] {len(analysis)} trials "
          f"({summary['total_results']} results, "
          f"{summary['total_iterations']} iterations) -> {out}",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
