"""Launchers: production mesh, multi-pod dry-run, train/serve/tune drivers,
roofline analysis and §Perf hillclimb variants.

NOTE: ``dryrun`` and ``perf`` set ``XLA_FLAGS`` for 512 placeholder devices at
import time — import them only in dedicated processes, never from tests.
"""
