"""§Perf hillclimb driver: named sharding/config variants for the three
selected (arch x shape) pairs, each lowered+compiled and roofline-analyzed.

    PYTHONPATH=src python -m repro.launch.perf --pair smollm  # or qwen/granite/all

Variants encode the hypothesis->change->measure iterations recorded in
EXPERIMENTS.md §Perf; results append to benchmarks/results/perf_iterations.json.
"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

import argparse
import dataclasses
import json
from typing import Any, Dict, List

from ..models.config import MoEConfig
from ..configs import get_config
from .dryrun import lower_one
from .mesh import make_production_mesh
from .shapes import SHAPES

RESULTS = os.path.join("benchmarks", "results")


def _moe_override(arch: str, **moe_kw) -> Dict[str, Any]:
    base = get_config(arch).moe
    return {"moe": dataclasses.replace(base, **moe_kw)}


# variant name -> kwargs for lower_one
PAIRS: Dict[str, List[Dict[str, Any]]] = {
    # Most representative of the paper's technique (small-model HPO sweeps):
    # baseline wastes 16x redundant attention compute (9 heads can't TP-shard).
    "smollm": [
        dict(arch="smollm-135m", shape="train_4k", variant="baseline"),
        dict(arch="smollm-135m", shape="train_4k", variant="dp_only",
             strategy="dp_only"),
        dict(arch="smollm-135m", shape="train_4k", variant="dp_only+noremat",
             strategy="dp_only", cfg_overrides={"remat": False}),
    ],
    # Most collective-bound + over-HBM: the 110B stress case.
    "qwen": [
        dict(arch="qwen1.5-110b", shape="train_4k", variant="baseline(mb8)"),
        dict(arch="qwen1.5-110b", shape="train_4k", variant="mb1",
             cfg_overrides={"train_microbatch": 1}),
        dict(arch="qwen1.5-110b", shape="train_4k", variant="mb16",
             cfg_overrides={"train_microbatch": 16}),
        dict(arch="qwen1.5-110b", shape="train_4k", variant="mb8+seqpar",
             seq_parallel=True),
        dict(arch="qwen1.5-110b", shape="train_4k", variant="mb1+seqpar",
             seq_parallel=True, cfg_overrides={"train_microbatch": 1}),
        # halve optimizer-state memory: AdamW moments in bf16
        dict(arch="qwen1.5-110b", shape="train_4k", variant="mb8+bf16mom",
             cfg_overrides={"opt_moment_dtype": "bfloat16"}),
        dict(arch="qwen1.5-110b", shape="train_4k", variant="mb4",
             cfg_overrides={"train_microbatch": 4}),
    ],
    # Worst useful-flops fraction: fine-grained MoE with E=40 (no clean EP).
    "granite": [
        dict(arch="granite-moe-3b-a800m", shape="train_4k", variant="baseline"),
        dict(arch="granite-moe-3b-a800m", shape="train_4k", variant="scatter",
             cfg_overrides=_moe_override("granite-moe-3b-a800m", impl="scatter")),
        dict(arch="granite-moe-3b-a800m", shape="train_4k", variant="scatter+g1024",
             cfg_overrides=_moe_override("granite-moe-3b-a800m", impl="scatter",
                                         group_size=1024)),
        dict(arch="granite-moe-3b-a800m", shape="train_4k", variant="einsum+g64",
             cfg_overrides=_moe_override("granite-moe-3b-a800m", group_size=64)),
        # vocab 49155 is indivisible by 16 -> logits replicate; pad to 49280
        # (= 16*3080, 128-aligned) so embed/head/logits shard over the TP axis
        dict(arch="granite-moe-3b-a800m", shape="train_4k", variant="padvocab",
             cfg_overrides={"padded_vocab": 49280}),
        dict(arch="granite-moe-3b-a800m", shape="train_4k", variant="padvocab+mb4",
             cfg_overrides={"padded_vocab": 49280, "train_microbatch": 4}),
        dict(arch="granite-moe-3b-a800m", shape="train_4k", variant="dp_only",
             strategy="dp_only"),
    ],
    # Beyond-paper check on the second MoE (EP divisible): does scatter help
    # when expert parallelism IS available?
    "deepseek": [
        dict(arch="deepseek-moe-16b", shape="train_4k", variant="baseline"),
        dict(arch="deepseek-moe-16b", shape="train_4k", variant="scatter",
             cfg_overrides=_moe_override("deepseek-moe-16b", impl="scatter")),
    ],
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pair", default="all", choices=["all"] + list(PAIRS))
    ap.add_argument("--variant", default=None, help="run only this variant name")
    args = ap.parse_args()

    mesh = make_production_mesh()
    selected = PAIRS if args.pair == "all" else {args.pair: PAIRS[args.pair]}
    path = os.path.join(RESULTS, "perf_iterations.json")
    records = []
    if os.path.exists(path):
        with open(path) as f:
            records = json.load(f)

    for pair, variants in selected.items():
        for v in variants:
            if args.variant and v["variant"] != args.variant:
                continue
            v = dict(v)
            shape = SHAPES[v.pop("shape")]
            rec = lower_one(v.pop("arch"), shape, mesh, "pod16x16", **v)
            rec["pair"] = pair
            records = [r for r in records
                       if not (r.get("pair") == pair
                               and r.get("variant") == rec.get("variant")
                               and r.get("shape") == rec.get("shape"))]
            records.append(rec)
            os.makedirs(RESULTS, exist_ok=True)
            with open(path, "w") as f:
                json.dump(records, f, indent=1, default=str)


if __name__ == "__main__":
    main()
