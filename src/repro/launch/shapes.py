"""Assigned input shapes and per-(arch, shape) input specs.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input — the
dry-run lowers against these (weak-type-correct, shardable, no allocation).

Applicability (DESIGN.md §4):
  - encoder-only archs (hubert) have no decode step -> decode shapes skipped;
    its ``prefill_32k`` is the encoder forward.
  - ``long_500k`` requires sub-quadratic decode state: SSM / hybrid / SWA only.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import ModelConfig
from ..models import transformer as T

__all__ = ["ShapeSpec", "SHAPES", "applicable", "skip_reason", "input_specs",
           "dryrun_config"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    if shape.kind == "decode":
        if not cfg.supports_decode:
            return "encoder-only: no autoregressive decode"
        if shape.seq_len > 100_000 and not cfg.supports_long_context:
            return "full attention without sub-quadratic variant: long-context skipped"
    return None


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    return skip_reason(cfg, shape) is None


def dryrun_config(cfg: ModelConfig) -> ModelConfig:
    """bf16 params/activations, chunked attention, per-layer remat.

    remat=True for every arch at production sequence lengths: per-layer
    activation checkpointing is the standard 4k-training memory policy (the
    §Perf log quantifies its compute-vs-memory trade)."""
    return dataclasses.replace(
        cfg, param_dtype="bfloat16", activation_dtype="bfloat16",
        attn_impl="auto", remat=True)


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _batch_structs(cfg: ModelConfig, B: int, S: int, with_labels: bool) -> Dict[str, Any]:
    adt = cfg.activation_dtype
    if cfg.frontend == "audio_stub":
        batch = {"features": _sds((B, S, cfg.frontend_dim), adt)}
        if with_labels:
            batch["labels"] = _sds((B, S), "int32")
        return batch
    if cfg.frontend == "vision_stub":
        P_ = cfg.n_prefix_embeds
        text = S - P_
        batch = {
            "patch_embeds": _sds((B, P_, cfg.frontend_dim), adt),
            "tokens": _sds((B, text), "int32"),
        }
        if with_labels:
            batch["labels"] = _sds((B, text), "int32")
        return batch
    batch = {"tokens": _sds((B, S), "int32")}
    if with_labels:
        batch["labels"] = _sds((B, S), "int32")
    return batch


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract inputs for the lowered step of ``shape.kind``.

    train   -> {"batch": ...}                       (state built separately)
    prefill -> {"batch": ...}
    decode  -> {"caches": ..., "tokens": (B,), "pos": ()}
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": _batch_structs(cfg, B, S, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": _batch_structs(cfg, B, S, with_labels=False)}
    caches = jax.eval_shape(partial(T.init_caches, cfg, B, S))
    return {
        "caches": caches,
        "tokens": _sds((B,), "int32"),
        "pos": _sds((), "int32"),
    }
