from .pipeline import DataConfig, SyntheticLMDataset, make_batch_iterator, synthetic_batch
