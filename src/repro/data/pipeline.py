"""Deterministic synthetic data pipeline.

Real-framework API (shards, epochs, prefetch-ready iterators) over procedurally
generated token streams, so experiments are exactly reproducible offline.  The
stream is a Markov-ish mixture: token t+1 depends on token t through a seeded
permutation plus noise — learnable structure (loss decreases) without any
external dataset.  Each data-parallel shard slices the global batch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import numpy as np

from ..models import ModelConfig

__all__ = ["DataConfig", "SyntheticLMDataset", "make_batch_iterator", "synthetic_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    noise: float = 0.1       # P(random token) vs structured continuation
    shard_index: int = 0
    num_shards: int = 1


class SyntheticLMDataset:
    """Infinite deterministic LM stream; batch b of step s is a pure function
    of (seed, s, b) — restarts and shard re-slicing reproduce identical data."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.num_shards:
            raise ValueError("global_batch must divide evenly across shards")
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.perm = rng.permutation(cfg.vocab_size)  # the "grammar"

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        local = cfg.global_batch // cfg.num_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + cfg.shard_index)
        first = rng.integers(0, cfg.vocab_size, size=(local, 1))
        toks = [first]
        for _ in range(cfg.seq_len - 1):
            nxt = self.perm[toks[-1]]
            noise = rng.integers(0, cfg.vocab_size, size=nxt.shape)
            use_noise = rng.random(nxt.shape) < cfg.noise
            toks.append(np.where(use_noise, noise, nxt))
        tokens = np.concatenate(toks, axis=1).astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], tokens[:, :1]], axis=1).astype(np.int32)  # shift-left
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_iterator(cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    return iter(SyntheticLMDataset(cfg))


def synthetic_batch(model_cfg: ModelConfig, batch: int, seq_len: int,
                    seed: int = 0) -> Dict[str, np.ndarray]:
    """One batch with family-appropriate inputs (for smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    if model_cfg.frontend == "audio_stub":
        return {
            "features": rng.standard_normal(
                (batch, seq_len, model_cfg.frontend_dim)).astype(np.float32),
            "labels": rng.integers(0, model_cfg.vocab_size,
                                   (batch, seq_len)).astype(np.int32),
        }
    if model_cfg.frontend == "vision_stub":
        P = model_cfg.n_prefix_embeds
        text = max(seq_len - P, 1)
        return {
            "patch_embeds": rng.standard_normal(
                (batch, P, model_cfg.frontend_dim)).astype(np.float32),
            "tokens": rng.integers(0, model_cfg.vocab_size,
                                   (batch, text)).astype(np.int32),
            "labels": rng.integers(0, model_cfg.vocab_size,
                                   (batch, text)).astype(np.int32),
        }
    data = SyntheticLMDataset(DataConfig(
        global_batch=batch, seq_len=seq_len,
        vocab_size=model_cfg.vocab_size, seed=seed))
    return data.batch_at(0)
